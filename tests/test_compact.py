"""Stream-compaction kernel vs reference on empty/full/ragged masks."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.compact.ops import compact_pairs, stream_compact
from repro.kernels.compact.ref import compact_ref


def _oracle(mask, vals, n_out):
    packed = np.asarray(vals)[np.asarray(mask)]
    return min(len(packed), n_out), packed[:n_out]


def _check(mask, vals, n_out, **kw):
    cnt, out = stream_compact(jnp.asarray(mask), jnp.asarray(vals), n_out,
                              **kw)
    exp_cnt, exp = _oracle(mask, vals, n_out)
    assert int(cnt) == exp_cnt
    assert (np.asarray(out)[:exp_cnt] == exp).all()


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
def test_compact_masks(use_pallas, density):
    rs = np.random.RandomState(0)
    n, n_out = 700, 512
    mask = rs.rand(n) < density
    vals = rs.randint(0, 1 << 30, (n, 2)).astype(np.int32)
    kw = {"use_pallas": use_pallas}
    if use_pallas:
        kw["interpret"] = True
    _check(mask, vals, n_out, **kw)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_compact_overflow_drops_highest(use_pallas):
    """Survivors past n_out are the highest input indices; they drop."""
    n, n_out = 300, 64
    mask = np.ones(n, bool)
    vals = np.arange(n, dtype=np.int32)[:, None]
    kw = {"use_pallas": use_pallas, "interpret": True} if use_pallas \
        else {"use_pallas": False}
    cnt, out = stream_compact(jnp.asarray(mask), jnp.asarray(vals), n_out,
                              **kw)
    assert int(cnt) == n_out
    assert (np.asarray(out)[:, 0] == np.arange(n_out)).all()


@pytest.mark.parametrize("n", [1, 255, 256, 257, 1000])
def test_compact_ragged_sizes_pallas_matches_ref(n):
    rs = np.random.RandomState(n)
    mask = rs.rand(n) < 0.5
    vals = rs.randint(0, 1 << 30, (n, 2)).astype(np.int32)
    n_out = 256
    c_ref, o_ref = compact_ref(jnp.asarray(mask), jnp.asarray(vals), n_out)
    c_pal, o_pal = stream_compact(jnp.asarray(mask), jnp.asarray(vals),
                                  n_out, use_pallas=True, interpret=True)
    assert int(c_ref) == int(c_pal)
    k = int(c_ref)
    assert (np.asarray(o_ref)[:k] == np.asarray(o_pal)[:k]).all()


def test_compact_pairs_roundtrips_uint32():
    rs = np.random.RandomState(7)
    n = 500
    mask = rs.rand(n) < 0.4
    q = rs.randint(0, 1 << 20, n).astype(np.int32)
    codes = rs.randint(0, 1 << 30, n).astype(np.uint32)
    cnt, q_out, c_out = compact_pairs(jnp.asarray(mask), jnp.asarray(q),
                                      jnp.asarray(codes), 1024,
                                      use_pallas=False)
    k = int(cnt)
    assert (np.asarray(q_out)[:k] == q[mask][:k]).all()
    assert (np.asarray(c_out)[:k] == codes[mask][:k]).all()
    assert np.asarray(c_out).dtype == np.uint32
