"""Streamed x quantized ragged multi-scene traversal parity.

The kernel-complete persistent path must serve ragged mixed-size scene
batches under the STREAMED metadata layout at every row format (fp32 /
bf16 / u8) with bitwise-identical verdicts and work counters across all
four execution paths that can serve a multi-scene batch:

  1. ``wavefront``            — padded-vmap legacy arm (verdict reference)
  2. ``wavefront_fused``      — ragged flat frontier, per-level kernels
  3. ``wavefront_persistent`` — jnp ref arm (use_pallas_traverse=False)
  4. ``wavefront_persistent`` — Pallas megakernel arm (interpret off-TPU)

The persistent ref and kernel arms must additionally agree on EVERY
counter (including the streamed-window row counts — the ref arm models
the kernel's per-scene sub-extent window schedule row-exactly), and none
of the persistent runs may take a silent ref-arm downgrade
(``ref_arm_fallbacks == 0``).  A subprocess case repeats the kernel==ref
check on 8 virtual CPU devices (the CI topology of the sharded suite).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.geometry import OBBs, random_obbs
from repro.core.octree import build_octree
from repro.core.quantize import META_FORMATS
from repro.engine import CollisionEngine, EngineConfig, query_batched_scenes
from repro.engine.plan import plan_scenes

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

WORK_FIELDS = ("nodes_traversed", "leaf_tests", "axis_tests_executed",
               "axis_tests_decoded", "sphere_tests", "frontier_overflow",
               "meta_rows_streamed", "meta_bytes_streamed",
               "ref_arm_fallbacks")


def _ragged_batch(seed=0, sizes=(220, 900, 64), depth=3, m=6):
    rs = np.random.RandomState(seed)
    trees = [build_octree(rs.uniform(-1, 1, (n, 3)).astype(np.float32),
                          depth=depth) for n in sizes]
    sets = [random_obbs(jax.random.PRNGKey(10 + i), m)
            for i in range(len(sizes))]
    stack = OBBs(center=jnp.stack([o.center for o in sets]),
                 half=jnp.stack([o.half for o in sets]),
                 rot=jnp.stack([o.rot for o in sets]))
    return trees, stack


@pytest.mark.parametrize("fmt", ["bf16", "u8"])
def test_ragged_streamed_quantized_four_mode_parity(fmt):
    """Ragged scenes, streamed windows, compressed rows: verdicts bitwise
    across padded / fused / persistent-ref / persistent-kernel, counters
    bitwise between the persistent arms, zero ref-arm fallbacks."""
    trees, stack = _ragged_batch()
    ref_v, _ = query_batched_scenes(trees, stack,
                                    EngineConfig(mode="wavefront"))
    fused_v, _ = query_batched_scenes(
        trees, stack, EngineConfig(mode="wavefront_fused", meta_format=fmt))
    assert (np.asarray(fused_v) == np.asarray(ref_v)).all(), fmt

    arms = {}
    for use_pallas in (False, True):
        v, c = query_batched_scenes(trees, stack, EngineConfig(
            mode="wavefront_persistent", use_pallas_traverse=use_pallas,
            stream_meta=True, meta_format=fmt))
        assert (np.asarray(v) == np.asarray(ref_v)).all(), (fmt, use_pallas)
        assert c.ref_arm_fallbacks == 0, (fmt, use_pallas)
        assert c.meta_rows_streamed > 0, (fmt, use_pallas)
        arms[use_pallas] = c
    for f in WORK_FIELDS:
        assert getattr(arms[True], f) == getattr(arms[False], f), (fmt, f)
    assert arms[True].nodes_per_level == arms[False].nodes_per_level, fmt
    assert (arms[True].exit_histogram == arms[False].exit_histogram).all()


def test_ragged_streamed_bytes_scale_with_format():
    """The streamed row COUNT is format-independent; bytes scale with the
    packed row width (16/8/4 B), so u8 streams exactly 4x less than fp32."""
    trees, stack = _ragged_batch()
    rows, bytes_ = {}, {}
    for fmt in META_FORMATS:
        _, c = query_batched_scenes(trees, stack, EngineConfig(
            mode="wavefront_persistent", use_pallas_traverse=True,
            stream_meta=True, meta_format=fmt))
        rows[fmt], bytes_[fmt] = c.meta_rows_streamed, c.meta_bytes_streamed
    assert rows["fp32"] == rows["bf16"] == rows["u8"] > 0
    assert bytes_["fp32"] == 2 * bytes_["bf16"] == 4 * bytes_["u8"]


def test_ragged_streamed_quantized_kernel_on_8_devices():
    """Interpret-mode megakernel == ref arm on a ragged streamed quantized
    batch with 8 virtual CPU devices present (the sharded-CI topology);
    subprocess-isolated so the rest of the suite keeps one device."""
    body = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {os.path.join(ROOT, 'src')!r})
    sys.path.insert(0, {os.path.join(ROOT, 'tests')!r})
    import jax
    import numpy as np
    assert jax.device_count() == 8
    from repro.engine import EngineConfig, query_batched_scenes
    from test_ragged_quantized import WORK_FIELDS, _ragged_batch

    trees, stack = _ragged_batch()
    ref_v, _ = query_batched_scenes(trees, stack,
                                    EngineConfig(mode="wavefront"))
    for fmt in ("bf16", "u8"):
        got = {{}}
        for use_pallas in (False, True):
            v, c = query_batched_scenes(trees, stack, EngineConfig(
                mode="wavefront_persistent", use_pallas_traverse=use_pallas,
                stream_meta=True, meta_format=fmt))
            assert (np.asarray(v) == np.asarray(ref_v)).all(), fmt
            assert c.ref_arm_fallbacks == 0, fmt
            got[use_pallas] = c
        for f in WORK_FIELDS:
            assert getattr(got[True], f) == getattr(got[False], f), (fmt, f)
    print("OK")
    """)
    res = subprocess.run([sys.executable, "-c", body],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "OK" in res.stdout


def test_oversized_owner_group_falls_back_loudly(caplog):
    """A plan the kernel cannot tile (owner group wider than MAX_TILE_BQ)
    must still answer correctly on the ref arm AND report the downgrade:
    ref_arm_fallbacks == 1 plus a debug log naming the plan shape."""
    import logging

    from repro.core.sact import PAYLOAD_INF
    from repro.engine.plan import plan_edges
    from repro.kernels.persist.ops import MAX_TILE_BQ

    rs = np.random.RandomState(3)
    tree = build_octree(rs.uniform(-1, 1, (500, 3)).astype(np.float32),
                        depth=3)
    n = MAX_TILE_BQ + 8            # one owner group too wide for any tile
    obbs = random_obbs(jax.random.PRNGKey(4), n)
    owner = np.zeros(n, np.int32)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_persistent",
                                             use_pallas_traverse=True))
    with caplog.at_level(logging.DEBUG, logger="repro.engine.executor"):
        best, c = eng.execute(plan_edges(obbs, owner, 1))
    assert c.ref_arm_fallbacks == 1
    assert any("edges[" in r.message for r in caplog.records)
    # the ref arm still answers: one group, boolean-style verdict payload
    assert best.shape == (1,) and int(best[0]) in (0, PAYLOAD_INF)
