"""Substrate tests: optimizer, data pipeline, roofline analysis, MCL,
planner training, checkpoint basics (single-device parts)."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec, get_smoke_config
from repro.train import optimizer as opt_mod


def test_adamw_matches_reference_update():
    cfg = opt_mod.OptConfig(lr=1e-2, beta1=0.9, beta2=0.999, eps=1e-8,
                            weight_decay=0.0, clip_norm=1e9,
                            warmup_steps=0, total_steps=1, min_lr_frac=1.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = opt_mod.init_opt_state(params, cfg)
    new_p, new_s, m = opt_mod.adamw_update(params, grads, state, cfg)
    # step 1 with bias correction: mhat = g, vhat = g^2
    g = np.asarray([0.1, 0.2, -0.3])
    expect = np.asarray([1.0, -2.0, 3.0]) - 1e-2 * g / (np.abs(g) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(new_s["step"]) == 1


def test_grad_clipping():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert abs(float(opt_mod.global_norm(clipped)) - 1.0) < 1e-5


def test_schedule_warmup_and_cosine():
    cfg = opt_mod.OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(opt_mod.schedule(jnp.asarray(0), cfg)) == 0.0
    assert abs(float(opt_mod.schedule(jnp.asarray(10), cfg)) - 1.0) < 1e-6
    end = float(opt_mod.schedule(jnp.asarray(110), cfg))
    assert abs(end - 0.1) < 1e-6


def test_bf16_optimizer_states():
    cfg = opt_mod.OptConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    st = opt_mod.init_opt_state(params, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    new_p, new_s, _ = opt_mod.adamw_update(
        params, {"w": jnp.ones((4, 4)) * 0.1}, st, cfg)
    assert new_s["v"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(new_p["w"], np.float32)).all()


def test_data_pipeline_determinism_and_host_sharding():
    from repro.data.pipeline import synth_batch
    cfg = get_smoke_config("glm4_9b")
    shape = ShapeSpec("t", 32, 8, "train")
    a = synth_batch(cfg, shape, step=3)
    b = synth_batch(cfg, shape, step=3)
    assert (a["tokens"] == b["tokens"]).all()
    c = synth_batch(cfg, shape, step=4)
    assert not (a["tokens"] == c["tokens"]).all()
    # host sharding: 2 hosts each get half the batch, different data
    h0 = synth_batch(cfg, shape, step=3, host_index=0, host_count=2)
    h1 = synth_batch(cfg, shape, step=3, host_index=1, host_count=2)
    assert h0["tokens"].shape[0] == 4
    assert not (h0["tokens"] == h1["tokens"]).all()
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()


def test_collective_parser_loop_aware():
    """A psum inside a scan must be multiplied by the trip count."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(
        0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.mesh import use_mesh
    from repro.roofline.analysis import parse_collective_bytes
    mesh = jax.make_mesh((8,), ("model",))
    def f(x, w):
        def body(c, _):
            # contraction over the sharded dim -> psum inside the loop
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return jnp.sum(y)
    with use_mesh(mesh):
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None)),
                                     NamedSharding(mesh, P("model", None)))
                    ).lower(jax.ShapeDtypeStruct((4, 64), jnp.float32),
                            jax.ShapeDtypeStruct((64, 64), jnp.float32)
                    ).compile()
    coll = parse_collective_bytes(c.as_text())
    total = sum(coll.values())
    print("COLL", coll, total)
    # one f32[4,64] all-reduce per iteration x 12 iterations (+ final sum)
    assert total >= 12 * 4 * 64 * 4, coll
    """)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "COLL" in res.stdout


def test_jaxpr_cost_scan_multiplication():
    from repro.roofline.jaxpr_cost import trace_cost
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    cost = trace_cost(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                      jax.ShapeDtypeStruct((128, 128), jnp.float32))
    expect = 2 * 128 * 128 * 128 * 10
    assert abs(cost.flops - expect) / expect < 0.05
    assert cost.bytes_major >= 10 * 128 * 128 * 4   # carries + dots


def test_mcl_engines_agree_and_converge():
    from repro.core.mcl import (make_corridor_world, ray_cast_compacted, ray_cast_dense)
    grid = make_corridor_world(jax.random.PRNGKey(0), size=96)
    rs = np.random.RandomState(2)
    org = jnp.asarray(rs.uniform(0.5, 4.0, (50, 2)).astype(np.float32))
    ang = jnp.asarray(rs.uniform(-np.pi, np.pi, 50).astype(np.float32))
    r1, c1 = ray_cast_dense(grid, org, ang, 4.0)
    r2, c2 = ray_cast_compacted(grid, org, ang, 4.0)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-6)
    assert c2 <= c1          # compaction never traverses more cells


def test_planner_bc_loss_decreases():
    from repro.models.planner import init_planner, planner_loss
    from repro.train.optimizer import OptConfig, adamw_update, init_opt_state
    rs = np.random.RandomState(0)
    B = 16
    batch = {
        "cloud": jnp.asarray(rs.uniform(-1, 1, (B, 256, 3)
                                        ).astype(np.float32)),
        "q": jnp.asarray(rs.uniform(-1, 1, (B, 7)).astype(np.float32)),
        "goal": jnp.asarray(rs.uniform(-1, 1, (B, 7)).astype(np.float32)),
        "expert_delta": jnp.asarray(
            rs.uniform(-0.3, 0.3, (B, 7)).astype(np.float32)),
    }
    params = init_planner(jax.random.PRNGKey(0), feat_dim=64, hidden=64)
    cfg = OptConfig(lr=3e-3, warmup_steps=0, total_steps=30)
    st = init_opt_state(params, cfg)
    lg = jax.jit(jax.value_and_grad(
        lambda p, b: planner_loss(p, b, "random", jax.random.PRNGKey(1))[0]))
    losses = []
    for i in range(15):
        loss, g = lg(params, batch)
        params, st, _ = adamw_update(params, g, st, cfg)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


def test_checkpoint_commit_protocol():
    from repro.train import checkpoint as ck
    tree = {"a": jnp.ones((4,)), "nested": {"b": jnp.zeros((2, 2))}}
    with tempfile.TemporaryDirectory() as d:
        ck.save_checkpoint(d, 1, tree, async_save=False)
        # a partial (uncommitted) checkpoint must be ignored
        os.makedirs(os.path.join(d, "step_00000007"), exist_ok=True)
        assert ck.latest_steps(d) == [1]
        restored, step = ck.restore_checkpoint(d, tree)
        assert step == 1
        assert (restored["a"] == tree["a"]).all()
