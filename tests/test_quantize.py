"""Quantized node-metadata formats (repro.core.quantize, DESIGN.md §3).

Soundness is enforced two ways, per the compression contract:

1. containment properties — outward-rounded u8/bf16 bounds always contain
   the fp32 bounds, degenerate thin boxes included (hypothesis-style via
   ``seeded_property``: random seeds with hypothesis installed, fixed
   seeds otherwise — never a skip);
2. bitwise verdict equality — every wavefront mode, every layout, every
   format produces the SAME verdict word and work counters as fp32
   (conservative bounds may only add visited nodes; for the aligned
   octree cells the packed coordinates are exact, so the inflation is
   exactly zero — asserted as the ``nodes_visited`` cap).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import seeded_property
from repro.core.counters import (BYTES_META_STREAM, BYTES_META_STREAM_BF16,
                                 BYTES_META_STREAM_U8)
from repro.core.geometry import random_obbs
from repro.core.octree import PAD_CODE, build_octree, device_octree
from repro.core.quantize import (META_FORMAT_WORDS, META_FORMATS, U8_GRID,
                                 bf16_round_down, bf16_round_up, bf16_support,
                                 dequantize_child_aabb_u8, format_eligible,
                                 pack_geom_bf16, pack_topo_bf16, pack_topo_u8,
                                 quantize_aabb_bf16, quantize_child_aabb_u8,
                                 unpack_geom_bf16, unpack_topo)
from repro.engine.executor import CollisionEngine, EngineConfig
from repro.kernels.persist.ops import (MetaChoice, choose_meta_layout,
                                       meta_stream_bytes, meta_table_bytes,
                                       traverse_whole)

WORK_FIELDS = ("nodes_traversed", "leaf_tests", "axis_tests_executed",
               "axis_tests_decoded", "sphere_tests", "frontier_overflow")


def _tree(seed=0, n=3000, depth=4):
    rs = np.random.RandomState(seed)
    pts = (rs.rand(n, 3).astype(np.float32) * 2 - 1)
    return build_octree(pts, depth=depth,
                        scene_lo=np.full(3, -1.0, np.float32), scene_size=2.0)


# ---------------------------------------------------------------------------
# Containment properties (satellite: quantization soundness)
# ---------------------------------------------------------------------------

@seeded_property(max_examples=25)
def test_u8_quantized_bounds_contain_fp32(seed):
    """Outward-rounded u8 child bounds ⊇ fp32 bounds, per parent cell —
    including degenerate thin (zero-extent) child boxes."""
    rs = np.random.RandomState(seed)
    parent_lo = rs.uniform(-10, 10, (64, 3)).astype(np.float32)
    cell = np.float32(rs.uniform(1e-3, 10))
    a = rs.uniform(0, 1, (64, 3))
    b = rs.uniform(0, 1, (64, 3))
    lo01, hi01 = np.minimum(a, b), np.maximum(a, b)
    if seed % 3 == 0:           # degenerate thin boxes: zero extent per axis
        hi01[:, seed % 2] = lo01[:, seed % 2]
    child_lo = parent_lo + lo01 * cell
    child_hi = parent_lo + hi01 * cell
    qlo, qhi = quantize_child_aabb_u8(child_lo, child_hi, parent_lo, cell)
    dlo, dhi = dequantize_child_aabb_u8(qlo, qhi, parent_lo, cell)
    assert (dlo <= child_lo).all()
    assert (dhi >= child_hi).all()
    # offsets live on the parent's 256-grid
    assert qlo.dtype == np.uint8 and qhi.dtype == np.uint8
    assert int(qlo.max()) < U8_GRID and int(qhi.max()) < U8_GRID


@seeded_property(max_examples=25)
def test_bf16_quantized_bounds_contain_fp32(seed):
    """bf16 outward rounding: round_down(lo) <= lo, round_up(hi) >= hi —
    thin boxes (hi == lo) stay contained too."""
    rs = np.random.RandomState(seed)
    lo = rs.uniform(-1e4, 1e4, (256, 3)).astype(np.float32)
    hi = lo + rs.uniform(0, 1e3, (256, 3)).astype(np.float32)
    hi[:32] = lo[:32]                             # degenerate thin boxes
    qlo, qhi = quantize_aabb_bf16(lo, hi)
    assert (qlo <= lo).all()
    assert (qhi >= hi).all()
    # the rounding is tight: one bf16 ulp of slack at most (mantissa step
    # is 2^-7 of the binade, i.e. <= |x| / 128 + smallest normal)
    slack = np.abs(lo) / 128 + 1e-30
    assert (lo - qlo <= slack).all()
    assert (qhi - hi <= np.abs(hi) / 128 + 1e-30).all()


def test_bf16_rounding_matches_ml_dtypes():
    """Cross-check the uint32-truncation bf16 rounding against native
    ml_dtypes casts — skipped WITH A NAMED REASON where the host lacks
    bf16 support (satellite: no raw lowering errors on such hosts)."""
    ok, reason = bf16_support()
    if not ok:
        pytest.skip(reason)
    import ml_dtypes
    rs = np.random.RandomState(11)
    x = np.concatenate([
        rs.uniform(-1e6, 1e6, 512).astype(np.float32),
        np.array([0.0, -0.0, 1.0, -1.0, 2.0 ** -120, -(2.0 ** -120)],
                 np.float32)])
    down, up = bf16_round_down(x), bf16_round_up(x)
    # round_down/up are representable and bracket x ...
    assert (down.astype(ml_dtypes.bfloat16).astype(np.float32) == down).all()
    assert (up.astype(ml_dtypes.bfloat16).astype(np.float32) == up).all()
    assert (down <= x).all() and (up >= x).all()
    # ... and exactly-representable values are fixed points of both.
    rep = x.astype(ml_dtypes.bfloat16).astype(np.float32)
    assert (bf16_round_down(rep) == rep).all()
    assert (bf16_round_up(rep) == rep).all()


@seeded_property(max_examples=10)
def test_topology_and_geometry_words_round_trip(seed):
    rs = np.random.RandomState(seed)
    n = 128
    full = rs.rand(n) < 0.5
    mask = rs.randint(0, 256, n)
    octant = rs.randint(0, 8, n)
    start_u8 = rs.randint(0, 1 << 20, n)
    start_bf = rs.randint(0, 1 << 23, n)
    f, o, s, m = unpack_topo(pack_topo_u8(full, octant, start_u8, mask), "u8")
    assert (f == full).all() and (o == octant).all()
    assert (s == start_u8).all() and (m == mask).all()
    f, o, s, m = unpack_topo(pack_topo_bf16(full, start_bf, mask), "bf16")
    assert (f == full).all() and (s == start_bf).all() and (m == mask).all()
    level = int(rs.randint(0, 11))
    xyz = rs.randint(0, 1 << level, (n, 3))
    assert (unpack_geom_bf16(pack_geom_bf16(xyz, level), level) == xyz).all()


def test_pack_raises_on_pointer_overflow():
    with pytest.raises(ValueError, match="overflows"):
        pack_topo_u8([0], [0], [1 << 20], [0])
    with pytest.raises(ValueError, match="overflows"):
        pack_topo_bf16([0], [1 << 23], [0])
    with pytest.raises(ValueError, match="leaf grid"):
        pack_geom_bf16(np.array([[4, 0, 0]]), 2)   # coord >= 2**level


# ---------------------------------------------------------------------------
# Packed device tables
# ---------------------------------------------------------------------------

def test_packed_tables_encode_the_fp32_channels():
    tree = _tree(3, 2000, 4)
    devs = {f: device_octree(tree, meta_format=f) for f in META_FORMATS}
    ref = devs["fp32"]
    for f in META_FORMATS:
        assert devs[f].meta_format == f
        assert devs[f].node_meta.shape[-1] == META_FORMAT_WORDS[f]
        # unpacked channel planes are retained identically in every format
        assert (devs[f].codes == ref.codes).all()
        assert (devs[f].child_start == ref.child_start).all()
    codes = np.asarray(ref.codes)
    occ = codes != PAD_CODE
    for f in ("bf16", "u8"):
        w0 = np.asarray(devs[f].node_meta[..., 0])
        full, octant, start, mask = unpack_topo(w0, f)
        assert (full[occ] == np.asarray(ref.full)[occ]).all(), f
        assert (start[occ] == np.asarray(ref.child_start)[occ]).all(), f
        assert (mask[occ] == np.asarray(ref.child_mask)[occ]).all(), f
        # pad rows pack to zero words (PAD_CODE coords would overflow)
        assert (w0[~occ] == 0).all(), f
    assert (unpack_topo(np.asarray(devs["u8"].node_meta[..., 0]),
                        "u8")[1][occ] == (codes & 7)[occ]).all()


# ---------------------------------------------------------------------------
# Bitwise verdict equality + nodes_visited inflation cap (all modes)
# ---------------------------------------------------------------------------

def test_bitwise_verdicts_across_all_wavefront_modes_and_formats():
    """The tentpole soundness sweep: all four wavefront modes, quantized
    verdicts AND work counters bitwise-identical to fp32; nodes_visited
    inflation is exactly 1x (aligned cells quantize exactly)."""
    tree = _tree(0)
    obbs = random_obbs(jax.random.PRNGKey(3), 48)
    base = {}
    for mode in ("wavefront_host", "wavefront", "wavefront_fused",
                 "wavefront_persistent"):
        base[mode] = CollisionEngine(tree, EngineConfig(mode=mode)).query(obbs)
        assert (base[mode][0] == base["wavefront_host"][0]).all(), mode
    ref_v, ref_c = base["wavefront_fused"]
    for mode in ("wavefront_fused", "wavefront_persistent"):
        for fmt in META_FORMATS:
            for stream in (False, True):
                eng = CollisionEngine(tree, EngineConfig(
                    mode=mode, meta_format=fmt, stream_meta=stream))
                assert eng.meta_format == fmt
                v, c = eng.query(obbs)
                ctx = (mode, fmt, stream)
                assert (np.asarray(v) == np.asarray(ref_v)).all(), ctx
                for fld in WORK_FIELDS:
                    assert getattr(c, fld) == getattr(ref_c, fld), (ctx, fld)
                assert c.nodes_per_level == ref_c.nodes_per_level, ctx
                assert (c.exit_histogram == ref_c.exit_histogram).all(), ctx
                # the inflation bound: quantization adds ZERO visits here
                assert c.nodes_traversed == ref_c.nodes_traversed, ctx


def test_streamed_bytes_scale_with_format_width():
    """Row COUNT is format-independent; streamed bytes divide by exactly
    2x (bf16) and 4x (u8) — the ISSUE's >= 3x acceptance mechanism."""
    tree = _tree(1)
    obbs = random_obbs(jax.random.PRNGKey(5), 32)
    rows, bytes_ = {}, {}
    for fmt in META_FORMATS:
        eng = CollisionEngine(tree, EngineConfig(
            mode="wavefront_persistent", meta_format=fmt, stream_meta=True))
        _, c = eng.query(obbs)
        rows[fmt], bytes_[fmt] = c.meta_rows_streamed, c.meta_bytes_streamed
    assert rows["fp32"] > 0
    assert rows["fp32"] == rows["bf16"] == rows["u8"]
    assert bytes_["fp32"] == rows["fp32"] * BYTES_META_STREAM
    assert bytes_["bf16"] == rows["fp32"] * BYTES_META_STREAM_BF16
    assert bytes_["u8"] == rows["fp32"] * BYTES_META_STREAM_U8
    assert bytes_["fp32"] == 4 * bytes_["u8"] == 2 * bytes_["bf16"]


def test_pallas_interpret_kernel_bitwise_across_formats():
    """The megakernel arm (interpret=True) matches the jnp ref on every
    format x layout, stats included — the kernel's in-register dequantize
    and u8 own-code frontier lane against the ref's."""
    tree = _tree(2, 2500, 4)
    obbs = random_obbs(jax.random.PRNGKey(7), 24)
    cap = 4096                       # no overflow: global == tile-local
    ref = traverse_whole(obbs.center, obbs.half, obbs.rot,
                         device_octree(tree), cap,
                         use_spheres=False, use_pallas=False, streamed=False)
    assert int(ref[1]["overflow"]) == 0
    for fmt in META_FORMATS:
        dev = device_octree(tree, meta_format=fmt)
        for stream in (False, True):
            pal = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                                 use_spheres=False, use_pallas=True,
                                 interpret=True, streamed=stream, bq=16)
            assert bool(jnp.all(ref[0] == pal[0])), (fmt, stream)
            for k in ref[1]:
                if k != "meta_rows":
                    assert bool(jnp.all(ref[1][k] == pal[1][k])), \
                        (fmt, stream, k)


# ---------------------------------------------------------------------------
# Chooser + EngineConfig + rebind invalidation
# ---------------------------------------------------------------------------

def test_choose_meta_layout_format_rules():
    depth, n_max = 5, 1024
    t32 = meta_table_bytes(depth, n_max, "fp32")
    # widest-first for residency: fp32 stays fp32 when it fits ...
    assert choose_meta_layout(depth, n_max, t32) == MetaChoice("resident",
                                                               "fp32")
    # ... compression is taken only to buy residency back ...
    assert choose_meta_layout(depth, n_max, t32 // 2) == \
        MetaChoice("resident", "bf16")
    assert choose_meta_layout(depth, n_max, t32 // 4) == \
        MetaChoice("resident", "u8")
    # ... and a truly over-budget table streams at the narrowest format.
    assert choose_meta_layout(depth, n_max, t32 // 8) == \
        MetaChoice("streamed", "u8")
    # pinned layouts
    assert choose_meta_layout(depth, n_max, t32 // 8,
                              layout="streamed") == MetaChoice("streamed",
                                                               "u8")
    assert choose_meta_layout(depth, n_max, t32 // 2,
                              layout="resident") == MetaChoice("resident",
                                                               "bf16")
    # pinned formats: layout falls out of that format's own table size
    assert choose_meta_layout(depth, n_max, t32 // 2, fmt="fp32") == \
        MetaChoice("streamed", "fp32")
    assert choose_meta_layout(depth, n_max, t32 // 2, fmt="bf16") == \
        MetaChoice("resident", "bf16")
    # eligibility: u8's 20-bit pointer cannot index a 2**21-row level
    assert not format_eligible("u8", 1 << 21)
    assert format_eligible("bf16", 1 << 21)
    assert format_eligible("fp32", 1 << 30)
    assert choose_meta_layout(depth, 1 << 21, 0).fmt == "bf16"
    with pytest.raises(ValueError, match="child_start"):
        choose_meta_layout(depth, 1 << 21, 0, fmt="u8")
    with pytest.raises(ValueError, match="unknown meta_format"):
        choose_meta_layout(depth, n_max, fmt="f16")
    # default-arg identities: fp32 pricing is unchanged from PR 5
    assert meta_table_bytes(depth, n_max) == meta_table_bytes(depth, n_max,
                                                              "fp32")
    assert meta_stream_bytes(n_max) == meta_stream_bytes(n_max, "fp32")


def test_engine_config_meta_format_validation():
    with pytest.raises(ValueError, match="unknown meta_format"):
        EngineConfig(mode="wavefront_persistent", meta_format="int4")
    with pytest.raises(ValueError, match="CSR mode"):
        EngineConfig(mode="wavefront", meta_format="u8")
    cfg = EngineConfig(mode="wavefront_persistent", meta_format="u8")
    assert cfg.meta_format == "u8"


def test_rebind_reruns_chooser_across_size_boundary():
    """Satellite: rebind_octrees must re-run the layout/format chooser.
    A scene grown past the residency boundary flips the SAME engine from
    resident-fp32 to a streamed compressed format, and the rebound
    verdicts match a fresh engine's."""
    small, big = _tree(4, 600, 4), _tree(5, 20000, 5)
    n_small = max(len(lv.codes) for lv in small.levels)
    budget = meta_table_bytes(small.depth, n_small)     # small fits exactly
    eng = CollisionEngine(small, EngineConfig(
        mode="wavefront_persistent", vmem_budget=budget))
    assert (eng.meta_layout, eng.meta_format) == ("resident", "fp32")
    obbs = random_obbs(jax.random.PRNGKey(1), 16)
    eng.query(obbs)
    eng.rebind_octrees(big)
    choice = choose_meta_layout(
        big.depth, max(len(lv.codes) for lv in big.levels), budget)
    # the stale small-scene decision must NOT survive the rebind
    assert (eng.meta_layout, eng.meta_format) == tuple(choice)
    assert (eng.meta_layout, eng.meta_format) != ("resident", "fp32")
    v, c = eng.query(obbs)
    fresh_v, fresh_c = CollisionEngine(big, EngineConfig(
        mode="wavefront_persistent", vmem_budget=budget)).query(obbs)
    assert (np.asarray(v) == np.asarray(fresh_v)).all()
    assert c.nodes_traversed == fresh_c.nodes_traversed
    assert c.meta_bytes_streamed == fresh_c.meta_bytes_streamed
    # ... and the device-table cache was invalidated with it: the packed
    # table the engine now serves is the big scene's, in the new format.
    assert eng.device_tree.meta_format == choice.fmt
