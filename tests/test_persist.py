"""Persistent whole-traversal megakernel: engine equivalence, interpret-mode
kernel vs ref, spill ring, ragged multi-scene frontier, escalation policy,
and the traversal jit cache.

The Pallas megakernel runs under ``interpret=True`` here so the CPU CI
matrix exercises the kernel body without a TPU, mirroring the
kernels/compact and kernels/traverse setups.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.geometry import OBBs, random_obbs
from repro.core.octree import (align_rows, build_octree,
                               concat_device_octrees, device_octree)
from repro.core.wavefront import (MODES, CollisionEngine, EngineConfig,
                                  query_batched_scenes, traversal_cache_info)
from repro.data.robotics import make_scene, scene_trajectories
from repro.kernels.persist.ops import (META_LAYOUTS, SUB_WINDOW_ROWS,
                                       choose_meta_layout, meta_stream_bytes,
                                       meta_table_bytes, sub_window_rows,
                                       traverse_whole)
from repro.kernels.persist.ref import frontier_widths

WORK_FIELDS = ("nodes_traversed", "leaf_tests", "axis_tests_executed",
               "axis_tests_decoded", "sphere_tests", "frontier_overflow")


def _assert_counters_equal(c, ref_c, ctx):
    for f in WORK_FIELDS:
        assert getattr(c, f) == getattr(ref_c, f), (ctx, f)
    assert c.nodes_per_level == ref_c.nodes_per_level, ctx
    assert (c.exit_histogram == ref_c.exit_histogram).all(), ctx


def test_frontier_widths():
    assert frontier_widths(2048, w_min=128) == (128, 256, 512, 1024, 2048)
    assert frontier_widths(128, w_min=128) == (128,)
    assert frontier_widths(64, w_min=128) == (64,)
    assert frontier_widths(96, w_min=32) == (32, 64, 96)


def test_persistent_engine_bitwise_equivalence_on_bench_scenes():
    """wavefront_persistent == wavefront_fused == wavefront: verdicts AND
    work counters, on benchmark scenes (the acceptance criterion)."""
    for env, n_pts, depth in [("cubby", 4096, 4), ("dresser", 4096, 4)]:
        sc = make_scene(env, num_points=n_pts)
        tree = build_octree(sc.points, depth=depth)
        obbs = scene_trajectories(sc, num_trajectories=2, waypoints=6)
        res = {}
        for mode in ("wavefront", "wavefront_fused", "wavefront_persistent"):
            res[mode] = CollisionEngine(tree,
                                        EngineConfig(mode=mode)).query(obbs)
        ref_col, ref_c = res["wavefront_fused"]
        col, c = res["wavefront_persistent"]
        assert (col == ref_col).all(), env
        _assert_counters_equal(c, ref_c, env)
        _assert_counters_equal(res["wavefront"][1], ref_c, env)
        # persistent bytes model (per query, not per pair-level) undercuts
        # the fused step's frontier round trips
        assert c.bytes_moved < ref_c.bytes_moved


@pytest.mark.parametrize("use_spheres", [False, True])
def test_persist_kernel_interpret_matches_ref(use_spheres):
    """Pallas megakernel (interpret=True, multiple query tiles) == jnp ref:
    verdicts and every stats field, bitwise."""
    rs = np.random.RandomState(7)
    pts = rs.uniform(-1, 1, (2500, 3)).astype(np.float32)
    tree = build_octree(pts, depth=3)
    dev = device_octree(tree)
    obbs = random_obbs(jax.random.PRNGKey(7), 21)     # 2 tiles at bq=16
    cap = 256
    ref = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_spheres=use_spheres, use_pallas=False)
    pal = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_spheres=use_spheres, use_pallas=True,
                         interpret=True, bq=16)
    assert bool(jnp.all(ref[0] == pal[0]))
    for k in ref[1]:
        assert bool(jnp.all(ref[1][k] == pal[1][k])), k


def test_persist_kernel_spill_ring_counts_overflow():
    """A deliberately tiny VMEM frontier must spill: the kernel reports the
    same overflow count as the global-pool ref (single tile == one pool)
    and records spilled pairs in the HBM ring."""
    rs = np.random.RandomState(3)
    pts = rs.uniform(-1, 1, (4000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    dev = device_octree(tree)
    obbs = random_obbs(jax.random.PRNGKey(3), 24)
    cap = 64                                     # << peak frontier
    ref = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_spheres=False, use_pallas=False)
    pal = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_spheres=False, use_pallas=True,
                         interpret=True, bq=32)  # one tile: global == tile
    assert int(ref[1]["overflow"]) > 0
    assert int(pal[1]["overflow"]) == int(ref[1]["overflow"])


def test_persistent_escalation_replays_until_exact():
    """A tiny initial bucket must climb the escalation ladder (>= 2
    replays), end with zero overflow, and report exact verdicts."""
    rs = np.random.RandomState(2)
    pts = rs.uniform(-1, 1, (8000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(3), 40)
    ref, _ = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_persistent",
                                             min_bucket=32))
    got, c = eng.query(obbs)
    assert (got == ref).all()
    assert c.frontier_overflow == 0
    assert c.escalations >= 2
    # The engine remembers the clean capacity: a repeat query pays zero
    # replays (and, per traversal_cache_info, zero retraces).
    got2, c2 = eng.query(obbs)
    assert (got2 == ref).all()
    assert c2.escalations == 0


def test_persistent_max_frontier_clamp_underapproximates():
    """At the max_frontier clamp the engine cannot escalate further: the
    overflow count is reported and verdicts under-approximate (drops can
    only lose collisions, never invent them)."""
    rs = np.random.RandomState(2)
    pts = rs.uniform(-1, 1, (8000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(3), 40)
    ref, _ = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    got, c = CollisionEngine(tree, EngineConfig(
        mode="wavefront_persistent", max_frontier=256)).query(obbs)
    assert c.frontier_overflow > 0
    assert not (got & ~ref).any()            # no false positives
    assert got.sum() <= ref.sum()


def test_query_batched_persistent_flattens_to_one_pool():
    """query_batched under the persistent mode (flat ragged pool, no vmap)
    == the fused vmapped arm, verdicts and aggregate work counters."""
    rs = np.random.RandomState(9)
    pts = rs.uniform(-1, 1, (5000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(10), 48)
    batch = OBBs(center=obbs.center.reshape(6, 8, 3),
                 half=obbs.half.reshape(6, 8, 3),
                 rot=obbs.rot.reshape(6, 8, 3, 3))
    got_f, cf = CollisionEngine(tree, EngineConfig(
        mode="wavefront_fused")).query_batched(batch)
    got_p, cp = CollisionEngine(tree, EngineConfig(
        mode="wavefront_persistent")).query_batched(batch)
    assert got_p.shape == (6, 8)
    assert (got_p == got_f).all()
    _assert_counters_equal(cp, cf, "batched")
    assert cp.num_queries == 48


def test_ragged_scenes_mixed_sizes_one_call():
    """Mixed-size scenes through the ragged flat frontier: verdicts match
    per-scene naive queries and aggregate counters match the sum of
    per-scene persistent queries."""
    trees, sets = [], []
    for seed, n_pts in ((11, 1000), (12, 12000), (13, 4000)):
        rs = np.random.RandomState(seed)
        pts = rs.uniform(-1, 1, (n_pts, 3)).astype(np.float32)
        trees.append(build_octree(pts, depth=4))
        sets.append(random_obbs(jax.random.PRNGKey(seed), 20))
    stack = OBBs(center=jnp.stack([o.center for o in sets]),
                 half=jnp.stack([o.half for o in sets]),
                 rot=jnp.stack([o.rot for o in sets]))
    for mode in ("wavefront_fused", "wavefront_persistent"):
        got, c = query_batched_scenes(trees, stack, EngineConfig(mode=mode))
        assert got.shape == (3, 20)
        for s in range(3):
            ref, _ = CollisionEngine(trees[s],
                                     EngineConfig(mode="naive")).query(sets[s])
            assert (got[s] == ref).all(), (mode, s)
        assert c.num_queries == 60
    # counters are the sum of independent per-scene traversals
    per_scene = [CollisionEngine(t, EngineConfig(
        mode="wavefront_persistent")).query(o) for t, o in zip(trees, sets)]
    _, cr = query_batched_scenes(trees, stack,
                                 EngineConfig(mode="wavefront_persistent"))
    for f in ("nodes_traversed", "leaf_tests", "axis_tests_executed",
              "sphere_tests"):
        assert getattr(cr, f) == sum(getattr(c, f) for _, c in per_scene), f


def test_ragged_concat_table_roots_and_counts():
    trees = []
    for seed, n_pts in ((1, 500), (2, 6000)):
        rs = np.random.RandomState(seed)
        trees.append(build_octree(
            rs.uniform(-1, 1, (n_pts, 3)).astype(np.float32), depth=3))
    multi = concat_device_octrees(trees)
    counts = np.asarray(multi.counts)
    for l in range(4):
        assert counts[l] == sum(len(t.levels[l].codes) for t in trees)
    # scene s's root is flat node s of the level-0 row
    meta0 = np.asarray(multi.node_meta[0])
    assert (meta0[:2, 0].view(np.uint32) == 0).all()
    # flat table holds the total (DMA-chunk aligned), not S x widest
    assert multi.node_meta.shape[1] == align_rows(max(counts))


def test_engineconfig_rejects_unknown_mode():
    with pytest.raises(ValueError) as ei:
        EngineConfig(mode="warpfront")
    msg = str(ei.value)
    assert "warpfront" in msg
    for mode in MODES:
        assert mode in msg


def _slab_scene(seed=3, n_pts=4000, depth=5):
    """Sparse slab: a real multi-level traversal (root never full)."""
    rs = np.random.RandomState(seed)
    pts = rs.uniform(-1, 1, (n_pts, 3)).astype(np.float32)
    return build_octree(pts[np.abs(pts[:, 2]) < 0.3], depth=depth)


def test_streamed_kernel_interpret_matches_ref_and_resident():
    """Streamed metadata windows (interpret-mode DMA machinery, multiple
    query tiles) == streamed jnp ref on EVERY stats field including the
    meta_rows window schedule; == the resident layout on everything but
    meta_rows (the layout cannot change work, only traffic)."""
    dev = device_octree(_slab_scene())
    obbs = random_obbs(jax.random.PRNGKey(3), 37)     # 3 tiles at bq=16
    cap = 2048
    kw = dict(use_spheres=False, bq=16)
    ref = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_pallas=False, streamed=True, **kw)
    pal = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_pallas=True, interpret=True, streamed=True,
                         **kw)
    res = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_pallas=True, interpret=True, streamed=False,
                         **kw)
    assert int(ref[1]["meta_rows"]) > 0
    assert bool(jnp.all(ref[0] == pal[0]))
    for k in ref[1]:
        assert bool(jnp.all(ref[1][k] == pal[1][k])), k
    assert int(res[1]["meta_rows"]) == 0
    assert bool(jnp.all(res[0] == pal[0]))
    for k in ref[1]:
        if k != "meta_rows":
            assert bool(jnp.all(res[1][k] == pal[1][k])), k


def test_bigscene_streamed_engine_bitwise_vs_fused():
    """The satellite acceptance run: a scene >= 4x the VMEM residency
    limit stays under mode="wavefront_persistent" (streamed layout, no
    fused fallback), with the interpret-mode megakernel's verdicts AND
    work counters bitwise-identical to wavefront_fused and to the jnp
    ref arm."""
    tree = _slab_scene()
    n_max = max(len(l.codes) for l in tree.levels)
    table = meta_table_bytes(tree.depth, n_max)
    # the residency limit IS the budget: table // 4 puts this scene at
    # 4x the limit.  The estimator must flip exactly there — resident at
    # a table-sized budget, streamed below it — or the test is not
    # exercising the streamed arm at all.
    budget = table // 4
    # (fmt pinned to fp32: the free chooser would instead COMPRESS its way
    # back under this budget — resident u8 — which test_quantize covers)
    assert choose_meta_layout(tree.depth, n_max, budget,
                              fmt="fp32").layout == "streamed"
    assert choose_meta_layout(tree.depth, n_max, table,
                              fmt="fp32").layout == "resident"
    obbs = random_obbs(jax.random.PRNGKey(5), 24)
    ref_col, ref_c = CollisionEngine(
        tree, EngineConfig(mode="wavefront_fused")).query(obbs)
    engines = {
        "kernel": EngineConfig(mode="wavefront_persistent",
                               vmem_budget=budget, meta_format="fp32",
                               use_pallas_traverse=True),
        "ref": EngineConfig(mode="wavefront_persistent",
                            vmem_budget=budget, meta_format="fp32"),
    }
    counters = {}
    for name, cfg in engines.items():
        eng = CollisionEngine(tree, cfg)
        assert eng.meta_layout == "streamed"
        col, c = eng.query(obbs)
        assert (col == ref_col).all(), name
        _assert_counters_equal(c, ref_c, name)
        assert c.meta_rows_streamed > 0, name
        counters[name] = c
    # kernel and ref arms agree on the window schedule itself
    assert (counters["kernel"].meta_rows_streamed
            == counters["ref"].meta_rows_streamed)
    # streamed metadata traffic is priced into the persistent bytes model
    assert counters["kernel"].bytes_moved > 0


def test_residency_estimator_and_override():
    """choose_meta_layout picks by table size vs budget; EngineConfig can
    pin either layout; verdicts and work counters never depend on it."""
    tree = _slab_scene()
    n_max = max(len(l.codes) for l in tree.levels)
    table = meta_table_bytes(tree.depth, n_max)
    assert choose_meta_layout(tree.depth, n_max, budget=table,
                              fmt="fp32").layout == "resident"
    assert choose_meta_layout(tree.depth, n_max, budget=table - 1,
                              fmt="fp32").layout == "streamed"
    assert set(META_LAYOUTS) == {"resident", "streamed"}
    # the streamed ping/pong pair holds two FIXED-SIZE sub-level windows
    # (plus one 8-row DMA chunk of slack each): its VMEM cost is fully
    # decoupled from n_max — a 16x wider table streams through the same
    # scratch — and a table narrower than one window shrinks the pair.
    assert meta_stream_bytes(1 << 20) == meta_stream_bytes(1 << 24)
    assert sub_window_rows(1 << 20) == SUB_WINDOW_ROWS
    assert meta_stream_bytes(n_max) <= meta_stream_bytes(1 << 20)
    assert meta_stream_bytes(64) < meta_stream_bytes(1 << 20)
    obbs = random_obbs(jax.random.PRNGKey(9), 24)
    runs = {}
    for layout, stream in (("resident", False), ("streamed", True)):
        eng = CollisionEngine(tree, EngineConfig(
            mode="wavefront_persistent", stream_meta=stream))
        assert eng.meta_layout == layout
        runs[layout] = eng.query(obbs)
    col_r, c_r = runs["resident"]
    col_s, c_s = runs["streamed"]
    assert (col_r == col_s).all()
    _assert_counters_equal(c_s, c_r, "layouts")
    assert c_r.meta_rows_streamed == 0
    assert c_s.meta_rows_streamed > 0
    assert c_s.bytes_moved > c_r.bytes_moved


def test_owner_tiled_streamed_kernel_matches_ref():
    """Cross-slot owner (swept-edge) plans run owner-group tiled on the
    megakernel under BOTH metadata layouts: verdicts and every stats
    field — including the streamed window schedule's meta_rows — bitwise
    kernel == ref, and the streamed layout actually models traffic (the
    old ref-only routing pinned these plans resident)."""
    dev = device_octree(_slab_scene())
    obbs = random_obbs(jax.random.PRNGKey(2), 24)
    owner = jnp.asarray(np.repeat(np.arange(3), 8), jnp.int32)
    payload = jnp.asarray(np.tile(np.arange(8), 3), jnp.int32)
    kw = dict(use_spheres=False, owner_of_query=owner, payload=payload,
              bq=8)
    for streamed in (False, True):
        ref = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, 512,
                             use_pallas=False, streamed=streamed, **kw)
        pal = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, 512,
                             use_pallas=True, interpret=True,
                             streamed=streamed, **kw)
        assert bool(jnp.all(ref[0][:3] == pal[0][:3])), streamed
        for k in ref[1]:
            assert bool(jnp.all(ref[1][k] == pal[1][k])), (streamed, k)
        assert int(ref[1]["meta_rows"]) > 0 if streamed \
            else int(ref[1]["meta_rows"]) == 0


def test_cap_memo_rekeys_on_scene_growth():
    """Growing a scene between calls (rebind_octrees) must re-enter the
    escalation ladder: the clean-capacity memo keys on the scene node
    counts, so the old scene's (too small) clean capacity is never
    reused and the first query against the grown scene still ends
    overflow-free and exact."""
    rs = np.random.RandomState(6)
    small = build_octree(
        rs.uniform(-1, 1, (300, 3)).astype(np.float32), depth=4)
    big = build_octree(
        rs.uniform(-1, 1, (8000, 3)).astype(np.float32), depth=4)
    obbs = random_obbs(jax.random.PRNGKey(3), 40)
    eng = CollisionEngine(small, EngineConfig(mode="wavefront_persistent",
                                              min_bucket=32))
    eng.query(obbs)
    (old_key,) = set(eng._cap_memo)
    eng.rebind_octrees(big)
    # superseded-scene entries are unreadable (sig-keyed) and pruned
    assert not eng._cap_memo
    ref, _ = CollisionEngine(big, EngineConfig(mode="naive")).query(obbs)
    got, c = eng.query(obbs)
    assert (got == ref).all()
    assert c.frontier_overflow == 0
    assert c.escalations >= 1          # ladder re-entered, not memo-skipped
    # same query shape, new scene signature in the key
    (new_key,) = set(eng._cap_memo)
    assert old_key[:-1] == new_key[:-1] and old_key[-1] != new_key[-1]


def test_traversal_cache_survives_engine_reconstruction():
    """A fresh CollisionEngine on a same-shaped scene reuses the traced
    traversal: the per-key trace counts do not grow."""
    rs = np.random.RandomState(4)
    pts = rs.uniform(-1, 1, (3000, 3)).astype(np.float32)
    obbs = random_obbs(jax.random.PRNGKey(4), 16)
    tree1 = build_octree(pts, depth=3)
    eng1 = CollisionEngine(tree1, EngineConfig(mode="wavefront_persistent"))
    eng1.query(obbs)
    traces_before = traversal_cache_info()["traces"]
    # new engine, new device arrays, same shapes -> no retrace
    tree2 = build_octree(pts, depth=3)
    eng2 = CollisionEngine(tree2, EngineConfig(mode="wavefront_persistent"))
    got, _ = eng2.query(obbs)
    traces_after = traversal_cache_info()["traces"]
    for key, n in traces_before.items():
        assert traces_after[key] == n, key
    assert traversal_cache_info()["hits"] > 0
