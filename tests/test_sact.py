"""SACT correctness: float64 SAT oracle, rigid invariance, staged semantics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# Without hypothesis (the ``dev`` extra) the property tests degrade to a few
# fixed seeds instead of failing collection.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.geometry import AABBs, random_aabbs, random_obbs
from repro.core import sact as S


def sat_oracle(oc, oh, orot, ac, ah):
    """Float64 full separating-axis test (ground truth)."""
    oc, oh, orot, ac, ah = [np.asarray(x, np.float64)
                            for x in (oc, oh, orot, ac, ah)]
    t = oc - ac
    R = orot
    A = np.abs(R) + 1e-9
    for i in range(3):
        if abs(t[i]) > ah[i] + (oh * A[i, :]).sum():
            return False
    for j in range(3):
        if abs(t @ R[:, j]) > (ah * A[:, j]).sum() + oh[j]:
            return False
    for i in range(3):
        i1, i2 = (i + 1) % 3, (i + 2) % 3
        for j in range(3):
            j1, j2 = (j + 1) % 3, (j + 2) % 3
            ra = ah[i1] * A[i2, j] + ah[i2] * A[i1, j]
            rb = oh[j1] * A[i, j2] + oh[j2] * A[i, j1]
            if abs(t[i2] * R[i1, j] - t[i1] * R[i2, j]) > ra + rb:
                return False
    return True


def test_pairwise_matches_float64_oracle():
    obbs = random_obbs(jax.random.PRNGKey(0), 48)
    aabbs = random_aabbs(jax.random.PRNGKey(1), 64)
    got = np.asarray(S.sact_pairwise(obbs, aabbs).collide)
    oc, oh, orot = map(np.asarray, (obbs.center, obbs.half, obbs.rot))
    ac, ah = map(np.asarray, (aabbs.center, aabbs.half))
    for m in range(48):
        for n in range(64):
            assert got[m, n] == sat_oracle(oc[m], oh[m], orot[m], ac[n],
                                           ah[n]), (m, n)


def test_blocked_equals_dense():
    obbs = random_obbs(jax.random.PRNGKey(2), 70)
    aabbs = random_aabbs(jax.random.PRNGKey(3), 33)
    a = S.sact_pairwise(obbs, aabbs)
    b = S.sact_pairwise_blocked(obbs, aabbs, block=32)
    assert bool(jnp.all(a.collide == b.collide))
    assert bool(jnp.all(a.exit_code == b.exit_code))


def test_sphere_pretests_do_not_change_verdict():
    obbs = random_obbs(jax.random.PRNGKey(4), 60)
    aabbs = random_aabbs(jax.random.PRNGKey(5), 60)
    plain = S.sact_pairwise(obbs, aabbs, use_spheres=False)
    sph = S.sact_pairwise(obbs, aabbs, use_spheres=True)
    assert bool(jnp.all(plain.collide == sph.collide))
    # sphere exits reduce executed axis tests
    assert int(jnp.sum(sph.axis_tests)) <= int(jnp.sum(plain.axis_tests))


def test_exit_codes_and_axis_counts_consistent():
    obbs = random_obbs(jax.random.PRNGKey(6), 40)
    aabbs = random_aabbs(jax.random.PRNGKey(7), 40)
    r = S.sact_pairwise(obbs, aabbs)
    ec = np.asarray(r.exit_code)
    at = np.asarray(r.axis_tests)
    col = np.asarray(r.collide)
    assert ((ec == S.EXIT_FULL) == col).all()        # no spheres: collide <=> full
    axis_exit = (ec >= S.EXIT_AXIS0) & (ec < S.EXIT_FULL)
    assert (at[axis_exit] == ec[axis_exit] - S.EXIT_AXIS0 + 1).all()
    assert (at[ec == S.EXIT_FULL] == S.NUM_AXES).all()


def _rigid_translation_invariance(seed, dx, dy, dz):
    """Translating both boxes by the same vector preserves the verdict."""
    key = jax.random.PRNGKey(seed)
    obbs = random_obbs(key, 8)
    aabbs = random_aabbs(jax.random.fold_in(key, 1), 8)
    d = jnp.asarray([dx, dy, dz], jnp.float32)
    r0 = S.sact(obbs.center, obbs.half, obbs.rot, aabbs.center, aabbs.half)
    r1 = S.sact(obbs.center + d, obbs.half, obbs.rot, aabbs.center + d,
                aabbs.half)
    assert bool(jnp.all(r0.collide == r1.collide))


def _containment_implies_collision(seed):
    """An OBB centred inside an AABB bigger than its bounding sphere collides."""
    key = jax.random.PRNGKey(seed)
    obbs = random_obbs(key, 8, min_half=0.05, max_half=0.1)
    big = AABBs(center=obbs.center,
                half=jnp.full_like(obbs.half, 1.0))
    r = S.sact(obbs.center, obbs.half, obbs.rot, big.center, big.half)
    assert bool(jnp.all(r.collide))


def _far_apart_never_collides(seed):
    key = jax.random.PRNGKey(seed)
    obbs = random_obbs(key, 8)
    aabbs = random_aabbs(jax.random.fold_in(key, 1), 8)
    far = AABBs(center=aabbs.center + 100.0, half=aabbs.half)
    r = S.sact(obbs.center, obbs.half, obbs.rot, far.center, far.half)
    assert not bool(jnp.any(r.collide))


if given is not None:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(-3.0, 3.0),
           st.floats(-3.0, 3.0), st.floats(-3.0, 3.0))
    def test_rigid_translation_invariance(seed, dx, dy, dz):
        _rigid_translation_invariance(seed, dx, dy, dz)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_containment_implies_collision(seed):
        _containment_implies_collision(seed)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_far_apart_never_collides(seed):
        _far_apart_never_collides(seed)
else:
    @pytest.mark.parametrize("seed", [0, 7, 123])
    def test_rigid_translation_invariance(seed):
        _rigid_translation_invariance(seed, 1.5, -2.0, 0.25)

    @pytest.mark.parametrize("seed", [0, 7, 123])
    def test_containment_implies_collision(seed):
        _containment_implies_collision(seed)

    @pytest.mark.parametrize("seed", [0, 7, 123])
    def test_far_apart_never_collides(seed):
        _far_apart_never_collides(seed)
