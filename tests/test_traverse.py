"""Fused traversal step: CSR child table, kernel-vs-ref, engine equivalence.

The Pallas traversal-step kernel runs under ``interpret=True`` here so the
CPU CI matrix exercises kernel changes without a TPU, mirroring the
kernels/compact setup.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import seeded_property

from repro.core.geometry import OBBs, random_obbs
from repro.core.octree import (build_octree, device_octree, lookup_children,
                               node_centers_from_codes)
from repro.core.sact import sact_frontier, sact_frontier_staged
from repro.core.wavefront import CollisionEngine, EngineConfig
from repro.data.robotics import make_scene, scene_trajectories
from repro.kernels.traverse import ops as traverse_ops
from repro.kernels.traverse.ops import traverse_step
from repro.kernels.traverse.ref import traverse_test_ref

WORK_FIELDS = ("nodes_traversed", "leaf_tests", "axis_tests_executed",
               "axis_tests_decoded", "sphere_tests", "frontier_overflow")


def _random_tree(seed):
    rs = np.random.RandomState(seed % 100000)
    n = int(rs.randint(200, 3000))
    depth = int(rs.randint(2, 6))
    pts = rs.uniform(-1, 1, (n, 3)).astype(np.float32)
    return build_octree(pts, depth=depth), rs


@seeded_property(max_examples=10)
def test_csr_child_table_matches_searchsorted_probe(seed):
    """CSR (child_start, child_mask) == the searchsorted occupancy probe on
    random octrees: same occupied octants, same child positions."""
    tree, _ = _random_tree(seed)
    for level in range(tree.depth):
        lvl, nxt = tree.levels[level], tree.levels[level + 1]
        cand, idx = lookup_children(jnp.asarray(nxt.codes),
                                    jnp.asarray(lvl.codes))
        idx = np.asarray(idx)
        occupied = idx >= 0
        mask_bits = ((lvl.child_mask[:, None].astype(np.int32)
                      >> np.arange(8)) & 1).astype(bool)
        assert (mask_bits == occupied).all()
        # child index = start + popcount(mask & ((1 << j) - 1))
        below = (1 << np.arange(8)) - 1
        prefix = np.array([[bin(int(m) & int(b)).count("1") for b in below]
                           for m in lvl.child_mask], np.int32)
        csr_idx = lvl.child_start[:, None] + prefix
        assert (csr_idx[occupied] == idx[occupied]).all()
        # contiguity: popcounts partition the next level exactly
        counts = np.array([bin(int(m)).count("1") for m in lvl.child_mask])
        assert counts.sum() == len(nxt.codes)
        assert (lvl.child_start == np.cumsum(counts) - counts).all()


_one_shot_jit = jax.jit(sact_frontier, static_argnames=("use_spheres",))
_staged_jit = jax.jit(sact_frontier_staged, static_argnames=("use_spheres",))


@seeded_property(max_examples=6)
def test_two_phase_sact_matches_one_shot(seed):
    """sact_frontier_staged == sact_frontier bitwise, both sphere modes."""
    rs = np.random.RandomState(seed % 100000)
    k = 160                                   # fixed shape: one jit compile
    obbs = random_obbs(jax.random.PRNGKey(seed % 100000), k)
    node_c = jnp.asarray(rs.uniform(-1, 1, (k, 3)).astype(np.float32))
    node_h = jnp.asarray(rs.uniform(0.05, 0.6, (k, 3)).astype(np.float32))
    valid = jnp.asarray(rs.rand(k) < 0.8)
    for spheres in (False, True):
        a = _one_shot_jit(obbs.center, obbs.half, obbs.rot, node_c, node_h,
                          valid, use_spheres=spheres)
        b = _staged_jit(obbs.center, obbs.half, obbs.rot, node_c,
                        node_h, valid, use_spheres=spheres)
        for f in a._fields:
            assert bool(jnp.all(getattr(a, f) == getattr(b, f))), f


@pytest.mark.parametrize("use_spheres", [False, True])
@pytest.mark.parametrize("bn", [32])
def test_traverse_kernel_interpret_matches_ref(use_spheres, bn):
    """Pallas traversal-step kernel (interpret=True) == jnp reference arm:
    packed verdicts, compacted next frontier, and work-model fields."""
    rs = np.random.RandomState(bn)
    pts = rs.uniform(-1, 1, (3000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    dev = device_octree(tree)
    obbs = random_obbs(jax.random.PRNGKey(bn), 24)
    for level in (1, 2, tree.depth):
        n_l = len(tree.levels[level].codes)
        cap = 96
        n_live = min(cap, max(n_l, 8))
        idx = rs.randint(0, n_l, cap).astype(np.int32)
        q = rs.randint(0, obbs.n, cap).astype(np.int32)
        args = (obbs.center, obbs.half, obbs.rot, dev, jnp.int32(level),
                jnp.int32(n_live), jnp.asarray(q), jnp.asarray(idx),
                jnp.zeros((obbs.n,), bool))
        ref = traverse_step(*args, use_spheres=use_spheres, use_pallas=False)
        pal = traverse_step(*args, use_spheres=use_spheres, use_pallas=True,
                            interpret=True, bn=bn)
        for name, a, b in zip(("cnt", "q_next", "idx_next", "collide"),
                              ref[:4], pal[:4]):
            assert bool(jnp.all(a == b)), (level, name)
        valid = np.asarray(ref[4]["valid"])
        assert (np.asarray(ref[4]["is_term"])[valid]
                == np.asarray(pal[4]["is_term"])[valid]).all()
        for f in ref[4]["res"]._fields:
            a, b = getattr(ref[4]["res"], f), getattr(pal[4]["res"], f)
            assert bool(jnp.all(a == b)), (level, f)


def test_traverse_packed_words_kernel_vs_ref_oracle():
    """The raw pallas_call's packed verdict words == the jnp oracle's."""
    rs = np.random.RandomState(5)
    pts = rs.uniform(-1, 1, (2000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=3)
    obbs = random_obbs(jax.random.PRNGKey(5), 16)
    level, cap = 2, 64
    n_l = len(tree.levels[level].codes)
    n_live = min(cap, n_l)
    idx = rs.randint(0, n_l, cap)
    codes = jnp.asarray(tree.levels[level].codes[idx])
    full = jnp.asarray(tree.levels[level].full[idx])
    q = jnp.asarray(rs.randint(0, obbs.n, cap).astype(np.int32))
    cell = jnp.float32(tree.cell_size(level))
    lo = jnp.asarray(tree.scene_lo)
    node_c, node_h = node_centers_from_codes(codes, lo, cell)
    ref_packed = traverse_test_ref(obbs.center, obbs.half, obbs.rot, q,
                                   node_c, node_h, full, False, n_live,
                                   use_spheres=False)
    pal_packed = traverse_ops._test_pallas(
        obbs.center, obbs.half, obbs.rot, q, codes, full, cell, lo,
        jnp.bool_(False), jnp.int32(n_live), False, bn=32, interpret=True)
    assert bool(jnp.all(ref_packed == pal_packed))


def test_fused_engine_bitwise_equivalence_on_bench_scenes():
    """wavefront_fused == wavefront == wavefront_host: verdicts AND work
    counters, on benchmark scenes (the fig11 acceptance criterion)."""
    for env, n_pts, depth in [("cubby", 4096, 4), ("dresser", 4096, 4)]:
        sc = make_scene(env, num_points=n_pts)
        tree = build_octree(sc.points, depth=depth)
        obbs = scene_trajectories(sc, num_trajectories=2, waypoints=6)
        res = {}
        for mode in ("wavefront_host", "wavefront", "wavefront_fused"):
            res[mode] = CollisionEngine(tree,
                                        EngineConfig(mode=mode)).query(obbs)
        ref_col, ref_c = res["wavefront"]
        for mode in ("wavefront_host", "wavefront_fused"):
            col, c = res[mode]
            assert (col == ref_col).all(), (env, mode)
            for f in WORK_FIELDS:
                assert getattr(c, f) == getattr(ref_c, f), (env, mode, f)
            assert c.nodes_per_level == ref_c.nodes_per_level, (env, mode)
            assert (c.exit_histogram == ref_c.exit_histogram).all(), (
                env, mode)
        # the fused step's bytes model must undercut the unfused arm
        assert res["wavefront_fused"][1].bytes_moved < ref_c.bytes_moved


def test_fused_engine_batched_and_spheres():
    """Fused engine under vmap (query_batched) and the sphere ablation."""
    rs = np.random.RandomState(9)
    pts = rs.uniform(-1, 1, (5000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(10), 48)
    batch = OBBs(center=obbs.center.reshape(6, 8, 3),
                 half=obbs.half.reshape(6, 8, 3),
                 rot=obbs.rot.reshape(6, 8, 3, 3))
    got_u, _ = CollisionEngine(tree, EngineConfig(
        mode="wavefront")).query_batched(batch)
    got_f, _ = CollisionEngine(tree, EngineConfig(
        mode="wavefront_fused")).query_batched(batch)
    assert (got_f == got_u).all()
    a, ca = CollisionEngine(tree, EngineConfig(
        mode="wavefront_fused", use_spheres=False)).query(obbs)
    b, cb = CollisionEngine(tree, EngineConfig(
        mode="wavefront_fused", use_spheres=True)).query(obbs)
    assert (a == b).all()
    assert cb.sphere_tests > 0
    assert cb.axis_tests_executed <= ca.axis_tests_executed
