"""Distributed correctness on 8 fake CPU devices (subprocess-isolated so the
rest of the suite keeps a single device).

Covers: sharded train step == single-device step (FSDP+TP numerics),
decode with seq-sharded KV == unsharded decode, compressed DP all-reduce,
GPipe pipeline == sequential stages, elastic checkpoint reshard.
"""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_devices(body: str, n: int = 8) -> str:
    script = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    import sys
    sys.path.insert(0, {os.path.join(ROOT, 'src')!r})
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import use_mesh
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    return res.stdout


def test_sharded_train_step_matches_single_device():
    out = run_devices("""
    from repro.configs.base import get_smoke_config, ShapeSpec
    from repro.models import api
    from repro.parallel import sharding as shd
    from repro.train import optimizer as opt_mod, train_loop
    from repro.data.pipeline import synth_batch

    cfg = get_smoke_config("glm4_9b")
    shape = ShapeSpec("t", 32, 8, "train")
    opt_cfg = opt_mod.OptConfig(lr=1e-3)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt_mod.init_opt_state(params, opt_cfg)
    batch = {k: jnp.asarray(v) for k, v in
             synth_batch(cfg, shape, 0).items()}

    # single-device reference
    ref_step = train_loop.make_train_step(cfg, opt_cfg)
    p1, o1, m1 = jax.jit(ref_step)(params, opt_state, batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with use_mesh(mesh):
        step, pspecs, ospecs, bspecs = train_loop.make_sharded_train_step(
            cfg, mesh, opt_cfg, shape)
        pp = jax.device_put(params, shd.named(mesh, pspecs))
        oo = jax.device_put(opt_state, shd.named(mesh, ospecs))
        bb = jax.device_put(batch, shd.named(mesh, bspecs))
        p2, o2, m2 = step(pp, oo, bb)
    print("LOSS", float(m1["loss"]), float(m2["loss"]))
    d = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, jax.device_get(p2)))
    print("MAXDIFF", d)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4
    assert d < 2e-4
    """)
    assert "MAXDIFF" in out


def test_decode_seq_sharded_kv_matches_unsharded():
    out = run_devices("""
    from repro.configs.base import get_smoke_config, ShapeSpec
    from repro.models import api
    from repro.parallel import sharding as shd
    from repro.train import train_loop
    from repro.models import transformer as tfm

    cfg = get_smoke_config("qwen1_5_110b")
    B, T = 8, 64
    shape = ShapeSpec("d", T, B, "decode")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    caches = tfm.init_decode_caches(cfg, B, T)
    # fill the cache with fake history at positions < pos
    caches = jax.tree.map(
        lambda x: (jax.random.normal(jax.random.PRNGKey(1), x.shape,
                                     x.dtype) * 0.1
                   if x.dtype != jnp.int32 else x), caches)
    tok = jnp.arange(B, dtype=jnp.int32) % cfg.vocab_size
    pos = jnp.asarray(T - 1, jnp.int32)
    decode = api.make_decode_fn(cfg)
    ref_logits, _ = jax.jit(decode)(params, tok, pos, caches)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        fn, pspecs, cspecs = train_loop.make_sharded_decode(cfg, mesh, shape)
        pp = jax.device_put(params, shd.named(mesh, pspecs))
        cc = jax.device_put(caches, shd.named(mesh, cspecs))
        logits, _ = fn(pp, jax.device_put(tok), jax.device_put(pos), cc)
    d = float(jnp.max(jnp.abs(ref_logits - jax.device_get(logits))))
    print("MAXDIFF", d)
    assert d < 2e-3
    """)
    assert "MAXDIFF" in out


def test_compressed_psum_error_feedback():
    out = run_devices("""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.parallel.compress import (compressed_psum_tree,
                                         init_residuals, quantize_int8,
                                         dequantize_int8)
    mesh = jax.make_mesh((8,), ("data",))
    g_local = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.01

    def body(g, r):
        mean, new_r = compressed_psum_tree({"w": g[0]}, {"w": r[0]}, "data")
        return mean["w"], new_r["w"]

    from repro.parallel.sharding import shard_map
    sm = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P(), P("data")))
    r = jnp.zeros((8, 64))
    mean, r2 = sm(g_local, r)
    exact = jnp.mean(g_local, 0)
    err1 = float(jnp.max(jnp.abs(mean - exact)))
    # error feedback: applying twice with residual carried reduces bias
    mean2, _ = sm(g_local, r2)
    two_step = (mean + mean2) / 2
    err2 = float(jnp.max(jnp.abs(two_step - exact)))
    print("ERR1", err1, "ERR2", err2)
    assert err1 < 5e-4            # int8 quantization error bound
    # error feedback keeps the two-step error the same order as one step
    # (it bounds accumulated error; per-step wobble of a few percent is
    # expected, growth by multiples is divergence)
    assert err2 <= 2 * err1
    """)
    assert "ERR1" in out


def test_pipeline_parallel_matches_sequential():
    out = run_devices("""
    from repro.parallel.pipeline_par import run_pipelined
    n_stages, n_micro, mb, d = 4, 8, 4, 16
    mesh = jax.make_mesh((4,), ("stage",))
    ks = jax.random.split(jax.random.PRNGKey(0), n_stages)
    Ws = jnp.stack([jax.random.normal(k, (d, d)) * 0.3 for k in ks])

    micro = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
    # sequential reference
    ref = micro
    for i in range(n_stages):
        ref = jnp.tanh(ref @ Ws[i])
    out = run_pipelined(mesh, "stage", lambda w, x: jnp.tanh(x @ w),
                        Ws, micro, n_stages)
    d_ = float(jnp.max(jnp.abs(out - ref)))
    print("MAXDIFF", d_)
    assert d_ < 1e-5
    """)
    assert "MAXDIFF" in out


def test_elastic_checkpoint_reshard():
    out = run_devices("""
    import tempfile
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.train import checkpoint as ck

    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
            "b": jnp.arange(8.0)}
    mesh8 = jax.make_mesh((8,), ("data",))
    tree8 = jax.device_put(tree, NamedSharding(mesh8, P("data")))
    d = tempfile.mkdtemp()
    t = ck.save_checkpoint(d, 5, tree8, async_save=True)
    t.join()
    # restore under a DIFFERENT mesh shape (elastic restart 8 -> 4)
    mesh4 = jax.make_mesh((4, 2), ("data", "model"))
    sh = {"w": NamedSharding(mesh4, P("data", "model")),
          "b": NamedSharding(mesh4, P(None))}
    restored, step = ck.restore_checkpoint(d, tree, shardings=sh)
    assert step == 5
    ok = bool(jnp.all(restored["w"] == tree["w"]))
    print("RESHARD_OK", ok, restored["w"].sharding.spec)
    assert ok
    # keep-last-k GC
    for s in (6, 7, 8, 9):
        ck.save_checkpoint(d, s, tree8, async_save=False, keep_last_k=2)
    print("STEPS", ck.latest_steps(d))
    assert ck.latest_steps(d) == [8, 9]
    """)
    assert "RESHARD_OK True" in out


def test_straggler_skip_and_preemption():
    from repro.train import ft
    import time

    def slow_iter():
        yield 1
        yield 2
        time.sleep(5.0)
        yield 3

    loader = ft.PrefetchingLoader(slow_iter(), depth=1)
    assert loader.next_batch(deadline_s=5) == 1
    assert loader.next_batch(deadline_s=5) == 2
    b = loader.next_batch(deadline_s=0.2)      # producer is straggling
    assert b == 2 and loader.skipped == 1      # reused last good batch

    guard = ft.PreemptionGuard()
    assert not guard.should_checkpoint
    guard.trigger()
    assert guard.should_checkpoint
