"""Docs drift guard: the engine-mode and workload tables in DESIGN.md §2
and README.md duplicate each other by design (one is the architecture doc,
one the landing page); these tests keep both in lockstep with ``MODES``
and the plan layer's ``WORKLOADS``."""
import os
import re

from repro.core.wavefront import MODES
from repro.engine.plan import WORKLOADS

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mode_table_cells(path: str) -> set:
    """Backticked first-column entries of markdown table rows."""
    cells = set()
    with open(os.path.join(_ROOT, path)) as f:
        for line in f:
            m = re.match(r"\|\s*`([a-z_]+)`\s*\|", line)
            if m:
                cells.add(m.group(1))
    return cells


def test_design_mode_table_lists_every_mode():
    cells = _mode_table_cells("DESIGN.md")
    for mode in MODES:
        assert mode in cells, f"DESIGN.md §2 table is missing `{mode}`"


def test_readme_mode_table_lists_every_mode():
    cells = _mode_table_cells("README.md")
    for mode in MODES:
        assert mode in cells, f"README engine-mode table is missing `{mode}`"


def test_design_workload_table_lists_every_plan_kind():
    cells = _mode_table_cells("DESIGN.md")
    for kind in WORKLOADS:
        assert kind in cells, f"DESIGN.md §2 workload table misses `{kind}`"


def test_readme_workload_table_lists_every_plan_kind():
    cells = _mode_table_cells("README.md")
    for kind in WORKLOADS:
        assert kind in cells, f"README workload table is missing `{kind}`"
