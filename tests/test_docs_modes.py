"""Docs drift guard: the engine-mode, workload, metadata-residency,
admission-policy, SLO, and reliability tables in DESIGN.md §2/§3/§6/§7
and README.md duplicate each other by design (one is the architecture
doc, one the landing page); these tests keep both in lockstep with
``MODES``, the plan layer's ``WORKLOADS``, the persistent megakernel's
``META_LAYOUTS``, the quantizer's ``META_FORMATS``, the batcher's
``ADMISSION_KNOBS``, the serve
harness's ``SLO_METRICS``/``RELIABILITY_METRICS``, and the fault
harness's ``FAILURE_MODES``."""
import dataclasses
import os
import re

from repro.core.counters import Counters
from repro.core.wavefront import MODES
from repro.engine.batcher import ADMISSION_KNOBS
from repro.engine.faults import FAILURE_MODES
from repro.core.quantize import META_FORMATS
from repro.engine.plan import QueryPlan, WORKLOADS
from repro.kernels.persist.ops import (MAX_TILE_BQ, META_LAYOUTS,
                                       SUB_WINDOW_ROWS)
from repro.launch.serve import RELIABILITY_METRICS, SLO_METRICS

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mode_table_cells(path: str) -> set:
    """Backticked first-column entries of markdown table rows (tables may
    be indented when they live inside a list item, e.g. DESIGN.md §3's
    residency table)."""
    cells = set()
    with open(os.path.join(_ROOT, path)) as f:
        for line in f:
            m = re.match(r"\s*\|\s*`([a-z0-9_]+)`\s*\|", line)
            if m:
                cells.add(m.group(1))
    return cells


def test_design_mode_table_lists_every_mode():
    cells = _mode_table_cells("DESIGN.md")
    for mode in MODES:
        assert mode in cells, f"DESIGN.md §2 table is missing `{mode}`"


def test_readme_mode_table_lists_every_mode():
    cells = _mode_table_cells("README.md")
    for mode in MODES:
        assert mode in cells, f"README engine-mode table is missing `{mode}`"


def test_design_workload_table_lists_every_plan_kind():
    cells = _mode_table_cells("DESIGN.md")
    for kind in WORKLOADS:
        assert kind in cells, f"DESIGN.md §2 workload table misses `{kind}`"


def test_readme_workload_table_lists_every_plan_kind():
    cells = _mode_table_cells("README.md")
    for kind in WORKLOADS:
        assert kind in cells, f"README workload table is missing `{kind}`"


def test_design_residency_table_lists_every_meta_layout():
    cells = _mode_table_cells("DESIGN.md")
    for layout in META_LAYOUTS:
        assert layout in cells, \
            f"DESIGN.md §3 residency/streaming table misses `{layout}`"


def test_readme_residency_table_lists_every_meta_layout():
    cells = _mode_table_cells("README.md")
    for layout in META_LAYOUTS:
        assert layout in cells, \
            f"README residency/streaming table is missing `{layout}`"


def test_design_format_table_lists_every_meta_format():
    cells = _mode_table_cells("DESIGN.md")
    for fmt in META_FORMATS:
        assert fmt in cells, \
            f"DESIGN.md §3 META_FORMATS table misses `{fmt}`"


def test_readme_format_table_lists_every_meta_format():
    cells = _mode_table_cells("README.md")
    for fmt in META_FORMATS:
        assert fmt in cells, \
            f"README compressed-metadata table is missing `{fmt}`"


def test_design_serving_section_lists_knobs_and_slos():
    cells = _mode_table_cells("DESIGN.md")
    for knob in ADMISSION_KNOBS:
        assert knob in cells, f"DESIGN.md §6 admission table misses `{knob}`"
    for metric in SLO_METRICS:
        assert metric in cells, f"DESIGN.md §6 SLO table misses `{metric}`"


def test_readme_service_section_lists_knobs_and_slos():
    cells = _mode_table_cells("README.md")
    for knob in ADMISSION_KNOBS:
        assert knob in cells, f"README admission table misses `{knob}`"
    for metric in SLO_METRICS:
        assert metric in cells, f"README SLO table misses `{metric}`"


def test_design_reliability_section_lists_failure_modes_and_counters():
    cells = _mode_table_cells("DESIGN.md")
    for mode in FAILURE_MODES:
        assert mode in cells, \
            f"DESIGN.md §7 failure-mode table misses `{mode}`"
    for metric in RELIABILITY_METRICS:
        assert metric in cells, \
            f"DESIGN.md §7 reliability-counters table misses `{metric}`"


def test_readme_reliability_section_lists_counters():
    cells = _mode_table_cells("README.md")
    for metric in RELIABILITY_METRICS:
        assert metric in cells, \
            f"README service-reliability table misses `{metric}`"


# -- persistent kernel-arm coverage (DESIGN.md §2 table + §3 schedule) --

# The optional QueryPlan lanes the §2 coverage table must map to kernel
# mechanisms.  Listed explicitly (rather than via dataclasses.fields) so
# a *new* optional lane fails the guard below until both the table and
# this tuple are updated.
_PLAN_LANES = ("scene_of_query", "owner_of_query", "payload")


def _flat_text(path: str) -> str:
    """File contents with runs of whitespace collapsed, so guards match
    across markdown line wraps."""
    with open(os.path.join(_ROOT, path)) as f:
        return re.sub(r"\s+", " ", f.read())


def test_design_coverage_table_lists_every_plan_lane():
    plan_fields = {f.name for f in dataclasses.fields(QueryPlan)}
    cells = _mode_table_cells("DESIGN.md")
    for lane in _PLAN_LANES:
        assert lane in plan_fields, f"QueryPlan lost lane `{lane}`"
        assert lane in cells, \
            f"DESIGN.md §2 kernel-arm coverage table misses `{lane}`"


def test_docs_name_the_fallback_counter():
    assert "ref_arm_fallbacks" in {f.name
                                   for f in dataclasses.fields(Counters)}
    for path in ("DESIGN.md", "README.md"):
        assert "ref_arm_fallbacks" in _flat_text(path), \
            f"{path} no longer documents Counters.ref_arm_fallbacks"


def test_design_window_constants_match_code():
    text = _flat_text("DESIGN.md")
    assert f"`SUB_WINDOW_ROWS` = {SUB_WINDOW_ROWS}" in text, \
        "DESIGN.md §3 window-schedule bullet disagrees with SUB_WINDOW_ROWS"
    assert "2 * (SUB_WINDOW_ROWS + 8)" in text, \
        "DESIGN.md no longer states the constant ping/pong VMEM footprint"
    assert f"`MAX_TILE_BQ` = {MAX_TILE_BQ}" in text, \
        "DESIGN.md §3 owner-tiling paragraph disagrees with MAX_TILE_BQ"
    assert f"`MAX_TILE_BQ` ({MAX_TILE_BQ})" in text, \
        "DESIGN.md §2 coverage table's capability bound disagrees with code"


def test_readme_window_constants_match_code():
    text = _flat_text("README.md")
    assert f"{SUB_WINDOW_ROWS} rows/slot" in text, \
        "README streamed-row cell disagrees with SUB_WINDOW_ROWS"
    assert f"wider than {MAX_TILE_BQ} slots" in text, \
        "README one-code-path paragraph disagrees with MAX_TILE_BQ"
