"""Docs drift guard: the engine-mode, workload, and metadata-residency
tables in DESIGN.md §2/§3 and README.md duplicate each other by design
(one is the architecture doc, one the landing page); these tests keep
both in lockstep with ``MODES``, the plan layer's ``WORKLOADS``, and the
persistent megakernel's ``META_LAYOUTS``."""
import os
import re

from repro.core.wavefront import MODES
from repro.engine.plan import WORKLOADS
from repro.kernels.persist.ops import META_LAYOUTS

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mode_table_cells(path: str) -> set:
    """Backticked first-column entries of markdown table rows (tables may
    be indented when they live inside a list item, e.g. DESIGN.md §3's
    residency table)."""
    cells = set()
    with open(os.path.join(_ROOT, path)) as f:
        for line in f:
            m = re.match(r"\s*\|\s*`([a-z_]+)`\s*\|", line)
            if m:
                cells.add(m.group(1))
    return cells


def test_design_mode_table_lists_every_mode():
    cells = _mode_table_cells("DESIGN.md")
    for mode in MODES:
        assert mode in cells, f"DESIGN.md §2 table is missing `{mode}`"


def test_readme_mode_table_lists_every_mode():
    cells = _mode_table_cells("README.md")
    for mode in MODES:
        assert mode in cells, f"README engine-mode table is missing `{mode}`"


def test_design_workload_table_lists_every_plan_kind():
    cells = _mode_table_cells("DESIGN.md")
    for kind in WORKLOADS:
        assert kind in cells, f"DESIGN.md §2 workload table misses `{kind}`"


def test_readme_workload_table_lists_every_plan_kind():
    cells = _mode_table_cells("README.md")
    for kind in WORKLOADS:
        assert kind in cells, f"README workload table is missing `{kind}`"


def test_design_residency_table_lists_every_meta_layout():
    cells = _mode_table_cells("DESIGN.md")
    for layout in META_LAYOUTS:
        assert layout in cells, \
            f"DESIGN.md §3 residency/streaming table misses `{layout}`"


def test_readme_residency_table_lists_every_meta_layout():
    cells = _mode_table_cells("README.md")
    for layout in META_LAYOUTS:
        assert layout in cells, \
            f"README residency/streaming table is missing `{layout}`"
