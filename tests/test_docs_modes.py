"""Docs drift guard: the engine-mode, workload, metadata-residency,
admission-policy, SLO, and reliability tables in DESIGN.md §2/§3/§6/§7
and README.md duplicate each other by design (one is the architecture
doc, one the landing page); these tests keep both in lockstep with
``MODES``, the plan layer's ``WORKLOADS``, the persistent megakernel's
``META_LAYOUTS``, the quantizer's ``META_FORMATS``, the batcher's
``ADMISSION_KNOBS``, the serve
harness's ``SLO_METRICS``/``RELIABILITY_METRICS``, and the fault
harness's ``FAILURE_MODES``."""
import os
import re

from repro.core.wavefront import MODES
from repro.engine.batcher import ADMISSION_KNOBS
from repro.engine.faults import FAILURE_MODES
from repro.core.quantize import META_FORMATS
from repro.engine.plan import WORKLOADS
from repro.kernels.persist.ops import META_LAYOUTS
from repro.launch.serve import RELIABILITY_METRICS, SLO_METRICS

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _mode_table_cells(path: str) -> set:
    """Backticked first-column entries of markdown table rows (tables may
    be indented when they live inside a list item, e.g. DESIGN.md §3's
    residency table)."""
    cells = set()
    with open(os.path.join(_ROOT, path)) as f:
        for line in f:
            m = re.match(r"\s*\|\s*`([a-z0-9_]+)`\s*\|", line)
            if m:
                cells.add(m.group(1))
    return cells


def test_design_mode_table_lists_every_mode():
    cells = _mode_table_cells("DESIGN.md")
    for mode in MODES:
        assert mode in cells, f"DESIGN.md §2 table is missing `{mode}`"


def test_readme_mode_table_lists_every_mode():
    cells = _mode_table_cells("README.md")
    for mode in MODES:
        assert mode in cells, f"README engine-mode table is missing `{mode}`"


def test_design_workload_table_lists_every_plan_kind():
    cells = _mode_table_cells("DESIGN.md")
    for kind in WORKLOADS:
        assert kind in cells, f"DESIGN.md §2 workload table misses `{kind}`"


def test_readme_workload_table_lists_every_plan_kind():
    cells = _mode_table_cells("README.md")
    for kind in WORKLOADS:
        assert kind in cells, f"README workload table is missing `{kind}`"


def test_design_residency_table_lists_every_meta_layout():
    cells = _mode_table_cells("DESIGN.md")
    for layout in META_LAYOUTS:
        assert layout in cells, \
            f"DESIGN.md §3 residency/streaming table misses `{layout}`"


def test_readme_residency_table_lists_every_meta_layout():
    cells = _mode_table_cells("README.md")
    for layout in META_LAYOUTS:
        assert layout in cells, \
            f"README residency/streaming table is missing `{layout}`"


def test_design_format_table_lists_every_meta_format():
    cells = _mode_table_cells("DESIGN.md")
    for fmt in META_FORMATS:
        assert fmt in cells, \
            f"DESIGN.md §3 META_FORMATS table misses `{fmt}`"


def test_readme_format_table_lists_every_meta_format():
    cells = _mode_table_cells("README.md")
    for fmt in META_FORMATS:
        assert fmt in cells, \
            f"README compressed-metadata table is missing `{fmt}`"


def test_design_serving_section_lists_knobs_and_slos():
    cells = _mode_table_cells("DESIGN.md")
    for knob in ADMISSION_KNOBS:
        assert knob in cells, f"DESIGN.md §6 admission table misses `{knob}`"
    for metric in SLO_METRICS:
        assert metric in cells, f"DESIGN.md §6 SLO table misses `{metric}`"


def test_readme_service_section_lists_knobs_and_slos():
    cells = _mode_table_cells("README.md")
    for knob in ADMISSION_KNOBS:
        assert knob in cells, f"README admission table misses `{knob}`"
    for metric in SLO_METRICS:
        assert metric in cells, f"README SLO table misses `{metric}`"


def test_design_reliability_section_lists_failure_modes_and_counters():
    cells = _mode_table_cells("DESIGN.md")
    for mode in FAILURE_MODES:
        assert mode in cells, \
            f"DESIGN.md §7 failure-mode table misses `{mode}`"
    for metric in RELIABILITY_METRICS:
        assert metric in cells, \
            f"DESIGN.md §7 reliability-counters table misses `{metric}`"


def test_readme_reliability_section_lists_counters():
    cells = _mode_table_cells("README.md")
    for metric in RELIABILITY_METRICS:
        assert metric in cells, \
            f"README service-reliability table misses `{metric}`"
