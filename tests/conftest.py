import os
import sys

# Keep CPU thread usage sane on the 1-core container.
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false "
                      "intra_op_parallelism_threads=1")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
