import os
import sys

# Keep CPU thread usage sane on the 1-core container.
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false "
                      "intra_op_parallelism_threads=1")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Deterministic fallback seeds for property-based tests when hypothesis (the
# ``dev`` extra) is absent: the test body runs over these fixed seeds instead
# of skipping, so a clean CPU run reports 0 skipped either way.
FIXED_PROPERTY_SEEDS = (0, 1, 7, 42, 1234, 99991)


def seeded_property(max_examples: int = 10):
    """Decorator for property tests taking one integer ``seed`` argument.

    With hypothesis installed, the test runs under ``@given`` with random
    integer seeds; without it, the same body loops over
    :data:`FIXED_PROPERTY_SEEDS` — a capability downgrade, never a skip.
    """
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        def deco(fn):
            def run_fixed_seeds():
                for seed in FIXED_PROPERTY_SEEDS[:max_examples]:
                    fn(seed)
            # No functools.wraps: its __wrapped__ would make pytest see the
            # one-argument signature and demand a ``seed`` fixture.
            run_fixed_seeds.__name__ = fn.__name__
            run_fixed_seeds.__doc__ = fn.__doc__
            return run_fixed_seeds
        return deco

    def deco(fn):
        return settings(max_examples=max_examples, deadline=None)(
            given(st.integers(0, 2**31 - 1))(fn))
    return deco
