"""Per-kernel interpret-mode validation vs pure-jnp oracles (shape sweeps)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.geometry import random_aabbs, random_obbs


@pytest.mark.parametrize("M,N,bm,bn,sph", [
    (64, 100, 32, 32, False),
    (130, 257, 64, 128, False),
    (8, 8, 8, 8, False),
    (100, 64, 32, 32, True),
])
def test_sact_kernel(M, N, bm, bn, sph):
    from repro.kernels.sact.ops import sact_fused_boxes
    from repro.kernels.sact.ref import sact_ref
    obbs = random_obbs(jax.random.PRNGKey(M + N), M)
    aabbs = random_aabbs(jax.random.PRNGKey(M * N), N)
    col_k, ec_k = sact_fused_boxes(obbs, aabbs, bm=bm, bn=bn,
                                   use_spheres=sph, interpret=True)
    col_r, ec_r = sact_ref(obbs.center, obbs.half, obbs.rot, aabbs.center,
                           aabbs.half, use_spheres=sph)
    assert bool(jnp.all(col_k == col_r))
    assert bool(jnp.all(ec_k == ec_r))


@pytest.mark.parametrize("M,N,r,k", [(70, 1000, 0.3, 16), (33, 500, 0.5, 4),
                                     (16, 128, 0.2, 8)])
def test_ballquery_kernel(M, N, r, k):
    from repro.kernels.ballquery.ops import ball_query_tiled
    from repro.kernels.ballquery.ref import ball_query_ref
    rs = np.random.RandomState(M)
    pts = jnp.asarray(rs.uniform(-1, 1, (N, 3)).astype(np.float32))
    qs = jnp.asarray(rs.uniform(-1, 1, (M, 3)).astype(np.float32))
    idx_k, cnt_k = ball_query_tiled(qs, pts, r, k, bm=32, bn=64)
    idx_r, cnt_r = ball_query_ref(pts, qs, r, k)
    assert bool(jnp.all(cnt_k == cnt_r))
    assert bool(jnp.all(idx_k == idx_r))     # exact: same first-k order


@pytest.mark.parametrize("N,m,bn", [(1000, 33, 128), (513, 16, 64)])
def test_fps_kernel(N, m, bn):
    from repro.kernels.fps.ops import fps_pallas
    from repro.kernels.fps.ref import fps_ref
    rs = np.random.RandomState(N)
    pts = jnp.asarray(rs.uniform(-1, 1, (N, 3)).astype(np.float32))
    assert bool(jnp.all(fps_pallas(pts, m, bn=bn) == fps_ref(pts, m)))


@pytest.mark.parametrize("BH,T,D,chunk", [(3, 70, 16, 16), (2, 64, 32, 32),
                                          (1, 33, 8, 8)])
def test_wkv6_kernel(BH, T, D, chunk):
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_ref
    rs = np.random.RandomState(T)
    mk = lambda s=0.5: jnp.asarray(
        rs.normal(size=(BH, T, D)).astype(np.float32)) * s
    r, k, v = mk(), mk(), mk(1.0)
    logw = -jnp.asarray(rs.uniform(0.01, 3.0, (BH, T, D)).astype(np.float32))
    u = jnp.asarray(rs.normal(size=(D,)).astype(np.float32)) * 0.3
    o_k, s_k = wkv6(r, k, v, logw, u, chunk=chunk)
    o_r, s_r = wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,d,causal", [
    (2, 4, 2, 64, 64, 32, True),
    (1, 8, 8, 100, 100, 16, True),
    (2, 4, 1, 40, 72, 32, False),
])
def test_flash_attention_kernel(B, Hq, Hkv, Tq, Tk, d, causal):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    rs = np.random.RandomState(B * Tq)
    q = jnp.asarray(rs.normal(size=(B, Hq, Tq, d)).astype(np.float32))
    k = jnp.asarray(rs.normal(size=(B, Hkv, Tk, d)).astype(np.float32))
    v = jnp.asarray(rs.normal(size=(B, Hkv, Tk, d)).astype(np.float32))
    o_k = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    o_r = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)


def test_wkv6_bf16_dtype():
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_ref
    rs = np.random.RandomState(9)
    BH, T, D = 2, 32, 16
    r = jnp.asarray(rs.normal(size=(BH, T, D)), jnp.bfloat16) * 0.5
    k = jnp.asarray(rs.normal(size=(BH, T, D)), jnp.bfloat16) * 0.5
    v = jnp.asarray(rs.normal(size=(BH, T, D)), jnp.bfloat16)
    logw = -jnp.asarray(rs.uniform(0.1, 2.0, (BH, T, D)), jnp.bfloat16)
    u = jnp.asarray(rs.normal(size=(D,)), jnp.bfloat16) * 0.3
    o_k, _ = wkv6(r, k, v, logw, u, chunk=16)
    o_r, _ = wkv6_ref(r.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), logw.astype(jnp.float32),
                      u.astype(jnp.float32))
    assert o_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r), atol=0.15, rtol=0.1)
