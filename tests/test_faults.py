"""Chaos suite: the DESIGN.md §7 reliability contract under injected faults.

Every test drives the service layer (validate-at-submit, bisect-retry,
deadlines/backpressure, the launch watchdog) through
:mod:`repro.engine.faults` and asserts the contract: no client ticket
ever hangs — every ``submit`` resolves to a verdict or a typed error —
and a poisoned request never fails an innocent co-batched request.
"""
import threading
import time

import numpy as np
import jax
import pytest

from conftest import seeded_property
from test_distributed import run_devices

from repro.core.geometry import random_obbs
from repro.core.octree import build_octree
from repro.engine.batcher import (BatcherClosed, DeadlineExceeded,
                                  LaunchStalled, Overloaded, RequestBatcher,
                                  WorkerDied, _pad_bucket)
from repro.engine.executor import CollisionEngine, EngineConfig
from repro.engine.faults import (FAILURE_MODES, POISON_KINDS, FaultPlan,
                                 FaultyEngine, InjectedFault, SimulatedOOM,
                                 poison_obbs, poisoned_plan)
from repro.engine.plan import (PlanValidationError, plan_queries,
                               validate_plan)


def _tree(seed, n=2000, depth=3):
    rs = np.random.RandomState(seed)
    return build_octree(rs.uniform(-1, 1, (n, 3)).astype(np.float32),
                        depth=depth)


def _engine(seed=0, **cfg):
    return CollisionEngine(_tree(seed),
                           EngineConfig(mode="wavefront_fused", **cfg))


class _CountingEngine:
    """Engine wrapper proving what does / does not reach ``execute``."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0
        self.pools = []

    @property
    def octree(self):
        return self.inner.octree

    @property
    def cfg(self):
        return self.inner.cfg

    def execute(self, plan):
        self.calls += 1
        self.pools.append(np.asarray(plan.obb_c))
        return self.inner.execute(plan)


# ---------------------------------------------------------------------------
# Malformed-input rejection at submit
# ---------------------------------------------------------------------------

@seeded_property(max_examples=8)
def test_malformed_plans_rejected_at_submit_never_reach_engine(seed):
    """Property: every poison kind, any slot, is rejected at ``submit``
    with a message naming the offending field, and the engine never sees
    the pool."""
    rs = np.random.RandomState(seed)
    kind = POISON_KINDS[rs.randint(len(POISON_KINDS))]
    n = int(rs.randint(1, 24))
    slot = int(rs.randint(n))
    obbs = random_obbs(jax.random.PRNGKey(seed), n)
    bad = poisoned_plan(obbs, kind, slot=slot)
    with pytest.raises(PlanValidationError) as ei:
        validate_plan(bad)
    assert "obb_" in str(ei.value)       # names the offending field
    eng = _CountingEngine(_engine())
    with RequestBatcher(eng, max_wait_ms=1.0) as b:
        with pytest.raises(PlanValidationError):
            b.submit(bad)
    assert eng.calls == 0, "malformed plan reached engine.execute"
    assert b.totals.rejected == 1


@seeded_property(max_examples=6)
def test_clean_plans_pass_validation(seed):
    obbs = random_obbs(jax.random.PRNGKey(seed), 9)
    plan = plan_queries(obbs)
    assert validate_plan(plan) is plan


def test_wrong_shape_rejected():
    obbs = random_obbs(jax.random.PRNGKey(0), 4)
    plan = plan_queries(obbs)
    bad = plan_queries(obbs)
    object.__setattr__(bad, "obb_h", np.asarray(obbs.half)[:, :2])
    with pytest.raises(PlanValidationError, match="shape"):
        validate_plan(bad)
    assert validate_plan(plan) is plan


# ---------------------------------------------------------------------------
# Fault isolation: bisect-retry
# ---------------------------------------------------------------------------

def test_poisoned_request_fails_alone_in_16_request_batch():
    """Regression for the §7 isolation contract: one poisoned request in a
    16-request coalesced batch errors alone; the other 15 verdicts are
    bitwise-identical to un-batched execution."""
    inner = _engine()
    reqs = [random_obbs(jax.random.PRNGKey(100 + i), 3 + i % 5)
            for i in range(16)]
    refs = [inner.execute(plan_queries(o))[0] for o in reqs]
    poisoned_i = 11
    # poison_nan models "this request crashes any launch it rides in";
    # validate=False sneaks it past admission (a fault validation missed).
    fe = FaultyEngine(inner, FaultPlan(poison_nan=True))
    with RequestBatcher(fe, max_batch=4096, max_wait_ms=250.0,
                        max_retries=0) as b:
        tickets = []
        for i, o in enumerate(reqs):
            if i == poisoned_i:
                tickets.append(b.submit(
                    poisoned_plan(o, "nan_center"), validate=False))
            else:
                tickets.append(b.submit(o))
        for i, t in enumerate(tickets):
            if i == poisoned_i:
                with pytest.raises(InjectedFault):
                    t.result(timeout=120)
            else:
                v, st = t.result(timeout=120)
                assert (v == refs[i]).all(), i
                assert st.splits >= 1     # rode through the bisection
        assert b.totals.launch_splits >= 4   # isolating 1 of 16 takes log2
        assert b.totals.launch_splits <= 15


def test_transient_oom_retries_at_reduced_width():
    """SimulatedOOM (RESOURCE_EXHAUSTED) retries with backoff, shrinking
    the oversized pow2 pad bucket toward the exact pool width."""
    inner = _engine()
    obbs = random_obbs(jax.random.PRNGKey(1), 5)
    ref = inner.execute(plan_queries(obbs))[0]
    fe = FaultyEngine(inner, FaultPlan(oom_rate=1.0, max_faults=1))
    with RequestBatcher(fe, max_wait_ms=1.0, max_retries=2,
                        retry_backoff_ms=0.1) as b:
        v, st = b.submit(obbs).result(timeout=120)
    assert (v == ref).all()
    assert st.retries == 1 and b.totals.retried == 1
    # First attempt padded to _pad_bucket(5)=64; the retry asked for half.
    assert st.pad_queries == _pad_bucket(5) // 2 - 5
    assert fe.injected["oom"] == 1


def test_retries_exhausted_surfaces_transient_error():
    inner = _engine()
    fe = FaultyEngine(inner, FaultPlan(oom_rate=1.0))   # every call OOMs
    obbs = random_obbs(jax.random.PRNGKey(2), 4)
    with RequestBatcher(fe, max_wait_ms=1.0, max_retries=1,
                        retry_backoff_ms=0.1) as b:
        with pytest.raises(SimulatedOOM):
            b.submit(obbs).result(timeout=120)
    assert b.totals.retried == 1


# ---------------------------------------------------------------------------
# Deadlines, backpressure, shedding
# ---------------------------------------------------------------------------

def test_deadline_exceeded_rejected_fast_never_launched():
    """A request whose deadline passed while queued fails typed BEFORE the
    launch: the engine never sees its queries."""
    inner = _CountingEngine(_engine())
    fe = FaultyEngine(inner, FaultPlan(stall_rate=1.0, stall_s=0.4,
                                       max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(3), 4)
    with RequestBatcher(fe, max_wait_ms=1.0) as b:
        t1 = b.submit(obbs)                  # rides the stalled launch
        time.sleep(0.1)                      # worker is now inside the stall
        t2 = b.submit(obbs, deadline_ms=0.01)
        with pytest.raises(DeadlineExceeded, match="unmeetable"):
            t2.result(timeout=120)
        t1.result(timeout=120)
        assert inner.calls == 1              # only t1's launch ran
        b.submit(obbs).result(timeout=120)   # service still live
        assert inner.calls == 2              # ... and t2 never launched
    assert b.totals.deadline_missed == 1


def test_overload_sheds_at_submit():
    """Bounded admission: submits beyond ``max_queue`` fail fast with
    Overloaded while queued requests still complete."""
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=0.5,
                                           max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(4), 4)
    with RequestBatcher(fe, max_wait_ms=1.0, max_queue=1) as b:
        t1 = b.submit(obbs)
        time.sleep(0.1)                      # worker busy inside the stall
        t2 = b.submit(obbs)                  # fills the bounded queue
        with pytest.raises(Overloaded, match="queue full"):
            b.submit(obbs)
        assert b.totals.rejected == 1
        t1.result(timeout=120)
        t2.result(timeout=120)


# ---------------------------------------------------------------------------
# Liveness: stalls, worker death, watchdog
# ---------------------------------------------------------------------------

def test_launch_stall_fails_batch_typed_and_service_recovers():
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=2.0,
                                           max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(5), 4)
    ref = fe.inner.execute(plan_queries(obbs))[0]
    with RequestBatcher(fe, max_wait_ms=1.0, launch_timeout_s=0.2) as b:
        with pytest.raises(LaunchStalled, match="launch_timeout_s"):
            b.submit(obbs).result(timeout=120)
        v, _ = b.submit(obbs).result(timeout=120)   # service recovered
        assert (v == ref).all()


def test_worker_death_fails_inflight_typed_and_self_heals():
    """An exception escaping per-launch containment kills the worker; the
    watchdog fails the unresolved in-flight tickets with WorkerDied and
    restarts the worker, so the next submit is served normally."""
    fe = FaultyEngine(_engine(), FaultPlan(crash_rate=1.0, max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(6), 4)
    ref = fe.inner.execute(plan_queries(obbs))[0]
    with RequestBatcher(fe, max_wait_ms=1.0) as b:
        with pytest.raises(WorkerDied, match="watchdog"):
            b.submit(obbs).result(timeout=120)
        v, _ = b.submit(obbs).result(timeout=120)   # restarted worker
        assert (v == ref).all()
        assert b.totals.worker_restarts == 1


# ---------------------------------------------------------------------------
# Ticket semantics + close() stranding (satellites)
# ---------------------------------------------------------------------------

def test_ticket_state_and_recallable_result():
    """Ticket state distinguishes queued / launched / done, the timeout
    error names the state, and ``result`` is safely re-callable."""
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=0.6,
                                           max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(7), 4)
    with RequestBatcher(fe, max_wait_ms=1.0) as b:
        t1 = b.submit(obbs)                  # will ride the stalled launch
        time.sleep(0.15)
        assert t1.state == "launched"
        t2 = b.submit(obbs)                  # queued behind the stall
        assert t2.state == "queued"
        with pytest.raises(TimeoutError, match="queued"):
            t2.result(timeout=0.01)
        with pytest.raises(TimeoutError, match="launched"):
            t1.result(timeout=0.01)
        v1, _ = t1.result(timeout=120)       # re-call after timeout works
        v2, _ = t2.result(timeout=120)
        assert t1.state == "done" and t2.state == "done"
        assert (v1 == v2).all()
        v1b, _ = t1.result(timeout=0.01)     # done: instant, repeatable
        assert (v1b == v1).all()


def test_close_fails_stranded_requests_typed():
    """Requests still queued when the batcher stops (stuck worker) resolve
    promptly with BatcherClosed — no ticket is silently dropped — and
    submit after close raises the same type."""
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=1.5,
                                           max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(8), 4)
    b = RequestBatcher(fe, max_wait_ms=1.0)
    b.submit(obbs)                           # occupies the worker (stall)
    time.sleep(0.15)
    stranded = [b.submit(obbs) for _ in range(3)]
    b.close(timeout=0.2)                     # worker still inside the stall
    for t in stranded:
        with pytest.raises(BatcherClosed):
            t.result(timeout=5)
    with pytest.raises(BatcherClosed):
        b.submit(obbs)


def test_close_launches_already_queued_work():
    """The graceful path: close() after the worker drains lets queued
    requests complete rather than failing them."""
    eng = _engine()
    obbs = random_obbs(jax.random.PRNGKey(9), 4)
    ref = eng.execute(plan_queries(obbs))[0]
    b = RequestBatcher(eng, max_wait_ms=1.0)
    t = b.submit(obbs)
    b.close()
    v, _ = t.result(timeout=120)
    assert (v == ref).all()


# ---------------------------------------------------------------------------
# Chaos end-to-end: the serve harness under a full FaultPlan
# ---------------------------------------------------------------------------

def test_chaos_service_no_hangs_no_drops_and_graceful_slos():
    """`run_service` under every §7 failure mode at once: all submits
    resolve (the harness asserts completed + failed == submitted), the
    reliability counters flow into the report, and healthy-request p99
    degrades gracefully (within 2x of the no-chaos run, plus a scheduling
    floor for this 1-core container)."""
    from repro.launch.serve import RELIABILITY_METRICS, run_service
    tree = _tree(10, n=1500)
    clean = run_service(tree, clients=3, requests=8, queries_per_request=4,
                        max_wait_ms=5.0, mode="wavefront_fused", seed=0)
    chaos = FaultPlan(malformed_rate=0.15, exception_rate=0.12,
                      oom_rate=0.1, stall_rate=0.06, crash_rate=0.04,
                      stall_s=0.6, seed=0)
    rep = run_service(tree, clients=3, requests=8, queries_per_request=4,
                      max_wait_ms=5.0, mode="wavefront_fused", seed=0,
                      deadline_ms=5000.0, launch_timeout_s=0.25,
                      chaos=chaos)
    assert rep["submitted"] == 24
    assert rep["requests"] + rep["failed"] == rep["submitted"]
    assert rep["failed"] > 0, "chaos rates injected nothing"
    for metric in RELIABILITY_METRICS:
        assert metric in rep
    assert rep["rejected"] >= 1          # malformed requests were shed
    assert rep["requests"] > 0           # healthy requests still complete
    assert rep["p99_ms"] <= 2 * clean["p99_ms"] + 300.0, \
        (rep["p99_ms"], clean["p99_ms"])


def test_chaos_sharded_engine_on_eight_devices():
    """The fault-injection stack over a shard_map engine: chaos containment
    must not depend on single-device execution."""
    out = run_devices("""
    from repro.core.octree import build_octree
    from repro.engine.faults import FaultPlan
    from repro.launch.serve import run_service

    rs = np.random.RandomState(0)
    tree = build_octree(rs.uniform(-1, 1, (1500, 3)).astype(np.float32),
                        depth=3)
    chaos = FaultPlan(malformed_rate=0.1, exception_rate=0.1, oom_rate=0.1,
                      seed=0)
    rep = run_service(tree, clients=2, requests=4, queries_per_request=4,
                      max_wait_ms=5.0, mode="wavefront_fused", shards=8,
                      deadline_ms=10000.0, chaos=chaos)
    assert rep["requests"] + rep["failed"] == rep["submitted"] == 8
    print("CHAOS_SHARDED_OK", rep["requests"], rep["failed"])
    """)
    assert "CHAOS_SHARDED_OK" in out


def test_failure_modes_tuple_is_canonical():
    assert len(set(FAILURE_MODES)) == len(FAILURE_MODES)
    for m in ("malformed_plan", "engine_exception", "worker_death",
              "overload", "deadline_miss"):
        assert m in FAILURE_MODES
