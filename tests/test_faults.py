"""Chaos suite: the DESIGN.md §7 reliability contract under injected faults.

Every test drives the service layer (validate-at-submit, bisect-retry,
deadlines/backpressure, the launch watchdog) through
:mod:`repro.engine.faults` and asserts the contract: no client ticket
ever hangs — every ``submit`` resolves to a verdict or a typed error —
and a poisoned request never fails an innocent co-batched request.
"""
import threading
import time

import numpy as np
import jax
import pytest

from conftest import seeded_property
from test_distributed import run_devices

from repro.core.geometry import random_obbs
from repro.core.octree import build_octree
from repro.engine.batcher import (BatcherClosed, DeadlineExceeded,
                                  DeviceLost, LaunchStalled, Overloaded,
                                  RequestBatcher, WorkerDied, _pad_bucket)
from repro.engine.executor import CollisionEngine, EngineConfig
from repro.engine.faults import (FAILURE_MODES, POISON_KINDS, FaultPlan,
                                 FaultyEngine, InjectedFault,
                                 SimulatedDeviceLoss, SimulatedOOM,
                                 poison_obbs, poisoned_plan)
from repro.engine.plan import (PlanValidationError, plan_queries,
                               validate_plan)


def _tree(seed, n=2000, depth=3):
    rs = np.random.RandomState(seed)
    return build_octree(rs.uniform(-1, 1, (n, 3)).astype(np.float32),
                        depth=depth)


def _engine(seed=0, **cfg):
    return CollisionEngine(_tree(seed),
                           EngineConfig(mode="wavefront_fused", **cfg))


class _CountingEngine:
    """Engine wrapper proving what does / does not reach ``execute``."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0
        self.pools = []

    @property
    def octree(self):
        return self.inner.octree

    @property
    def cfg(self):
        return self.inner.cfg

    def execute(self, plan):
        self.calls += 1
        self.pools.append(np.asarray(plan.obb_c))
        return self.inner.execute(plan)


# ---------------------------------------------------------------------------
# Malformed-input rejection at submit
# ---------------------------------------------------------------------------

@seeded_property(max_examples=8)
def test_malformed_plans_rejected_at_submit_never_reach_engine(seed):
    """Property: every poison kind, any slot, is rejected at ``submit``
    with a message naming the offending field, and the engine never sees
    the pool."""
    rs = np.random.RandomState(seed)
    kind = POISON_KINDS[rs.randint(len(POISON_KINDS))]
    n = int(rs.randint(1, 24))
    slot = int(rs.randint(n))
    obbs = random_obbs(jax.random.PRNGKey(seed), n)
    bad = poisoned_plan(obbs, kind, slot=slot)
    with pytest.raises(PlanValidationError) as ei:
        validate_plan(bad)
    assert "obb_" in str(ei.value)       # names the offending field
    eng = _CountingEngine(_engine())
    with RequestBatcher(eng, max_wait_ms=1.0) as b:
        with pytest.raises(PlanValidationError):
            b.submit(bad)
    assert eng.calls == 0, "malformed plan reached engine.execute"
    assert b.totals.rejected == 1


@seeded_property(max_examples=6)
def test_clean_plans_pass_validation(seed):
    obbs = random_obbs(jax.random.PRNGKey(seed), 9)
    plan = plan_queries(obbs)
    assert validate_plan(plan) is plan


def test_wrong_shape_rejected():
    obbs = random_obbs(jax.random.PRNGKey(0), 4)
    plan = plan_queries(obbs)
    bad = plan_queries(obbs)
    object.__setattr__(bad, "obb_h", np.asarray(obbs.half)[:, :2])
    with pytest.raises(PlanValidationError, match="shape"):
        validate_plan(bad)
    assert validate_plan(plan) is plan


# ---------------------------------------------------------------------------
# Fault isolation: bisect-retry
# ---------------------------------------------------------------------------

def test_poisoned_request_fails_alone_in_16_request_batch():
    """Regression for the §7 isolation contract: one poisoned request in a
    16-request coalesced batch errors alone; the other 15 verdicts are
    bitwise-identical to un-batched execution."""
    inner = _engine()
    reqs = [random_obbs(jax.random.PRNGKey(100 + i), 3 + i % 5)
            for i in range(16)]
    refs = [inner.execute(plan_queries(o))[0] for o in reqs]
    poisoned_i = 11
    # poison_nan models "this request crashes any launch it rides in";
    # validate=False sneaks it past admission (a fault validation missed).
    fe = FaultyEngine(inner, FaultPlan(poison_nan=True))
    with RequestBatcher(fe, max_batch=4096, max_wait_ms=250.0,
                        max_retries=0) as b:
        tickets = []
        for i, o in enumerate(reqs):
            if i == poisoned_i:
                tickets.append(b.submit(
                    poisoned_plan(o, "nan_center"), validate=False))
            else:
                tickets.append(b.submit(o))
        for i, t in enumerate(tickets):
            if i == poisoned_i:
                with pytest.raises(InjectedFault):
                    t.result(timeout=120)
            else:
                v, st = t.result(timeout=120)
                assert (v == refs[i]).all(), i
                assert st.splits >= 1     # rode through the bisection
        assert b.totals.launch_splits >= 4   # isolating 1 of 16 takes log2
        assert b.totals.launch_splits <= 15


def test_transient_oom_retries_at_reduced_width():
    """SimulatedOOM (RESOURCE_EXHAUSTED) retries with backoff, shrinking
    the oversized pow2 pad bucket toward the exact pool width."""
    inner = _engine()
    obbs = random_obbs(jax.random.PRNGKey(1), 5)
    ref = inner.execute(plan_queries(obbs))[0]
    fe = FaultyEngine(inner, FaultPlan(oom_rate=1.0, max_faults=1))
    with RequestBatcher(fe, max_wait_ms=1.0, max_retries=2,
                        retry_backoff_ms=0.1) as b:
        v, st = b.submit(obbs).result(timeout=120)
    assert (v == ref).all()
    assert st.retries == 1 and b.totals.retried == 1
    # First attempt padded to _pad_bucket(5)=64; the retry asked for half.
    assert st.pad_queries == _pad_bucket(5) // 2 - 5
    assert fe.injected["oom"] == 1


def test_retries_exhausted_surfaces_transient_error():
    inner = _engine()
    fe = FaultyEngine(inner, FaultPlan(oom_rate=1.0))   # every call OOMs
    obbs = random_obbs(jax.random.PRNGKey(2), 4)
    with RequestBatcher(fe, max_wait_ms=1.0, max_retries=1,
                        retry_backoff_ms=0.1) as b:
        with pytest.raises(SimulatedOOM):
            b.submit(obbs).result(timeout=120)
    assert b.totals.retried == 1


# ---------------------------------------------------------------------------
# Deadlines, backpressure, shedding
# ---------------------------------------------------------------------------

def test_deadline_exceeded_rejected_fast_never_launched():
    """A request whose deadline passed while queued fails typed BEFORE the
    launch: the engine never sees its queries."""
    inner = _CountingEngine(_engine())
    fe = FaultyEngine(inner, FaultPlan(stall_rate=1.0, stall_s=0.4,
                                       max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(3), 4)
    with RequestBatcher(fe, max_wait_ms=1.0) as b:
        t1 = b.submit(obbs)                  # rides the stalled launch
        time.sleep(0.1)                      # worker is now inside the stall
        t2 = b.submit(obbs, deadline_ms=0.01)
        with pytest.raises(DeadlineExceeded, match="unmeetable"):
            t2.result(timeout=120)
        t1.result(timeout=120)
        assert inner.calls == 1              # only t1's launch ran
        b.submit(obbs).result(timeout=120)   # service still live
        assert inner.calls == 2              # ... and t2 never launched
    assert b.totals.deadline_missed == 1


def test_overload_sheds_at_submit():
    """Bounded admission: submits beyond ``max_queue`` fail fast with
    Overloaded while queued requests still complete."""
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=0.5,
                                           max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(4), 4)
    with RequestBatcher(fe, max_wait_ms=1.0, max_queue=1) as b:
        t1 = b.submit(obbs)
        time.sleep(0.1)                      # worker busy inside the stall
        t2 = b.submit(obbs)                  # fills the bounded queue
        with pytest.raises(Overloaded, match="queue full"):
            b.submit(obbs)
        assert b.totals.rejected == 1
        t1.result(timeout=120)
        t2.result(timeout=120)


# ---------------------------------------------------------------------------
# Liveness: stalls, worker death, watchdog
# ---------------------------------------------------------------------------

def test_launch_stall_fails_batch_typed_and_service_recovers():
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=2.0,
                                           max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(5), 4)
    ref = fe.inner.execute(plan_queries(obbs))[0]
    with RequestBatcher(fe, max_wait_ms=1.0, launch_timeout_s=0.2) as b:
        with pytest.raises(LaunchStalled, match="launch_timeout_s"):
            b.submit(obbs).result(timeout=120)
        v, _ = b.submit(obbs).result(timeout=120)   # service recovered
        assert (v == ref).all()


def test_worker_death_fails_inflight_typed_and_self_heals():
    """An exception escaping per-launch containment kills the worker; the
    watchdog fails the unresolved in-flight tickets with WorkerDied and
    restarts the worker, so the next submit is served normally."""
    fe = FaultyEngine(_engine(), FaultPlan(crash_rate=1.0, max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(6), 4)
    ref = fe.inner.execute(plan_queries(obbs))[0]
    with RequestBatcher(fe, max_wait_ms=1.0) as b:
        with pytest.raises(WorkerDied, match="watchdog"):
            b.submit(obbs).result(timeout=120)
        v, _ = b.submit(obbs).result(timeout=120)   # restarted worker
        assert (v == ref).all()
        assert b.totals.worker_restarts == 1


# ---------------------------------------------------------------------------
# Ticket semantics + close() stranding (satellites)
# ---------------------------------------------------------------------------

def test_ticket_state_and_recallable_result():
    """Ticket state distinguishes queued / launched / done, the timeout
    error names the state, and ``result`` is safely re-callable."""
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=0.6,
                                           max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(7), 4)
    with RequestBatcher(fe, max_wait_ms=1.0) as b:
        t1 = b.submit(obbs)                  # will ride the stalled launch
        time.sleep(0.15)
        assert t1.state == "launched"
        t2 = b.submit(obbs)                  # queued behind the stall
        assert t2.state == "queued"
        with pytest.raises(TimeoutError, match="queued"):
            t2.result(timeout=0.01)
        with pytest.raises(TimeoutError, match="launched"):
            t1.result(timeout=0.01)
        v1, _ = t1.result(timeout=120)       # re-call after timeout works
        v2, _ = t2.result(timeout=120)
        assert t1.state == "done" and t2.state == "done"
        assert (v1 == v2).all()
        v1b, _ = t1.result(timeout=0.01)     # done: instant, repeatable
        assert (v1b == v1).all()


def test_close_fails_stranded_requests_typed():
    """Requests still queued when the batcher stops (stuck worker) resolve
    promptly with BatcherClosed — no ticket is silently dropped — and
    submit after close raises the same type."""
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=1.5,
                                           max_faults=1))
    obbs = random_obbs(jax.random.PRNGKey(8), 4)
    b = RequestBatcher(fe, max_wait_ms=1.0)
    b.submit(obbs)                           # occupies the worker (stall)
    time.sleep(0.15)
    stranded = [b.submit(obbs) for _ in range(3)]
    b.close(timeout=0.2)                     # worker still inside the stall
    for t in stranded:
        with pytest.raises(BatcherClosed):
            t.result(timeout=5)
    with pytest.raises(BatcherClosed):
        b.submit(obbs)


def test_close_launches_already_queued_work():
    """The graceful path: close() after the worker drains lets queued
    requests complete rather than failing them."""
    eng = _engine()
    obbs = random_obbs(jax.random.PRNGKey(9), 4)
    ref = eng.execute(plan_queries(obbs))[0]
    b = RequestBatcher(eng, max_wait_ms=1.0)
    t = b.submit(obbs)
    b.close()
    v, _ = t.result(timeout=120)
    assert (v == ref).all()


# ---------------------------------------------------------------------------
# Chaos end-to-end: the serve harness under a full FaultPlan
# ---------------------------------------------------------------------------

def test_chaos_service_no_hangs_no_drops_and_graceful_slos():
    """`run_service` under every §7 failure mode at once: all submits
    resolve (the harness asserts completed + failed == submitted), the
    reliability counters flow into the report, and healthy-request p99
    degrades gracefully (within 2x of the no-chaos run, plus a scheduling
    floor for this 1-core container)."""
    from repro.launch.serve import RELIABILITY_METRICS, run_service
    tree = _tree(10, n=1500)
    clean = run_service(tree, clients=3, requests=8, queries_per_request=4,
                        max_wait_ms=5.0, mode="wavefront_fused", seed=0)
    chaos = FaultPlan(malformed_rate=0.15, exception_rate=0.12,
                      oom_rate=0.1, stall_rate=0.06, crash_rate=0.04,
                      stall_s=0.6, seed=0)
    rep = run_service(tree, clients=3, requests=8, queries_per_request=4,
                      max_wait_ms=5.0, mode="wavefront_fused", seed=0,
                      deadline_ms=5000.0, launch_timeout_s=0.25,
                      chaos=chaos)
    assert rep["submitted"] == 24
    assert rep["requests"] + rep["failed"] == rep["submitted"]
    assert rep["failed"] > 0, "chaos rates injected nothing"
    for metric in RELIABILITY_METRICS:
        assert metric in rep
    assert rep["rejected"] >= 1          # malformed requests were shed
    assert rep["requests"] > 0           # healthy requests still complete
    assert rep["p99_ms"] <= 2 * clean["p99_ms"] + 300.0, \
        (rep["p99_ms"], clean["p99_ms"])


def test_chaos_sharded_engine_on_eight_devices():
    """The fault-injection stack over a shard_map engine: chaos containment
    must not depend on single-device execution."""
    out = run_devices("""
    from repro.core.octree import build_octree
    from repro.engine.faults import FaultPlan
    from repro.launch.serve import run_service

    rs = np.random.RandomState(0)
    tree = build_octree(rs.uniform(-1, 1, (1500, 3)).astype(np.float32),
                        depth=3)
    chaos = FaultPlan(malformed_rate=0.1, exception_rate=0.1, oom_rate=0.1,
                      seed=0)
    rep = run_service(tree, clients=2, requests=4, queries_per_request=4,
                      max_wait_ms=5.0, mode="wavefront_fused", shards=8,
                      deadline_ms=10000.0, chaos=chaos)
    assert rep["requests"] + rep["failed"] == rep["submitted"] == 8
    print("CHAOS_SHARDED_OK", rep["requests"], rep["failed"])
    """)
    assert "CHAOS_SHARDED_OK" in out


def test_failure_modes_tuple_is_canonical():
    assert len(set(FAILURE_MODES)) == len(FAILURE_MODES)
    for m in ("malformed_plan", "engine_exception", "worker_death",
              "overload", "deadline_miss", "device_loss"):
        assert m in FAILURE_MODES


# ---------------------------------------------------------------------------
# Device loss: re-shard recovery (service v2 tentpole)
# ---------------------------------------------------------------------------

def test_device_loss_reshard_bitwise_identical_on_eight_devices():
    """Losing 3 of 8 shard devices mid-launch re-shards the flat pool over
    the 5 survivors and relaunches; the verdict AND every counter except
    padding/wall/recovery bookkeeping are bitwise-identical to the healthy
    8-shard run (the ANY-shard-count invariant is what makes recovery
    safe), and the engine stays pinned to the surviving mesh."""
    out = run_devices("""
    import dataclasses
    from repro.core.geometry import random_obbs
    from repro.core.octree import build_octree
    from repro.engine.executor import CollisionEngine, EngineConfig
    from repro.engine.faults import SimulatedDeviceLoss
    from repro.engine.plan import plan_queries

    rs = np.random.RandomState(0)
    tree = build_octree(rs.uniform(-1, 1, (2000, 3)).astype(np.float32),
                        depth=3)
    obbs = random_obbs(jax.random.PRNGKey(1), 37)   # uneven: forces pad
    plan = plan_queries(obbs)
    cfg = dict(mode="wavefront_fused", frontier_capacity=4096)
    v_ref, c_ref = CollisionEngine(
        tree, EngineConfig(**cfg, shards=8)).execute(plan)

    eng = CollisionEngine(tree, EngineConfig(**cfg, shards=8))
    fired = []
    def lose_three_once(shards):
        if not fired:
            fired.append(shards)
            raise SimulatedDeviceLoss(3, shards)
    eng.device_fault_injector = lose_three_once
    v, c = eng.execute(plan)
    assert fired == [8]
    assert (np.asarray(v) == np.asarray(v_ref)).all()
    assert c.reshards == 1 and c.shards_lost == 3
    assert eng.active_shards == 5          # sticky surviving mesh
    d0, d1 = c_ref.as_dict(), c.as_dict()
    for k in d0:
        if k in ("wall_time_s", "pad_queries", "reshards", "shards_lost"):
            continue
        assert np.all(np.asarray(d0[k]) == np.asarray(d1[k])), \\
            (k, d0[k], d1[k])
    # ... and the relaunch really ran 5-wide: a clean 5-shard engine
    # produces the identical verdict.
    v5, _ = CollisionEngine(
        tree, EngineConfig(**cfg, shards=5)).execute(plan)
    assert (np.asarray(v) == np.asarray(v5)).all()
    # Next launch reuses the surviving mesh without another reshard.
    v2, c2 = eng.execute(plan)
    assert (np.asarray(v2) == np.asarray(v_ref)).all()
    assert c2.reshards == 0 and c2.shards_lost == 0
    print("RESHARD_BITWISE_OK")
    """)
    assert "RESHARD_BITWISE_OK" in out


def test_device_loss_no_survivors_fails_typed_never_bisected():
    """A mesh that loses its LAST device cannot recover: the batch fails
    with the typed DeviceLost — bisect-retry must not kick in (splitting
    cannot cure a dead mesh, it would just burn retries)."""
    eng = _engine(shards=1)

    def lose_all(shards):
        raise SimulatedDeviceLoss(shards, shards)
    eng.device_fault_injector = lose_all
    obbs = random_obbs(jax.random.PRNGKey(11), 4)
    with RequestBatcher(eng, max_wait_ms=1.0, max_retries=2) as b:
        t1 = b.submit(obbs)
        t2 = b.submit(obbs)
        for t in (t1, t2):
            with pytest.raises(DeviceLost, match="no surviv"):
                t.result(timeout=120)
    assert b.totals.launch_splits == 0
    assert b.totals.retried == 0


def test_chaos_device_loss_recovery_on_eight_devices():
    """run_service under deterministic device loss (8 -> 5 -> 2 shard
    devices): recovery happens BELOW the batcher, so every request still
    completes — no typed failures, no hangs — and the recovery counters
    flow into the report."""
    out = run_devices("""
    from repro.core.octree import build_octree
    from repro.engine.faults import FaultPlan
    from repro.launch.serve import run_service

    rs = np.random.RandomState(0)
    tree = build_octree(rs.uniform(-1, 1, (1500, 3)).astype(np.float32),
                        depth=3)
    chaos = FaultPlan(device_loss_rate=1.0, devices_lost=3, max_faults=2,
                      seed=0)
    rep = run_service(tree, clients=2, requests=4, queries_per_request=4,
                      max_wait_ms=5.0, mode="wavefront_fused", shards=8,
                      deadline_ms=30000.0, chaos=chaos)
    assert rep["requests"] == rep["submitted"] == 8, rep["failures"]
    assert rep["failed"] == 0
    assert rep["reshards"] == 2, rep["reshards"]
    assert rep["shards_lost"] == 6, rep["shards_lost"]
    print("DEVICE_LOSS_RECOVERY_OK")
    """)
    assert "DEVICE_LOSS_RECOVERY_OK" in out


# ---------------------------------------------------------------------------
# Work-based admission, degraded mode, per-bucket exec-EWMA (service v2)
# ---------------------------------------------------------------------------

class _ProxyEngine:
    """Forwarding wrapper: subclasses override execute."""

    def __init__(self, inner):
        self.inner = inner

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_work_based_admission_sheds_on_predicted_work():
    """max_queue_work sheds by predicted work (scene nodes x queries), not
    request count: a backlog under the request cap but over the work cap
    rejects typed, while an oversized request with an EMPTY queue still
    admits (it launches alone, like an over-max_batch request)."""
    fe = FaultyEngine(_engine(), FaultPlan(stall_rate=1.0, stall_s=0.5,
                                           max_faults=1))
    nodes = fe.scene_nodes
    assert nodes > 1
    with RequestBatcher(fe, max_wait_ms=1.0,
                        max_queue_work=8 * nodes) as b:
        t1 = b.submit(random_obbs(jax.random.PRNGKey(20), 4))
        time.sleep(0.1)                      # worker busy inside the stall
        t2 = b.submit(random_obbs(jax.random.PRNGKey(21), 4))  # 4n queued
        with pytest.raises(Overloaded, match="work"):
            b.submit(random_obbs(jax.random.PRNGKey(22), 6))   # 10n > 8n
        assert b.totals.rejected == 1
        t1.result(timeout=120)
        t2.result(timeout=120)
    with RequestBatcher(_engine(), max_wait_ms=1.0, max_queue_work=1) as b:
        v, _ = b.submit(random_obbs(jax.random.PRNGKey(23), 4)).result(
            timeout=120)
        assert v.shape == (4,)


def test_degraded_mode_flagged_and_conservative_superset():
    """Past degrade_queue the batcher serves depth-capped launches instead
    of shedding: responses carry degraded=True, the counter ticks, and
    every degraded verdict is a conservative SUPERSET of the exact one
    (false positives at cap-cell granularity, never a missed collision)."""
    inner = _engine(40)

    class _SlowFirst(_ProxyEngine):
        calls = 0

        def execute(self, plan, max_depth=None):
            type(self).calls += 1
            if type(self).calls == 1:
                time.sleep(0.3)              # builds a queue behind launch 1
            return self.inner.execute(plan, max_depth=max_depth)

    reqs = [random_obbs(jax.random.PRNGKey(41 + i), 5) for i in range(5)]
    refs = [inner.execute(plan_queries(o))[0] for o in reqs]
    with RequestBatcher(_SlowFirst(inner), max_wait_ms=1.0,
                        degrade_queue=1) as b:
        t0 = b.submit(reqs[0])
        time.sleep(0.05)
        later = [b.submit(o) for o in reqs[1:]]
        results = [t0.result(timeout=120)]
        results += [t.result(timeout=120) for t in later]
    assert b.totals.degraded_launches >= 1
    assert any(st.degraded for _, st in results)
    for (v, st), ref in zip(results, refs):
        v = np.asarray(v)
        assert not (np.asarray(ref) & ~v).any(), \
            "degraded verdict missed a true collision"
        if not st.degraded:
            assert (v == np.asarray(ref)).all()


def test_per_bucket_ewma_no_spurious_deadline():
    """Regression for the v1 global exec-EWMA: after slow WIDE launches, a
    small request with a modest deadline must not be shed — the estimate
    for its own pad bucket (unseen -> work-rate fallback) is far under the
    deadline even though the global average would blow it."""
    inner = _engine(50)

    class _Proportional(_ProxyEngine):
        def execute(self, plan, max_depth=None):
            time.sleep(plan.num_queries * 2e-4)   # 1024-wide ~= 200 ms
            return self.inner.execute(plan, max_depth=max_depth)

    big = random_obbs(jax.random.PRNGKey(51), 1000)
    small = random_obbs(jax.random.PRNGKey(52), 8)
    with RequestBatcher(_Proportional(inner), max_batch=2048,
                        max_wait_ms=1.0) as b:
        for _ in range(2):                   # seed the 1024-bucket EWMA
            b.submit(big).result(timeout=120)
        assert b._exec_ewma[_pad_bucket(1000)] > 0.15
        v, st = b.submit(small, deadline_ms=150.0).result(timeout=120)
    assert v.shape == (8,)
    assert b.totals.deadline_missed == 0
    assert b._exec_ewma[_pad_bucket(8)] < 0.1   # per-bucket, not global


def test_chaos_streamed_quantized_scene_no_hangs():
    """Satellite: chaos over a persistent-megakernel engine with a
    STREAMED quantized (bf16 and u8) scene — the §7 contract (every submit
    resolves typed or completes, survivors bitwise-exact, p99 within 2x of
    clean plus a scheduling floor) must hold on the bandwidth-optimized
    path too, not just the fp32 resident one."""
    for fmt in ("bf16", "u8"):
        tree = _tree(60)
        inner = CollisionEngine(tree, EngineConfig(
            mode="wavefront_persistent", stream_meta=True, meta_format=fmt))
        reqs = [random_obbs(jax.random.PRNGKey(61 + i), 3 + i % 5)
                for i in range(12)]
        refs = [inner.execute(plan_queries(o))[0] for o in reqs]
        # Warm the pad-bucket width every launch hits (sum of live
        # queries stays under the floor-64 bucket, and the fault mix
        # below never changes the width: exceptions bisect — sub-batches
        # re-pad to the same bucket — and stalls only add latency), so
        # neither pass pays a persistent-kernel compile inside its
        # latency numbers.
        inner.execute(plan_queries(random_obbs(jax.random.PRNGKey(73), 64)))

        def drive(engine, deadline_ms=30000.0, timeout_s=None):
            lat, n_ok, n_failed = [], 0, 0
            with RequestBatcher(engine, max_wait_ms=1.0, max_retries=2,
                                retry_backoff_ms=0.1,
                                launch_timeout_s=timeout_s) as b:
                tickets = [b.submit(plan_queries(o),
                                    deadline_ms=deadline_ms)
                           for o in reqs]
                for i, t in enumerate(tickets):
                    try:
                        v, st = t.result(timeout=120)
                    except (SimulatedOOM, InjectedFault, LaunchStalled,
                            DeadlineExceeded):
                        n_failed += 1
                        continue
                    n_ok += 1
                    lat.append(st.total_s)
                    assert (np.asarray(v)
                            == np.asarray(refs[i])).all(), (fmt, i)
            return lat, n_ok, n_failed

        clean_lat, clean_ok, _ = drive(inner)
        assert clean_ok == len(reqs)
        fe = FaultyEngine(inner, FaultPlan(exception_rate=0.2,
                                           stall_rate=0.1,
                                           stall_s=0.4, seed=2))
        lat, n_ok, n_failed = drive(fe, timeout_s=2.0)
        assert sum(fe.injected.values()) > 0, "chaos injected nothing"
        assert n_ok + n_failed == len(reqs), \
            f"{fmt}: a ticket hung or vanished"
        assert n_ok > 0, fmt
        p99 = float(np.percentile(np.asarray(lat), 99))
        clean_p99 = float(np.percentile(np.asarray(clean_lat), 99))
        # 2x clean plus the injected-stall and bisect-serialisation
        # allowance this 1-core container needs.
        assert p99 <= 2 * clean_p99 + 2 * 0.4 + 1.0, \
            (fmt, p99, clean_p99)
