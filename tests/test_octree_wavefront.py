"""Octree build invariants + engine-variant equivalence to the naive arm."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.geometry import OBBs, random_obbs
from repro.core.octree import build_octree, morton_decode, morton_encode
from repro.core.wavefront import (MODES, CollisionEngine, EngineConfig,
                                  query_batched_scenes)
from repro.data.robotics import make_scene, scene_trajectories


def test_morton_roundtrip():
    rs = np.random.RandomState(0)
    xyz = rs.randint(0, 1 << 10, (1000, 3)).astype(np.uint32)
    codes = morton_encode(xyz[:, 0], xyz[:, 1], xyz[:, 2])
    x, y, z = morton_decode(codes)
    assert (x == xyz[:, 0]).all() and (y == xyz[:, 1]).all() \
        and (z == xyz[:, 2]).all()


def test_octree_levels_consistent():
    rs = np.random.RandomState(1)
    pts = rs.uniform(-1, 1, (5000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=5)
    # every point falls inside some leaf AABB
    leaves = tree.leaf_aabbs()
    lo = np.asarray(leaves.center) - np.asarray(leaves.half)
    hi = np.asarray(leaves.center) + np.asarray(leaves.half)
    eps = 1e-5
    for p in pts[::97]:
        inside = ((p >= lo - eps) & (p <= hi + eps)).all(-1).any()
        assert inside
    # parent of every occupied node exists at the previous level
    for l in range(1, tree.depth + 1):
        parents = set((tree.levels[l].codes >> np.uint32(3)).tolist())
        assert parents <= set(tree.levels[l - 1].codes.tolist())
    # point ranges partition the cloud
    assert tree.leaf_point_count.sum() == len(pts)


def test_full_flags():
    # a solid dense block of points -> interior nodes become full
    g = np.stack(np.meshgrid(*[np.linspace(0.01, 0.99, 64)] * 3,
                             indexing="ij"), -1).reshape(-1, 3)
    tree = build_octree(g.astype(np.float32), depth=4,
                        scene_lo=np.zeros(3, np.float32), scene_size=1.0)
    # at depth 4 every cell holds points -> every level is fully occupied
    assert tree.levels[0].full.all()
    assert all(l.full.all() for l in tree.levels)


@pytest.mark.parametrize("mode", [m for m in MODES if m != "naive"])
def test_engine_matches_naive(mode):
    rs = np.random.RandomState(2)
    pts = rs.uniform(-1, 1, (8000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(3), 40)
    ref, _ = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    got, c = CollisionEngine(tree, EngineConfig(mode=mode)).query(obbs)
    assert (got == ref).all()
    assert c.frontier_overflow == 0


def test_engine_spheres_ablation_matches():
    rs = np.random.RandomState(4)
    pts = rs.uniform(-1, 1, (6000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(5), 30)
    a, ca = CollisionEngine(tree, EngineConfig(
        mode="wavefront", use_spheres=False)).query(obbs)
    b, cb = CollisionEngine(tree, EngineConfig(
        mode="wavefront", use_spheres=True)).query(obbs)
    assert (a == b).all()
    assert cb.sphere_tests > 0
    assert cb.axis_tests_executed <= ca.axis_tests_executed


def test_work_model_orderings():
    """Tree < naive in tests; early-exit executes fewer axis tests."""
    rs = np.random.RandomState(6)
    pts = rs.uniform(-1, 1, (8000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(7), 32)
    _, c_naive = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    _, c_tta = CollisionEngine(tree, EngineConfig(
        mode="staged_noexit")).query(obbs)
    _, c_wf = CollisionEngine(tree, EngineConfig(mode="wavefront")).query(obbs)
    assert c_tta.nodes_traversed < c_naive.nodes_traversed
    assert c_wf.axis_tests_executed <= c_tta.axis_tests_executed
    assert c_wf.axis_tests_executed < c_wf.axis_tests_decoded
    # fused bytes model < unfused
    _, c_fu = CollisionEngine(tree, EngineConfig(
        mode="wavefront_fused")).query(obbs)
    assert c_fu.bytes_moved < c_wf.bytes_moved


def test_device_engine_matches_host_bitwise():
    """Device-resident while_loop traversal == legacy host-loop engine,
    verdicts AND work counters, on the seed test scenes."""
    for seed, n_pts, depth, n_obb in [(2, 8000, 4, 40), (8, 5000, 5, 24)]:
        rs = np.random.RandomState(seed)
        pts = rs.uniform(-1, 1, (n_pts, 3)).astype(np.float32)
        tree = build_octree(pts, depth=depth)
        obbs = random_obbs(jax.random.PRNGKey(seed), n_obb)
        host, ch = CollisionEngine(
            tree, EngineConfig(mode="wavefront_host")).query(obbs)
        dev, cd = CollisionEngine(
            tree, EngineConfig(mode="wavefront")).query(obbs)
        assert (dev == host).all()
        assert cd.nodes_traversed == ch.nodes_traversed
        assert cd.axis_tests_executed == ch.axis_tests_executed
        assert cd.leaf_tests == ch.leaf_tests
        assert cd.nodes_per_level == ch.nodes_per_level
        assert (cd.exit_histogram == ch.exit_histogram).all()
        assert cd.frontier_overflow == 0


def _as_batch(obbs: OBBs, b: int) -> OBBs:
    m = obbs.n // b
    return OBBs(center=obbs.center.reshape(b, m, 3),
                half=obbs.half.reshape(b, m, 3),
                rot=obbs.rot.reshape(b, m, 3, 3))


def test_query_batched_matches_per_set_queries():
    rs = np.random.RandomState(3)
    pts = rs.uniform(-1, 1, (6000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(4), 48)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront"))
    batch = _as_batch(obbs, 6)                       # (6, 8) query sets
    got, c = eng.query_batched(batch)
    assert got.shape == (6, 8)
    flat, _ = eng.query(obbs)
    assert (got.reshape(-1) == flat).all()
    assert c.num_queries == 48
    # host fallback loop agrees with the single-call device path
    host = CollisionEngine(tree, EngineConfig(mode="wavefront_host"))
    got_h, _ = host.query_batched(batch)
    assert (got_h == got).all()


def test_query_batched_scenes_single_call():
    trees, sets = [], []
    for seed in (11, 12):
        rs = np.random.RandomState(seed)
        pts = rs.uniform(-1, 1, (4000, 3)).astype(np.float32)
        trees.append(build_octree(pts, depth=4))
        sets.append(random_obbs(jax.random.PRNGKey(seed), 20))
    stack = OBBs(center=jnp.stack([o.center for o in sets]),
                 half=jnp.stack([o.half for o in sets]),
                 rot=jnp.stack([o.rot for o in sets]))
    got, c = query_batched_scenes(trees, stack)
    assert got.shape == (2, 20)
    for s in range(2):
        ref, _ = CollisionEngine(trees[s],
                                 EngineConfig(mode="naive")).query(sets[s])
        assert (got[s] == ref).all()
    assert c.num_queries == 40


def test_device_engine_capacity_escalation():
    """A deliberately tiny initial bucket must escalate, not drop work."""
    rs = np.random.RandomState(5)
    pts = rs.uniform(-1, 1, (8000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(6), 40)
    ref, _ = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    got, c = CollisionEngine(tree, EngineConfig(
        mode="wavefront", min_bucket=64)).query(obbs)
    assert (got == ref).all()
    assert c.frontier_overflow == 0


def test_scene_traversal_on_synthetic_cubby():
    scene = make_scene("cubby", num_points=30000)
    tree = build_octree(scene.points, depth=5)
    obbs = scene_trajectories(scene, num_trajectories=3, waypoints=10)
    ref, _ = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    got, c = CollisionEngine(tree, EngineConfig(mode="wavefront")).query(obbs)
    assert (got == ref).all()
    assert 0 < int(ref.sum()) < obbs.n           # some but not all collide
