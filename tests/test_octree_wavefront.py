"""Octree build invariants + engine-variant equivalence to the naive arm."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.geometry import random_obbs
from repro.core.octree import build_octree, morton_decode, morton_encode
from repro.core.wavefront import MODES, CollisionEngine, EngineConfig
from repro.data.robotics import make_scene, scene_trajectories


def test_morton_roundtrip():
    rs = np.random.RandomState(0)
    xyz = rs.randint(0, 1 << 10, (1000, 3)).astype(np.uint32)
    codes = morton_encode(xyz[:, 0], xyz[:, 1], xyz[:, 2])
    x, y, z = morton_decode(codes)
    assert (x == xyz[:, 0]).all() and (y == xyz[:, 1]).all() \
        and (z == xyz[:, 2]).all()


def test_octree_levels_consistent():
    rs = np.random.RandomState(1)
    pts = rs.uniform(-1, 1, (5000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=5)
    # every point falls inside some leaf AABB
    leaves = tree.leaf_aabbs()
    lo = np.asarray(leaves.center) - np.asarray(leaves.half)
    hi = np.asarray(leaves.center) + np.asarray(leaves.half)
    eps = 1e-5
    for p in pts[::97]:
        inside = ((p >= lo - eps) & (p <= hi + eps)).all(-1).any()
        assert inside
    # parent of every occupied node exists at the previous level
    for l in range(1, tree.depth + 1):
        parents = set((tree.levels[l].codes >> np.uint32(3)).tolist())
        assert parents <= set(tree.levels[l - 1].codes.tolist())
    # point ranges partition the cloud
    assert tree.leaf_point_count.sum() == len(pts)


def test_full_flags():
    # a solid dense block of points -> interior nodes become full
    g = np.stack(np.meshgrid(*[np.linspace(0.01, 0.99, 64)] * 3,
                             indexing="ij"), -1).reshape(-1, 3)
    tree = build_octree(g.astype(np.float32), depth=4,
                        scene_lo=np.zeros(3, np.float32), scene_size=1.0)
    # at depth 4 every cell holds points -> every level is fully occupied
    assert tree.levels[0].full.all()
    assert all(l.full.all() for l in tree.levels)


@pytest.mark.parametrize("mode", [m for m in MODES if m != "naive"])
def test_engine_matches_naive(mode):
    rs = np.random.RandomState(2)
    pts = rs.uniform(-1, 1, (8000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(3), 40)
    ref, _ = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    got, c = CollisionEngine(tree, EngineConfig(mode=mode)).query(obbs)
    assert (got == ref).all()
    assert c.frontier_overflow == 0


def test_engine_spheres_ablation_matches():
    rs = np.random.RandomState(4)
    pts = rs.uniform(-1, 1, (6000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(5), 30)
    a, ca = CollisionEngine(tree, EngineConfig(
        mode="wavefront", use_spheres=False)).query(obbs)
    b, cb = CollisionEngine(tree, EngineConfig(
        mode="wavefront", use_spheres=True)).query(obbs)
    assert (a == b).all()
    assert cb.sphere_tests > 0
    assert cb.axis_tests_executed <= ca.axis_tests_executed


def test_work_model_orderings():
    """Tree < naive in tests; early-exit executes fewer axis tests."""
    rs = np.random.RandomState(6)
    pts = rs.uniform(-1, 1, (8000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=4)
    obbs = random_obbs(jax.random.PRNGKey(7), 32)
    _, c_naive = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    _, c_tta = CollisionEngine(tree, EngineConfig(
        mode="staged_noexit")).query(obbs)
    _, c_wf = CollisionEngine(tree, EngineConfig(mode="wavefront")).query(obbs)
    assert c_tta.nodes_traversed < c_naive.nodes_traversed
    assert c_wf.axis_tests_executed <= c_tta.axis_tests_executed
    assert c_wf.axis_tests_executed < c_wf.axis_tests_decoded
    # fused bytes model < unfused
    _, c_fu = CollisionEngine(tree, EngineConfig(
        mode="wavefront_fused")).query(obbs)
    assert c_fu.bytes_moved < c_wf.bytes_moved


def test_scene_traversal_on_synthetic_cubby():
    scene = make_scene("cubby", num_points=30000)
    tree = build_octree(scene.points, depth=5)
    obbs = scene_trajectories(scene, num_trajectories=3, waypoints=10)
    ref, _ = CollisionEngine(tree, EngineConfig(mode="naive")).query(obbs)
    got, c = CollisionEngine(tree, EngineConfig(mode="wavefront")).query(obbs)
    assert (got == ref).all()
    assert 0 < int(ref.sum()) < obbs.n           # some but not all collide
