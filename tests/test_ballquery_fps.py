"""Ball query (P-Ray == P-Sphere == brute force) and FPS invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import seeded_property

from repro.core.ballquery import (ball_query_pray, ball_query_psphere,
                                  ball_query_ref)
from repro.core.fps import (farthest_point_sampling, random_sampling,
                            sampling_spread)
from repro.core.octree import build_octree


def _sets(idx, cnt):
    idx, cnt = np.asarray(idx), np.asarray(cnt)
    return [set(idx[m][:cnt[m]].tolist()) for m in range(len(cnt))]


@pytest.mark.parametrize("r,k", [(0.15, 8), (0.3, 32)])
def test_psphere_and_pray_match_bruteforce(r, k):
    rs = np.random.RandomState(0)
    pts = rs.uniform(-1, 1, (3000, 3)).astype(np.float32)
    qs = rs.uniform(-1, 1, (48, 3)).astype(np.float32)
    ref_idx, ref_cnt = ball_query_ref(jnp.asarray(pts), jnp.asarray(qs), r, k)
    tree = build_octree(pts, depth=5)
    ps_idx, ps_cnt, _ = ball_query_psphere(tree, jnp.asarray(qs), r, k)
    pr_idx, pr_cnt, _ = ball_query_pray(jnp.asarray(pts), jnp.asarray(qs), r,
                                        k, depth=3)
    ref_cnt = np.asarray(ref_cnt)
    assert (np.asarray(ps_cnt) == ref_cnt).all()
    assert (np.asarray(pr_cnt) == ref_cnt).all()
    d2 = ((qs[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    for m in range(48):
        true_set = set(np.nonzero(d2[m] <= r * r)[0].tolist())
        for got in (_sets(ps_idx, ps_cnt)[m], _sets(pr_idx, pr_cnt)[m]):
            if ref_cnt[m] < k:
                assert got == true_set
            else:
                assert got <= true_set and len(got) == k


def test_psphere_early_exit_saves_nodes_and_preserves_counts():
    rs = np.random.RandomState(1)
    pts = rs.uniform(-1, 1, (40000, 3)).astype(np.float32)
    tree = build_octree(pts, depth=6)
    qs = jnp.asarray(rs.uniform(-0.8, 0.8, (64, 3)).astype(np.float32))
    _, c_ee_cnt, c_ee = ball_query_psphere(tree, qs, 0.3, 8, early_exit=True)
    _, c_ne_cnt, c_ne = ball_query_psphere(tree, qs, 0.3, 8, early_exit=False)
    assert (np.asarray(c_ee_cnt) == np.asarray(c_ne_cnt)).all()
    assert c_ee.nodes_traversed < c_ne.nodes_traversed


def _ballquery_property(seed):
    rs = np.random.RandomState(seed % 100000)
    pts = rs.uniform(-1, 1, (500, 3)).astype(np.float32)
    qs = rs.uniform(-1, 1, (8, 3)).astype(np.float32)
    r, k = float(rs.uniform(0.05, 0.5)), int(rs.randint(1, 16))
    tree = build_octree(pts, depth=4)
    idx, cnt, _ = ball_query_psphere(tree, jnp.asarray(qs), r, k)
    idx, cnt = np.asarray(idx), np.asarray(cnt)
    d2 = ((qs[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    for m in range(8):
        sel = idx[m][:cnt[m]]
        assert (d2[m][sel] <= r * r + 1e-6).all()       # all within radius
        true_n = int((d2[m] <= r * r).sum())
        assert cnt[m] == min(true_n, k)                 # exact counts


@seeded_property(max_examples=10)
def test_ballquery_property_random(seed):
    """Hypothesis when available; deterministic fixed seeds otherwise —
    either way the property runs and the tier-1 suite reports 0 skipped."""
    _ballquery_property(seed)


def test_fps_invariants():
    rs = np.random.RandomState(2)
    pts = jnp.asarray(rs.uniform(-1, 1, (2000, 3)).astype(np.float32))
    idx = farthest_point_sampling(pts, 64)
    idx_np = np.asarray(idx)
    assert idx_np[0] == 0
    assert len(set(idx_np.tolist())) == 64              # distinct points
    # FPS spread beats random sampling (coverage metric, averaged seeds)
    fps_spread = float(sampling_spread(pts, idx))
    rnd = [float(sampling_spread(pts, random_sampling(
        jax.random.PRNGKey(s), 2000, 64))) for s in range(5)]
    assert fps_spread < np.mean(rnd)


def test_fps_matches_numpy_oracle():
    rs = np.random.RandomState(3)
    pts = rs.uniform(-1, 1, (300, 3)).astype(np.float32)
    got = np.asarray(farthest_point_sampling(jnp.asarray(pts), 20))
    dist = np.full(300, np.inf)
    idx = [0]
    for _ in range(19):
        d = ((pts - pts[idx[-1]]) ** 2).sum(-1)
        dist = np.minimum(dist, d)
        idx.append(int(dist.argmax()))
    assert (got == np.asarray(idx)).all()
