"""Collision service layer: sharded execution, continuous batching, SLOs.

Covers DESIGN.md §6: the sharded execute path must be bitwise-identical
to single-device on verdicts AND counters (in-process with shards=1 on
any backend; on 8 virtual CPU devices — including an uneven shard count
that forces padding — via the subprocess helper), the batcher must route
K coalesced requests back to K callers independent of arrival order, and
the serve harness must report the SLO quantities end to end.
"""
import threading

import numpy as np
import jax
import pytest

from test_distributed import run_devices

from repro.core.geometry import OBBs, random_obbs
from repro.core.octree import build_octree
from repro.engine.batcher import RequestBatcher, _pad_bucket
from repro.engine.executor import CollisionEngine, EngineConfig
from repro.engine.plan import (plan_edges, plan_queries, plan_scenes,
                               plan_trajectory)


def _tree(seed, n=3000, depth=4):
    rs = np.random.RandomState(seed)
    return build_octree(rs.uniform(-1, 1, (n, 3)).astype(np.float32),
                        depth=depth)


# ---------------------------------------------------------------------------
# Sharded execution
# ---------------------------------------------------------------------------

def _assert_counters_equal(c0, c1, ctx):
    d0, d1 = c0.as_dict(), c1.as_dict()
    for k in d0:
        if k in ("wall_time_s", "pad_queries"):
            continue
        assert np.all(np.asarray(d0[k]) == np.asarray(d1[k])), \
            (ctx, k, d0[k], d1[k])


@pytest.mark.parametrize("mode", ["wavefront", "wavefront_fused",
                                  "wavefront_persistent"])
def test_sharded_one_shard_matches_single_device(mode):
    """shards=1 routes the shard_map path on any backend; verdicts and
    every counter must be bitwise-identical to the unsharded engine."""
    tree = _tree(0)
    obbs = random_obbs(jax.random.PRNGKey(1), 37)
    plan = plan_queries(obbs)
    cfg = dict(mode=mode, frontier_capacity=4096)
    v0, c0 = CollisionEngine(tree, EngineConfig(**cfg)).execute(plan)
    v1, c1 = CollisionEngine(
        tree, EngineConfig(**cfg, shards=1)).execute(plan)
    assert (v0 == v1).all()
    _assert_counters_equal(c0, c1, mode)
    assert c1.pad_queries == 0


def test_sharded_eight_devices_bitwise_identical():
    """8-way sharding on 8 virtual CPU devices: even (96) and uneven (101,
    forces per-shard padding) pool sizes, verdicts AND counters."""
    out = run_devices("""
    from repro.core.geometry import random_obbs
    from repro.core.octree import build_octree
    from repro.engine.executor import CollisionEngine, EngineConfig
    from repro.engine.plan import plan_queries

    rs = np.random.RandomState(0)
    tree = build_octree(rs.uniform(-1, 1, (2000, 3)).astype(np.float32),
                        depth=3)
    cases = [("wavefront_fused", 96), ("wavefront_fused", 101),
             ("wavefront_persistent", 101)]
    for mode, Q in cases:
        obbs = random_obbs(jax.random.PRNGKey(Q), Q)
        plan = plan_queries(obbs)
        v0, c0 = CollisionEngine(tree, EngineConfig(
            mode=mode, frontier_capacity=4096)).execute(plan)
        v1, c1 = CollisionEngine(tree, EngineConfig(
            mode=mode, frontier_capacity=4096, shards=8)).execute(plan)
        assert (v0 == v1).all(), (mode, Q)
        d0, d1 = c0.as_dict(), c1.as_dict()
        for k in d0:
            if k in ("wall_time_s", "pad_queries"):
                continue
            assert np.all(np.asarray(d0[k]) == np.asarray(d1[k])), \\
                (mode, Q, k, d0[k], d1[k])
        assert c1.pad_queries == (-Q) % 8, (Q, c1.pad_queries)
        print("SHARDED_OK", mode, Q, c0.nodes_traversed)
    """)
    assert out.count("SHARDED_OK") == 3


def test_sharded_config_and_plan_validation():
    with pytest.raises(ValueError):
        EngineConfig(mode="wavefront_host", shards=2)
    with pytest.raises(ValueError):
        EngineConfig(mode="wavefront_fused", shards=0)
    tree = _tree(1, n=800, depth=3)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused",
                                             shards=1))
    obbs = random_obbs(jax.random.PRNGKey(2), 8)
    with pytest.raises(ValueError):           # owner/payload lanes
        eng.execute(plan_edges(obbs, np.zeros(8, np.int32), 1))
    batch = OBBs(center=obbs.center.reshape(2, 4, 3),
                 half=obbs.half.reshape(2, 4, 3),
                 rot=obbs.rot.reshape(2, 4, 3, 3))
    eng2 = CollisionEngine([tree, _tree(2, n=800, depth=3)],
                           EngineConfig(mode="wavefront_fused", shards=1))
    with pytest.raises(ValueError):           # multi-scene pool
        eng2.execute(plan_scenes(batch))


def test_collision_mesh_validation():
    from repro.parallel.sharding import make_collision_mesh
    with pytest.raises(ValueError):
        make_collision_mesh(0)
    with pytest.raises(ValueError):
        make_collision_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

def test_batcher_routes_k_requests_order_independent():
    """K concurrent requests of mixed sizes coalesce into fewer launches
    and every caller gets exactly its own verdicts back."""
    tree = _tree(3)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    K = 10
    reqs = [random_obbs(jax.random.PRNGKey(i), 3 + (7 * i) % 11)
            for i in range(K)]
    refs = [eng.execute(plan_queries(o))[0] for o in reqs]

    with RequestBatcher(eng, max_batch=4096, max_wait_ms=250.0) as b:
        tickets = [None] * K

        def submit(i):
            tickets[i] = b.submit(reqs[i])

        # submit from K threads in no particular order
        threads = [threading.Thread(target=submit, args=(i,))
                   for i in reversed(range(K))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        results = [tickets[i].result(timeout=120) for i in range(K)]
        launches = b.num_launches
    for i, (verdict, stats) in enumerate(results):
        assert verdict.shape == (reqs[i].n,)
        assert (verdict == refs[i]).all(), i
        assert stats.total_s >= stats.exec_s >= 0
        assert stats.wait_s >= 0
        assert 1 <= stats.batch_requests <= K
    assert launches < K, "requests did not coalesce"


def test_batcher_mixed_workload_kinds_share_a_launch():
    """A trajectory plan and a flat query plan coalesce into one pool and
    each un-flattens through its own recipe."""
    tree = _tree(4)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    rs = np.random.RandomState(5)
    wps = rs.uniform(-1, 1, (4, 7)).astype(np.float32)
    traj = plan_trajectory(wps)
    obbs = random_obbs(jax.random.PRNGKey(6), 9)
    ref_traj = eng.execute(traj)[0]
    ref_q = eng.execute(plan_queries(obbs))[0]
    with RequestBatcher(eng, max_batch=4096, max_wait_ms=250.0) as b:
        t1 = b.submit(traj)
        t2 = b.submit(obbs)                  # OBBs shorthand
        v1, s1 = t1.result(timeout=120)
        v2, s2 = t2.result(timeout=120)
    assert v1.shape == (4,) and (v1 == ref_traj).all()
    assert (v2 == ref_q).all()
    if s1.batch_requests == 2:               # coalesced (timing-dependent)
        assert s1.batch_queries == traj.num_queries + obbs.n
        assert s1.pad_queries == _pad_bucket(s1.batch_queries) \
            - s1.batch_queries


def test_batcher_pad_accounting_and_rejections():
    tree = _tree(7, n=800, depth=3)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    obbs = random_obbs(jax.random.PRNGKey(8), 5)
    with RequestBatcher(eng, max_batch=64, max_wait_ms=1.0) as b:
        _, stats = b.submit(obbs).result(timeout=120)
        with pytest.raises(ValueError):      # grouped plan
            b.submit(plan_edges(obbs, np.zeros(5, np.int32), 1))
    assert stats.pad_queries == _pad_bucket(5) - 5
    assert b.totals.pad_queries >= stats.pad_queries
    assert b.totals.num_queries >= 5
    with pytest.raises(RuntimeError):        # closed
        b.submit(obbs)


# ---------------------------------------------------------------------------
# Serve harness
# ---------------------------------------------------------------------------

def test_run_service_reports_slos():
    from repro.launch.serve import SLO_METRICS, run_service
    tree = _tree(9, n=1500, depth=3)
    rep = run_service(tree, clients=2, requests=3, queries_per_request=4,
                      max_wait_ms=5.0, mode="wavefront_fused", seed=0)
    for metric in SLO_METRICS:
        assert rep[metric] > 0, metric
    assert rep["requests"] == 6 and rep["queries"] == 24
    assert rep["launches"] >= 1
    assert rep["p99_ms"] >= rep["p50_ms"]
    assert rep["counters"].num_queries >= 24


def test_engine_exports_typed_service_errors():
    """Satellite: clients catch service errors from ``repro.engine``
    without reaching into batcher internals."""
    import repro.engine as E
    for name in ("ServiceError", "Overloaded", "DeadlineExceeded",
                 "LaunchStalled", "WorkerDied", "BatcherClosed",
                 "DeviceLost", "RequestBatcher", "RequestStats",
                 "DEPTH_CAP_MODES"):
        assert name in E.__all__ and hasattr(E, name), name
    for err in (E.Overloaded, E.DeadlineExceeded, E.LaunchStalled,
                E.WorkerDied, E.BatcherClosed, E.DeviceLost):
        assert issubclass(err, E.ServiceError)
    assert not issubclass(E.ServiceError, ValueError)


# ---------------------------------------------------------------------------
# Depth-capped traversal (degraded mode substrate)
# ---------------------------------------------------------------------------

def test_depth_cap_conservative_superset_and_mode_agreement():
    """execute(max_depth=k) treats level-k cells as terminal: verdicts are
    a conservative SUPERSET of the exact ones (never a missed collision),
    identical across every DEPTH_CAP_MODES member, and full-depth
    max_depth is a no-op."""
    from repro.engine.executor import DEPTH_CAP_MODES
    tree = _tree(11)
    obbs = random_obbs(jax.random.PRNGKey(12), 64)
    plan = plan_queries(obbs)
    exact = np.asarray(CollisionEngine(
        tree, EngineConfig(mode="wavefront_fused")).execute(plan)[0])
    for k in (1, 2, tree.depth):
        capped = {}
        for mode in DEPTH_CAP_MODES:
            eng = CollisionEngine(tree, EngineConfig(mode=mode))
            assert eng.supports_depth_cap
            v, _ = eng.execute(plan, max_depth=k)
            capped[mode] = np.asarray(v)
            assert not (exact & ~capped[mode]).any(), (mode, k)
        ref = capped[DEPTH_CAP_MODES[0]]
        for mode, v in capped.items():
            assert (v == ref).all(), (mode, k)
        if k == tree.depth:
            assert (ref == exact).all()
    # Sharded capped equals single-device capped (shards=1 in-process).
    v1, _ = CollisionEngine(tree, EngineConfig(
        mode="wavefront_fused", shards=1)).execute(plan, max_depth=2)
    v0, _ = CollisionEngine(tree, EngineConfig(
        mode="wavefront_fused")).execute(plan, max_depth=2)
    assert (np.asarray(v1) == np.asarray(v0)).all()


def test_depth_cap_rejected_where_unsupported():
    tree = _tree(13, n=800, depth=3)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_persistent"))
    assert not eng.supports_depth_cap
    obbs = random_obbs(jax.random.PRNGKey(14), 4)
    with pytest.raises(ValueError, match="max_depth"):
        eng.execute(plan_queries(obbs), max_depth=1)
    eng2 = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    with pytest.raises(ValueError):
        eng2.execute(plan_queries(obbs), max_depth=0)


# ---------------------------------------------------------------------------
# Live rebind + elastic shard width (service v2)
# ---------------------------------------------------------------------------

def test_rebind_under_live_batcher_streaming_clients():
    """Satellite regression: swapping the bound scene while clients stream
    requests is safe — every verdict matches the request's queries against
    scene A or scene B (never a torn mix), rebind() is FIFO with the
    requests around it, and submits after it see scene B exactly."""
    tree_a = _tree(15, n=1200, depth=3)
    tree_b = _tree(16, n=1200, depth=3)
    cfg = EngineConfig(mode="wavefront_fused")
    ref_a = CollisionEngine(tree_a, cfg)
    ref_b = CollisionEngine(tree_b, cfg)
    n_clients, n_reqs = 3, 8
    reqs = [[random_obbs(jax.random.PRNGKey(100 * ci + ri), 4 + ri % 3)
             for ri in range(n_reqs)] for ci in range(n_clients)]
    refs = [[(np.asarray(ref_a.execute(plan_queries(o))[0]),
              np.asarray(ref_b.execute(plan_queries(o))[0]))
             for o in per_client] for per_client in reqs]

    live = CollisionEngine(tree_a, cfg)
    results = [[None] * n_reqs for _ in range(n_clients)]
    errors = []

    with RequestBatcher(live, max_wait_ms=1.0) as b:
        def client(ci):
            try:
                for ri in range(n_reqs):
                    v, _ = b.submit(reqs[ci][ri]).result(timeout=120)
                    results[ci][ri] = np.asarray(v)
            except BaseException as e:        # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(n_clients)]
        for t in threads:
            t.start()
        b.rebind(tree_b)                      # mid-stream, worker-routed
        probe = random_obbs(jax.random.PRNGKey(999), 6)
        v_after, _ = b.submit(probe).result(timeout=120)
        for t in threads:
            t.join()
    assert not errors, errors
    assert (np.asarray(v_after)
            == np.asarray(ref_b.execute(plan_queries(probe))[0])).all()
    for ci in range(n_clients):
        for ri in range(n_reqs):
            v = results[ci][ri]
            va, vb = refs[ci][ri]
            assert (v == va).all() or (v == vb).all(), (ci, ri)


def test_autoscale_widens_shards_under_load_on_eight_devices():
    """The elastic batcher scales EngineConfig.shards up between launches
    when p99 drifts past the SLO, and verdicts stay bitwise-correct
    across the rescale."""
    out = run_devices("""
    from repro.core.geometry import random_obbs
    from repro.core.octree import build_octree
    from repro.engine.batcher import RequestBatcher
    from repro.engine.executor import CollisionEngine, EngineConfig
    from repro.engine.plan import plan_queries

    rs = np.random.RandomState(0)
    tree = build_octree(rs.uniform(-1, 1, (1500, 3)).astype(np.float32),
                        depth=3)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused",
                                             shards=1))
    reqs = [random_obbs(jax.random.PRNGKey(i), 5 + i % 7)
            for i in range(14)]
    refs = [np.asarray(eng.execute(plan_queries(o))[0]) for o in reqs]
    with RequestBatcher(eng, max_wait_ms=1.0, autoscale_shards=True,
                        target_p99_ms=0.01) as b:   # unmeetable SLO
        for o, ref in zip(reqs, refs):
            v, _ = b.submit(o).result(timeout=120)
            assert (np.asarray(v) == ref).all()
    assert b.totals.shard_rescales >= 1, b.totals.shard_rescales
    assert eng.cfg.shards > 1, eng.cfg.shards
    print("AUTOSCALE_OK", eng.cfg.shards, b.totals.shard_rescales)
    """)
    assert "AUTOSCALE_OK" in out


def test_run_service_sharded_on_eight_devices():
    """The full service stack (shard_map engine under the batcher under
    concurrent clients) on 8 virtual devices."""
    out = run_devices("""
    from repro.core.octree import build_octree
    from repro.launch.serve import run_service

    rs = np.random.RandomState(0)
    tree = build_octree(rs.uniform(-1, 1, (1500, 3)).astype(np.float32),
                        depth=3)
    rep = run_service(tree, clients=2, requests=2, queries_per_request=4,
                      max_wait_ms=5.0, mode="wavefront_fused", shards=8)
    assert rep["requests"] == 4 and rep["qps"] > 0
    print("SERVE_SHARDED_OK", round(rep["p50_ms"], 3))
    """)
    assert "SERVE_SHARDED_OK" in out
