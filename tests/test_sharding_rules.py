"""Regression guards for the sharding rules discovered in §Perf."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import api
from repro.parallel import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh is fine: rules depend on axis names/sizes only via
    # divisibility, which we pin with the real 16x16 shape below.
    return jax.make_mesh((1, 1), ("data", "model"))


def _prod_mesh():
    # shape-only stand-in for the production mesh (no devices needed for
    # divisibility logic: use axis sizes via a fake mesh dict)
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
        devices = np.empty((16, 16), dtype=object)
    return FakeMesh()


def test_param_specs_basic_rules():
    cfg = get_config("glm4_9b")
    aparams = api.abstract_params(cfg)
    mesh = _prod_mesh()
    specs = shd.param_pspecs(cfg, aparams, mesh)
    blocks = specs["blocks"]
    # FFN: (L, d, f) -> (None, data, model); down: (L, f, d) -> (None, model, data)
    assert tuple(blocks["ffn"]["w_gate"]) == (None, "data", "model")
    assert tuple(blocks["ffn"]["w_down"]) == (None, "model", "data")
    # GQA kv (2 heads < 16) stays replicated over model
    assert tuple(blocks["attn"]["wk"])[2] is None
    # q heads divisible -> TP
    assert tuple(blocks["attn"]["wq"])[2] == "model"


def test_vocab_not_sharded_when_indivisible():
    cfg = get_config("granite_moe_1b_a400m")     # vocab 49155, odd
    aparams = api.abstract_params(cfg)
    specs = shd.param_pspecs(cfg, aparams, _prod_mesh())
    assert tuple(specs["embed"])[0] is None      # 49155 % 16 != 0
    cfg2 = get_config("glm4_9b")                 # vocab 151552 divisible
    specs2 = shd.param_pspecs(cfg2, api.abstract_params(cfg2), _prod_mesh())
    assert tuple(specs2["embed"])[0] == "model"


def test_use_specs_exclude_moe_experts():
    """§Perf P3: expert-tensor gather hints get hoisted by XLA and
    materialize the gathered expert stack — they must be 'skip'."""
    cfg = get_config("arctic_480b")
    aparams = api.abstract_params(cfg)
    us = shd.use_pspecs(cfg, aparams, _prod_mesh())
    assert us["blocks"]["ffn"]["w_gate"] == "skip"
    assert us["blocks"]["ffn"]["w_down"] == "skip"
    # dense-residual branch and attention still get gather hints
    assert tuple(us["blocks"]["ffn"]["dense"]["w_gate"]) == (None, "model")
    assert tuple(us["blocks"]["attn"]["wk"]) == (None, None, None)


def test_use_specs_strip_fsdp_keep_tp():
    cfg = get_config("glm4_9b")
    us = shd.use_pspecs(cfg, api.abstract_params(cfg), _prod_mesh())
    # stacked layer dim dropped; FSDP axis stripped; TP kept
    assert tuple(us["blocks"]["ffn"]["w_gate"]) == (None, "model")
    assert tuple(us["lm_head"]) == (None, "model")


def test_shard_hint_spec_skip_sentinel():
    from repro.models.common import shard_hint_spec
    x = jax.numpy.ones((4, 4))
    assert shard_hint_spec(x, "skip") is x
    assert shard_hint_spec(x, None) is x


def test_cache_specs_seq_sharded(mesh):
    """Decode KV caches: batch over FSDP, sequence dim over model."""
    from repro.configs.base import SHAPES
    cfg = get_config("qwen1_5_110b")
    ac = api.abstract_caches(cfg, SHAPES["decode_32k"])
    cs = shd.cache_pspecs(cfg, ac, _prod_mesh())
    k_spec = tuple(cs["kv"]["k"])                 # (L, B, T, K, hd)
    assert k_spec[1] == "data" and k_spec[2] == "model"
