"""Plan layer: lowering round-trips, executor equivalence, payload lanes.

Covers the plan/execute split: every front-end shape must lower to the
canonical flat pool and un-flatten bit-exactly; grouped (owner/payload)
plans must agree with boolean plans reduced on the host; and the
payload-lane traverse/persist kernel variants run under interpret mode
against their jnp references, mirroring the other kernel suites.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import seeded_property

from repro.core.geometry import NUM_LINKS, OBBs, arm_link_obbs, random_obbs
from repro.core.octree import build_octree, device_octree
from repro.core.sact import PAYLOAD_INF
from repro.core.wavefront import CollisionEngine, EngineConfig
from repro.engine.plan import (QueryPlan, WORKLOADS, plan_batch, plan_edges,
                               plan_queries, plan_scenes, plan_trajectory)
from repro.kernels.persist.ops import traverse_whole
from repro.kernels.traverse.ops import traverse_step


def _tree(seed, n=4000, depth=4):
    rs = np.random.RandomState(seed)
    return build_octree(rs.uniform(-1, 1, (n, 3)).astype(np.float32),
                        depth=depth)


@seeded_property(max_examples=6)
def test_plan_lowering_roundtrips_bit_exactly(seed):
    """Every front-end shape -> flat pool -> unflatten, bit-exact."""
    rs = np.random.RandomState(seed % 100000)
    B, M = int(rs.randint(2, 6)), int(rs.randint(2, 8))
    obbs = random_obbs(jax.random.PRNGKey(seed % 100000), B * M)
    batch = OBBs(center=obbs.center.reshape(B, M, 3),
                 half=obbs.half.reshape(B, M, 3),
                 rot=obbs.rot.reshape(B, M, 3, 3))

    flat = plan_queries(obbs)
    assert flat.num_queries == B * M and flat.groups == B * M
    assert (np.asarray(flat.obb_c) == np.asarray(obbs.center)).all()

    pb = plan_batch(batch)
    assert pb.num_queries == B * M and pb.out_shape == (B, M)
    assert (np.asarray(pb.obb_c)
            == np.asarray(obbs.center)).all()          # row-major flatten
    assert (np.asarray(pb.obb_r).reshape(B, M, 3, 3)
            == np.asarray(batch.rot)).all()
    verdicts = rs.rand(B * M) < 0.5
    assert (pb.unflatten(verdicts) == verdicts.reshape(B, M)).all()

    ps = plan_scenes(batch)                            # (S, M) reading
    assert ps.num_scenes == B
    soq = np.asarray(ps.scene_of_query)
    assert (soq == np.repeat(np.arange(B), M)).all()
    assert (ps.unflatten(verdicts) == verdicts.reshape(B, M)).all()

    T = int(rs.randint(2, 6))
    wps = rs.uniform(-1, 1, (T, 7)).astype(np.float32)
    pt = plan_trajectory(jnp.asarray(wps))
    ref = arm_link_obbs(jnp.asarray(wps))
    assert pt.num_queries == T * NUM_LINKS
    assert (np.asarray(pt.obb_c) == np.asarray(ref.center)).all()
    link_hits = rs.rand(T * NUM_LINKS) < 0.3
    assert (pt.unflatten(link_hits)
            == link_hits.reshape(T, NUM_LINKS).any(axis=1)).all()


def test_plan_validation():
    obbs = random_obbs(jax.random.PRNGKey(0), 8)
    with pytest.raises(ValueError):
        QueryPlan(kind="nope", obb_c=obbs.center, obb_h=obbs.half,
                  obb_r=obbs.rot, out_shape=(8,))
    with pytest.raises(ValueError):
        QueryPlan(kind="queries", obb_c=obbs.center, obb_h=obbs.half,
                  obb_r=obbs.rot, out_shape=(4,))
    assert "edges" in WORKLOADS and "trajectory" in WORKLOADS


def test_query_front_ends_match_execute():
    tree = _tree(0)
    obbs = random_obbs(jax.random.PRNGKey(1), 24)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    got_q, cq = eng.query(obbs)
    got_e, ce = eng.execute(plan_queries(obbs))
    assert (got_q == got_e).all()
    assert cq.axis_tests_executed == ce.axis_tests_executed
    batch = OBBs(center=obbs.center.reshape(4, 6, 3),
                 half=obbs.half.reshape(4, 6, 3),
                 rot=obbs.rot.reshape(4, 6, 3, 3))
    got_b, _ = eng.query_batched(batch)
    assert (got_b == got_q.reshape(4, 6)).all()


def test_trajectory_plan_unifies_host_and_device():
    """check_trajectory's device_resident fork is gone: every mode consumes
    the same trajectory plan and agrees on flags AND work counters."""
    from repro.core.pipeline import check_trajectory
    tree = _tree(1)
    rs = np.random.RandomState(2)
    wps = jnp.asarray(rs.uniform(-1, 1, (5, 7)).astype(np.float32))
    res = {}
    for mode in ("wavefront_host", "wavefront", "wavefront_fused",
                 "wavefront_persistent"):
        res[mode] = check_trajectory(
            CollisionEngine(tree, EngineConfig(mode=mode)), wps)
    flags_ref, c_ref = res["wavefront"]
    assert flags_ref.shape == (5,)
    for mode, (flags, c) in res.items():
        assert (flags == flags_ref).all(), mode
        assert c.nodes_traversed == c_ref.nodes_traversed, mode
        assert c.axis_tests_executed == c_ref.axis_tests_executed, mode
        assert (c.exit_histogram == c_ref.exit_histogram).all(), mode


@pytest.mark.parametrize("mode", ["wavefront", "wavefront_fused",
                                  "wavefront_persistent"])
def test_grouped_plan_matches_boolean_plan_reduced_on_host(mode):
    """Owner/payload plans == boolean verdicts min-reduced per group: the
    in-traversal early exit may skip pairs but can never change the min."""
    tree = _tree(3)
    rs = np.random.RandomState(4)
    Q, G = 36, 9
    obbs = random_obbs(jax.random.PRNGKey(5), Q)
    owner = rs.randint(0, G, Q).astype(np.int32)
    owner[:G] = np.arange(G)                          # keep ids compact
    payload = rs.randint(0, 50, Q).astype(np.int32)
    eng = CollisionEngine(tree, EngineConfig(mode=mode))
    flat, _ = eng.execute(plan_queries(obbs))
    expect = np.full(G, PAYLOAD_INF, np.int64)
    np.minimum.at(expect, owner[flat], payload[flat].astype(np.int64))
    best, c = eng.execute(plan_edges(obbs, owner, G, payload=payload))
    assert best.shape == (G,)
    assert (best == expect).all()
    assert c.frontier_overflow == 0
    # owner-only plans give boolean-style group verdicts (payload zeros)
    hits, _ = eng.execute(plan_edges(obbs, owner, G))
    grp_any = np.zeros(G, bool)
    np.logical_or.at(grp_any, owner, flat)
    assert ((hits < PAYLOAD_INF) == grp_any).all()


def test_grouped_plan_rejected_on_host_modes():
    tree = _tree(3)
    obbs = random_obbs(jax.random.PRNGKey(5), 8)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_host"))
    with pytest.raises(ValueError):
        eng.execute(plan_edges(obbs, np.zeros(8, np.int32), 1))


def test_engine_scene_count_mismatch_rejected():
    tree = _tree(0, n=1000, depth=3)
    obbs = random_obbs(jax.random.PRNGKey(0), 8)
    batch = OBBs(center=obbs.center.reshape(2, 4, 3),
                 half=obbs.half.reshape(2, 4, 3),
                 rot=obbs.rot.reshape(2, 4, 3, 3))
    with pytest.raises(ValueError):
        CollisionEngine(tree, EngineConfig(mode="wavefront_fused")).execute(
            plan_scenes(batch))


@pytest.mark.parametrize("use_spheres", [False])
def test_traverse_step_payload_lane_interpret_matches_ref(use_spheres):
    """Payload-lane fused step: Pallas verdict kernel (interpret=True) and
    jnp arm agree on the grouped best, compacted frontier, and counters."""
    rs = np.random.RandomState(11)
    tree = _tree(11, n=2500, depth=4)
    dev = device_octree(tree)
    obbs = random_obbs(jax.random.PRNGKey(11), 24)
    G = 6
    owner = jnp.asarray(rs.randint(0, G, obbs.n).astype(np.int32))
    payload = jnp.asarray(rs.randint(0, 100, obbs.n).astype(np.int32))
    level, cap = 2, 96
    n_l = len(tree.levels[level].codes)
    n_live = min(cap, max(n_l, 8))
    idx = jnp.asarray(rs.randint(0, n_l, cap).astype(np.int32))
    q = jnp.asarray(rs.randint(0, obbs.n, cap).astype(np.int32))
    best0 = jnp.full((obbs.n,), PAYLOAD_INF, jnp.int32)
    args = (obbs.center, obbs.half, obbs.rot, dev, jnp.int32(level),
            jnp.int32(n_live), q, idx, best0)
    kw = dict(use_spheres=use_spheres, owner=owner, payload=payload)
    ref = traverse_step(*args, use_pallas=False, **kw)
    pal = traverse_step(*args, use_pallas=True, interpret=True, bn=32, **kw)
    for name, a, b in zip(("cnt", "q_next", "idx_next", "best"),
                          ref[:4], pal[:4]):
        assert bool(jnp.all(a == b)), name
    assert ref[3].dtype == jnp.int32


def test_persist_kernel_payload_lane_interpret_matches_ref():
    """Payload-lane megakernel (identity owner): interpret-mode kernel ==
    jnp ref, best words and every stats field."""
    rs = np.random.RandomState(7)
    tree = _tree(7, n=2500, depth=3)
    dev = device_octree(tree)
    obbs = random_obbs(jax.random.PRNGKey(7), 21)     # 2 tiles at bq=16
    payload = jnp.asarray(rs.randint(0, 9, obbs.n).astype(np.int32))
    cap = 256
    ref = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_spheres=False, use_pallas=False,
                         payload=payload)
    pal = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                         use_spheres=False, use_pallas=True,
                         interpret=True, bq=16, payload=payload)
    assert ref[0].dtype == jnp.int32
    assert bool(jnp.all(ref[0] == pal[0]))
    for k in ref[1]:
        assert bool(jnp.all(ref[1][k] == pal[1][k])), k
    # payload semantics: best == payload where the boolean engine collides
    collide, _ = traverse_whole(obbs.center, obbs.half, obbs.rot, dev, cap,
                                use_spheres=False, use_pallas=False)
    best = np.asarray(ref[0])
    assert (best[np.asarray(collide)] == np.asarray(payload)[
        np.asarray(collide)]).all()
    assert (best[~np.asarray(collide)] == PAYLOAD_INF).all()
