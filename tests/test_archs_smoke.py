"""Per-arch smoke tests: reduced config, one forward/train step on CPU.

Asserts output shapes, finiteness (no NaNs), and that one SGD step reduces
loss on a repeated batch.  Also exercises prefill->decode consistency for
one representative arch per family.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_REGISTRY, get_smoke_config
from repro.models import api


def _dummy_batch(cfg, B=2, S=32, key=0):
    rng = np.random.RandomState(key)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)
                       ).astype(np.float32))
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_REGISTRY)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _dummy_batch(cfg)
    loss_fn = api.make_loss_fn(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                     grads))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch
    # one SGD step reduces loss on the same batch
    lr = 0.05
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
    loss2, _ = jax.jit(loss_fn)(params2, batch)
    assert float(loss2) < float(loss), (arch, float(loss), float(loss2))


@pytest.mark.parametrize("arch", ["glm4_9b", "hymba_1_5b", "rwkv6_1_6b",
                                  "granite_moe_1b_a400m", "whisper_medium",
                                  "pixtral_12b"])
def test_prefill_decode_consistency(arch):
    """Greedy decode logits must match the teacher-forced forward."""
    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        # Capacity-factor MoE drops tokens under load; decode (1 token/group)
        # never drops, so run the equivalence check in the no-drop regime.
        cfg = cfg.replace(moe_capacity_factor=8.0)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _dummy_batch(cfg, B, S, key=1)
    prefill_fn = jax.jit(api.make_prefill_fn(cfg))
    decode_fn = jax.jit(api.make_decode_fn(cfg))
    last_logits, caches = prefill_fn(params, batch)

    # Full forward over S+1 tokens: compare position S logits with one
    # decode step applied after prefilling S tokens.
    next_tok = jnp.argmax(last_logits, -1).astype(jnp.int32)
    step_logits, _ = decode_fn(params, next_tok,
                               jnp.asarray(S + (cfg.num_patches
                                                if cfg.family == "vlm"
                                                else 0), jnp.int32), caches)

    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], next_tok[:, None]], 1)
    if cfg.family == "encdec":
        full_logits, _ = __import__(
            "repro.models.encdec", fromlist=["encdec_forward"]
        ).encdec_forward(params, batch["frames"], ext["tokens"], cfg)
    else:
        from repro.models import transformer as tfm
        full_logits, _, _ = tfm.lm_forward(
            params, ext["tokens"], cfg,
            prefix_embeds=ext.get("patch_embeds"))
        if cfg.family == "vlm":
            full_logits = full_logits[:, cfg.num_patches:]
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_param_count_sanity():
    """Full configs land near their nameplate sizes."""
    from repro.configs.base import get_config
    expect = {"nemotron_4_340b": (300e9, 400e9),
              "qwen1_5_110b": (95e9, 130e9),
              "starcoder2_7b": (6e9, 9e9),
              "glm4_9b": (8e9, 12e9),
              "arctic_480b": (430e9, 530e9),
              "pixtral_12b": (10e9, 15e9),
              # our rwkv block is simplified (no low-rank decay towers),
              # so it lands a bit under the 1.6B nameplate
              "rwkv6_1_6b": (1.0e9, 2.2e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).num_params
        assert lo < n < hi, (arch, n)
