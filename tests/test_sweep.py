"""Swept-edge (CCD) workload: enclosure soundness, first-hit correctness,
mode equivalence, and the edge early-exit work advantage.

The first-hit reference replicates the left-first descent with the naive
engine deciding each segment (dense SACT against every leaf), so it shares
no traversal machinery with the plan/executor path it checks.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import seeded_property

from repro.core.geometry import NUM_LINKS
from repro.core.octree import build_octree
from repro.core.pipeline import check_edges, check_trajectories
from repro.core.sweep import (edge_link_geometry, edge_waypoints,
                              sweep_edges, swept_obbs)
from repro.core.wavefront import CollisionEngine, EngineConfig
from repro.data.robotics import PANDA_JOINT_HI, PANDA_JOINT_LO, make_scene

_JLO, _JHI = PANDA_JOINT_LO, PANDA_JOINT_HI


def _edge_batch(seed, E, delta=0.35):
    """Seeded PRM-style edge batch: short joint-space hops."""
    rs = np.random.RandomState(seed)
    qf = rs.uniform(_JLO, _JHI, (E, 7)).astype(np.float32)
    qt = np.clip(qf + rs.uniform(-delta, delta, (E, 7)).astype(np.float32),
                 _JLO, _JHI)
    return qf, qt


def _scene_and_tree(n_points=5000, depth=4):
    sc = make_scene("cubby", num_points=n_points)
    return sc, build_octree(sc.points, depth=depth)


@seeded_property(max_examples=4)
def test_swept_enclosure_contains_all_waypoint_corners(seed):
    """The fitted segment OBB contains every contained waypoint's corner
    points — the invariant bisection pruning relies on."""
    rs = np.random.RandomState(seed % 100000)
    E, R = 3, 8
    qf, qt = _edge_batch(seed % 100000, E, delta=0.8)
    corners, rot = edge_link_geometry(qf, qt, R)
    lo = rs.randint(0, R - 1, E).astype(np.int32)
    width = np.full(E, int(rs.randint(1, 4)), np.int32)
    hi = np.minimum(lo + width, R).astype(np.int32)
    edge = np.arange(E, dtype=np.int32)
    obbs = swept_obbs(corners, rot, edge, lo, hi)
    ctr = np.asarray(obbs.center).reshape(E, NUM_LINKS, 3)
    hlf = np.asarray(obbs.half).reshape(E, NUM_LINKS, 3)
    r = np.asarray(obbs.rot).reshape(E, NUM_LINKS, 3, 3)
    for e in range(E):
        pts = corners[e, lo[e]:hi[e] + 1]              # (w+1, L, 8, 3)
        rel = pts - ctr[e][None, :, None, :]
        local = np.einsum("lji,wlkj->wlki", r[e], rel)
        assert (np.abs(local) <= hlf[e][None, :, None, :] + 1e-4).all()


def test_swept_verdict_upper_bounds_dense_sampling():
    """Soundness: any edge that dense waypoint sampling flags at equal
    resolution is flagged by the swept check, and the swept first hit is
    never later than the first colliding waypoint."""
    sc, tree = _scene_and_tree()
    qf, qt = _edge_batch(0, 16)
    R = 8
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    res = check_edges(eng, qf, qt, resolution=R, base_pos=sc.robot_base)
    wps = edge_waypoints(qf, qt, R)
    flags, _ = check_trajectories(eng, jnp.asarray(wps),
                                  base_pos=sc.robot_base)
    dense = np.asarray(flags).any(axis=1)
    assert (~dense | res.collide).all()
    assert 0 < int(dense.sum()) < len(dense)       # scene is discriminative
    for e in np.where(dense)[0]:
        first_wp = int(np.argmax(np.asarray(flags[e]))) / R
        assert res.first_hit[e] <= first_wp + 1e-6
    assert np.isinf(res.first_hit[~res.collide]).all()


def test_check_edges_modes_agree_bitwise():
    """Every engine mode — including the host loop, which runs the same
    plans as boolean rounds — produces identical first hits and verdicts."""
    sc, tree = _scene_and_tree(n_points=3000)
    qf, qt = _edge_batch(1, 8)
    res = {}
    for mode in ("wavefront", "wavefront_fused", "wavefront_persistent",
                 "wavefront_host"):
        eng = CollisionEngine(tree, EngineConfig(mode=mode))
        res[mode] = check_edges(eng, qf, qt, resolution=8,
                                base_pos=sc.robot_base)
    ref = res["wavefront_fused"]
    assert ref.collide.any()
    for mode, r in res.items():
        assert (r.first_hit == ref.first_hit).all(), mode
        assert (r.collide == ref.collide).all(), mode


def test_first_hit_matches_naive_descent_reference():
    """Replicate the left-first descent with the naive engine deciding each
    segment: the traversal path must confirm the same first sub-intervals."""
    sc, tree = _scene_and_tree(n_points=3000)
    qf, qt = _edge_batch(2, 8)
    R = 8
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_persistent"))
    first_hit, collide, _ = sweep_edges(eng, qf, qt, resolution=R,
                                        base_pos=sc.robot_base)

    naive = CollisionEngine(tree, EngineConfig(mode="naive"))
    corners, rot = edge_link_geometry(qf, qt, R, base_pos=sc.robot_base)
    E = qf.shape[0]
    ref_hit = np.full(E, np.inf, np.float32)
    for e in range(E):
        queue = [(0, R)]
        while queue:
            lo, hi = queue.pop(0)
            obbs = swept_obbs(corners, rot, np.asarray([e]),
                              np.asarray([lo]), np.asarray([hi]))
            hit, _ = naive.query(obbs)
            if not hit.any():
                continue
            if hi - lo == 1:
                ref_hit[e] = lo / R
                break
            mid = (lo + hi) // 2
            queue.insert(0, (mid, hi))
            queue.insert(0, (lo, mid))
    assert (collide == np.isfinite(ref_hit)).all()
    assert (first_hit[collide] == ref_hit[collide]).all()


def test_edge_early_exit_beats_dense_sampling_work():
    """The fig_edges acceptance: swept edge validation executes measurably
    fewer axis tests than dense waypoint sampling at equal resolution."""
    sc, tree = _scene_and_tree()
    qf, qt = _edge_batch(3, 20)
    R = 16
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    res = check_edges(eng, qf, qt, resolution=R, base_pos=sc.robot_base)
    wps = edge_waypoints(qf, qt, R)
    _, cd = check_trajectories(eng, jnp.asarray(wps), base_pos=sc.robot_base)
    assert res.counters.axis_tests_executed < cd.axis_tests_executed
    assert res.counters.nodes_traversed < cd.nodes_traversed


def test_sweep_resolution_one_and_free_batch():
    """Degenerate cases: resolution 1 (whole edge = one payload round) and
    an all-free batch (bisection never refines)."""
    sc, tree = _scene_and_tree(n_points=2000)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    qf, qt = _edge_batch(4, 4)
    first_hit, collide, c = sweep_edges(eng, qf, qt, resolution=1,
                                        base_pos=sc.robot_base)
    assert first_hit.shape == (4,)
    assert set(np.unique(first_hit[collide])) <= {0.0}
    assert c.num_queries > 0
    # edges far outside the scene volume: free, one round, tiny work
    off = np.tile(np.asarray([0.0, -1.5, 0.0, -1.5, 0.0, 1.5, 0.0],
                             np.float32), (3, 1))
    fh, col, cf = sweep_edges(eng, off, off + 0.01, resolution=8,
                              base_pos=np.asarray([50.0, 50.0, 50.0]))
    assert not col.any()
    assert np.isinf(fh).all()
    assert cf.nodes_traversed <= 3 * NUM_LINKS * 2


def test_invalid_resolution_rejected():
    sc, tree = _scene_and_tree(n_points=1000)
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront"))
    qf, qt = _edge_batch(5, 2)
    with pytest.raises(ValueError):
        sweep_edges(eng, qf, qt, resolution=3)
    with pytest.raises(ValueError):
        sweep_edges(eng, qf[0], qt[0], resolution=4)
