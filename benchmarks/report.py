"""Generate the §Dry-run / §Roofline markdown tables from dry-run JSONs,
plus the metadata-traffic table from bench results.json artifacts.

  PYTHONPATH=src python -m benchmarks.report [--dir benchmarks/results/dryrun]
  PYTHONPATH=src python -m benchmarks.report --sections bench \\
      [--bench-dir benchmarks/results/smoke]

Markdown goes to stdout; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs.base import ARCH_REGISTRY, SHAPES
from repro.roofline import hw


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(d):
    recs = {}
    for fn in os.listdir(d):
        if fn.endswith(".json"):
            r = json.load(open(os.path.join(d, fn)))
            recs[r["cell"]] = r
    return recs


def roofline_table(recs, mesh: str):
    print(f"\n### Roofline — {mesh} mesh "
          f"({256 if mesh == 'single' else 512} chips, per-chip terms)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "useful FLOPs ratio | mem/chip |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_REGISTRY:
        for shape in SHAPES:
            cell = f"{arch}__{shape}__{mesh}"
            r = recs.get(cell)
            if r is None:
                print(f"| {arch} | {shape} | - | - | - | MISSING | | |")
                continue
            if r["status"] == "skipped":
                print(f"| {arch} | {shape} | — | — | — | *skipped: "
                      f"full-attention @524k* | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | - | - | - | ERROR | | |")
                continue
            fit = "" if r["peak_mem_per_chip"] <= hw.HBM_BYTES else " ⚠"
            print(f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                  f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                  f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
                  f"{r['peak_mem_per_chip']/2**30:.1f} GiB{fit} |")


def dryrun_table(recs):
    print("\n### Dry-run summary (lower+compile status, all cells)\n")
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    er = sum(1 for r in recs.values() if r["status"] == "error")
    print(f"cells: {len(recs)} — ok {ok}, documented skips {sk}, errors "
          f"{er}\n")
    print("| cell | status | compile | FLOPs/chip | HBM B/chip | "
          "coll B/chip | mem/chip |")
    print("|---|---|---|---|---|---|---|")
    for cell in sorted(recs):
        r = recs[cell]
        if r["status"] != "ok":
            print(f"| {cell} | {r['status']} | | | | | |")
            continue
        print(f"| {cell} | ok | {r['compile_s']:.1f}s | "
              f"{r['flops_per_chip']:.2e} | {r['hbm_bytes_per_chip']:.2e} | "
              f"{r['collective_bytes_per_chip']:.2e} | "
              f"{r['peak_mem_per_chip']/2**30:.1f} GiB |")


def _derived_fields(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def bench_table(path: str):
    """Streamed-metadata traffic across bench rows (fig_bigscene /
    fig_compress): wall, rows fetched, and the priced
    ``meta_bytes_streamed`` per row family."""
    if not os.path.isfile(path):
        print(f"\n### Metadata traffic — no bench artifacts at {path}\n")
        return
    rows = json.load(open(path))
    print("\n### Metadata traffic (streamed layout rows, "
          "`Counters.meta_bytes_streamed`)\n")
    print("| bench row | wall/call | layout | meta rows streamed | "
          "meta bytes streamed | vs fp32 |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        d = _derived_fields(r.get("derived", ""))
        if "meta_bytes_streamed" not in d:
            continue
        print(f"| {r['name']} | {fmt_s(r['us_per_call'] / 1e6)} | "
              f"{d.get('layout', '-')} | {d.get('meta_rows_streamed', '-')} | "
              f"{d['meta_bytes_streamed']} | {d.get('bytes_vs_fp32', '-')} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "results", "dryrun"))
    ap.add_argument("--bench-dir", default=os.path.join(
        os.path.dirname(__file__), "results", "smoke"))
    ap.add_argument("--sections", default="roofline,dryrun")
    args = ap.parse_args()
    secs = args.sections.split(",")
    if "roofline" in secs or "dryrun" in secs:
        recs = load(args.dir)
        if "roofline" in secs:
            roofline_table(recs, "single")
            roofline_table(recs, "multi")
        if "dryrun" in secs:
            dryrun_table(recs)
    if "bench" in secs:
        bench_table(os.path.join(args.bench_dir, "results.json"))


if __name__ == "__main__":
    main()
