"""Diff a smoke-benchmark results.json against the checked-in baseline.

Usage:
  python benchmarks/check_regression.py BENCH_smoke.json \\
      benchmarks/results/smoke/results.json [--threshold 1.5] \\
      [--fail-threshold 2.0] [--strict]

Rows are matched by name.  A row whose ``us_per_call`` grew past
``threshold`` x baseline is reported as a GitHub Actions ``::warning::``
line (warn-only — shared CI runners are noisy; pass ``--strict`` to turn
warnings into a nonzero exit).  A row past ``--fail-threshold`` is an
``::error::`` and ALWAYS fails the job: noise does not double a row, so a
>2x regression is treated as real.  Rows under ``--min-us`` in the
baseline are ignored (timer noise / model-only 0.0 rows), as are rows that
exist on only one side (new or retired benches) — except prefixes named
via ``--require``: a required bench family missing from the fresh results
fails the job (a silently crashed/retired bench must not pass the diff).
"""
from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in rows}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="checked-in BENCH_smoke.json")
    ap.add_argument("new", help="fresh results.json from --smoke")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="warn when new > threshold * baseline")
    ap.add_argument("--fail-threshold", type=float, default=2.0,
                    help="hard-fail when new > fail_threshold * baseline")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="ignore baseline rows faster than this")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any row regresses past --threshold")
    ap.add_argument("--require", action="append", default=[],
                    metavar="PREFIX",
                    help="fail unless the fresh results contain at least "
                         "one row with this name prefix (repeatable)")
    args = ap.parse_args()

    base = load_rows(args.baseline)
    new = load_rows(args.new)
    # A crashed bench leaves a single "<family>/ERROR" row in the artifact
    # (benchmarks.run's keep-going handler); it must NOT satisfy --require,
    # or a required family that crashed every run would pass vacuously.
    live = {name for name in new if not name.endswith("/ERROR")}
    missing = [p for p in args.require
               if not any(name.startswith(p) for name in live)]
    for prefix in missing:
        errored = sorted(n for n in new if n.startswith(prefix)
                         and n.endswith("/ERROR"))
        why = (f"bench crashed (row {errored[0]!r})" if errored
               else "required bench family absent")
        print(f"::error title=bench missing::no '{prefix}*' rows in "
              f"{args.new} ({why})")
    shared = sorted(set(base) & set(new))
    regressions, failures = [], []
    for name in shared:
        b, n = base[name], new[name]
        if b < args.min_us:
            continue
        if n > args.fail_threshold * b:
            failures.append((name, b, n))
            print(f"::error title=bench regression::{name}: "
                  f"{b:.0f}us -> {n:.0f}us ({n / b:.2f}x, "
                  f"hard limit {args.fail_threshold}x)")
        elif n > args.threshold * b:
            regressions.append((name, b, n))
            print(f"::warning title=bench regression::{name}: "
                  f"{b:.0f}us -> {n:.0f}us ({n / b:.2f}x, "
                  f"threshold {args.threshold}x)")
    print(f"# compared {len(shared)} rows "
          f"({len(base) - len(shared)} baseline-only, "
          f"{len(new) - len(shared)} new-only), "
          f"{len(regressions)} warning(s) past {args.threshold}x, "
          f"{len(failures)} failure(s) past {args.fail_threshold}x, "
          f"{len(missing)} required famil(ies) missing")
    if failures or missing:
        return 1
    return 1 if (regressions and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
