"""Shared benchmark plumbing: scene setup, engine timing, CSV emission."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Tuple

import numpy as np

#: Every emit() is also recorded here so the runner can persist the full
#: suite as CSV/JSON artifacts (CI perf trajectory; see run.py --out).
RESULTS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    RESULTS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def write_results(out_dir: str) -> None:
    """Persist recorded rows as results.csv + results.json under out_dir."""
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "results.csv"), "w") as f:
        f.write("name,us_per_call,derived\n")
        for name, us, derived in RESULTS:
            f.write(f"{name},{us:.1f},{derived}\n")
    with open(os.path.join(out_dir, "results.json"), "w") as f:
        json.dump([{"name": n, "us_per_call": us, "derived": d}
                   for n, us, d in RESULTS], f, indent=2)


def time_call(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds over repeats (after warmup)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_group(fns: dict, repeats: int = 7, warmup: int = 1) -> dict:
    """Contention-robust A/B timing: best (min) seconds per arm, with the
    arms *interleaved* round-robin so slow background-load phases hit every
    arm equally instead of whichever arm's block they land on."""
    for fn in fns.values():
        for _ in range(warmup):
            fn()
    ts = {name: [] for name in fns}
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            ts[name].append(time.perf_counter() - t0)
    return {name: float(min(t)) for name, t in ts.items()}


# Simple RoboCore-style cycle model used where the paper reports simulator
# cycles we cannot measure (Figs. 12/13/16).  Calibrated in relative terms:
#   axis test      : CYCLES_AXIS per executed axis (decoded-but-skipped axes
#                    cost CYCLES_DECODE on predication designs)
#   interconnect   : CYCLES_PER_BYTE * bytes moved between units
#   sphere test    : CYCLES_SPHERE
# Energy model: pJ per executed op / per byte moved (45nm-scaled, relative).
CYCLES_AXIS = 4.0
CYCLES_DECODE = 1.0
CYCLES_SPHERE = 6.0
CYCLES_PER_BYTE = 0.05
PJ_PER_AXIS = 8.0
PJ_PER_BYTE = 1.2
PJ_PER_SHADER = 400.0


def work_model_cycles(c, mode: str) -> float:
    """Counters -> modeled cycles for one query batch.

    no-exit designs (naive / rta_like / staged_noexit) execute every decoded
    axis; predication executes only until the exit but still decodes+routes
    the rest; conditional returns (wavefront*) skip them entirely.
    """
    executed = c.axis_tests_executed
    decoded = c.axis_tests_decoded
    skipped = max(decoded - executed, 0)
    if mode in ("naive", "rta_like", "staged_noexit"):
        cycles = decoded * CYCLES_AXIS
    elif mode == "predicated":
        cycles = executed * CYCLES_AXIS + skipped * CYCLES_DECODE
    else:                                      # conditional returns
        cycles = executed * CYCLES_AXIS
    cycles += c.sphere_tests * CYCLES_SPHERE
    cycles += c.bytes_moved * CYCLES_PER_BYTE
    cycles += c.shader_invocations * 50.0
    return cycles


def work_model_energy_pj(c) -> float:
    return (c.axis_tests_executed * PJ_PER_AXIS
            + c.bytes_moved * PJ_PER_BYTE
            + c.shader_invocations * PJ_PER_SHADER)
