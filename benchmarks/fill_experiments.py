"""Inject generated dry-run/roofline tables into EXPERIMENTS.md markers."""
import io
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def gen(sections: str, d: str) -> str:
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.report", "--sections", sections,
         "--dir", d],
        capture_output=True, text=True, cwd=ROOT,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert out.returncode == 0, out.stderr
    return out.stdout


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    base_dir = os.path.join(ROOT, "benchmarks", "results", "dryrun")
    opt_dir = os.path.join(ROOT, "benchmarks", "results", "dryrun_opt")
    subs = {
        "<!-- ROOFLINE_BASELINE -->": gen("roofline", base_dir),
        "<!-- DRYRUN_TABLE -->": gen("dryrun", opt_dir),
        "<!-- ROOFLINE_OPT -->": gen("roofline", opt_dir),
    }
    for marker, content in subs.items():
        if marker in text:
            text = text.replace(marker, content)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
