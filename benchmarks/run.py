"""Benchmark harness: one function per RoboGPU table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows.  Default sizes are scaled so
the suite finishes on one CPU core; pass --full for paper-scale inputs
(524288-point clouds).  Simulator-cycle/energy claims use the work model in
benchmarks/common.py; wall-clock rows are measured on the JAX engine.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig11,table4
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# Support plain-script invocation (python benchmarks/run.py) next to
# module invocation (python -m benchmarks.run): put the repo root and src/
# on sys.path before the package imports below.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if __package__ in (None, ""):
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    sys.path.insert(0, _ROOT)

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (emit, time_call, time_group,
                               work_model_cycles, work_model_energy_pj,
                               write_results)
from repro.core.ballquery import ball_query_pray, ball_query_psphere
from repro.core.fps import (farthest_point_sampling, random_sampling,
                            sampling_spread)
from repro.core.geometry import OBBs
from repro.core.octree import build_octree
from repro.core.quantize import META_FORMATS
from repro.core.wavefront import (CollisionEngine, EngineConfig,
                                  traversal_cache_info)
from repro.data.robotics import (ENVIRONMENTS, make_mpaccel_scenario,
                                 make_scene, scene_trajectories)

SCALE = {"points": 65536, "trajs": 6, "wps": 30, "depth": 6,
         "mpaccel_scenarios": 4, "mpaccel_points": 16384,
         "edges": 24, "edge_res": 16,
         "serve_clients": 8, "serve_requests": 16, "serve_queries": 12,
         "serve_max_wait_ms": 2.0}
FULL_SCALE = {"points": 524288, "trajs": 25, "wps": 60, "depth": 7,
              "mpaccel_scenarios": 10, "mpaccel_points": 65536,
              "edges": 64, "edge_res": 32,
              "serve_clients": 16, "serve_requests": 32,
              "serve_queries": 12, "serve_max_wait_ms": 2.0}
# CI artifact job: tiny scene, 1 repeat, subset of benches (see --smoke).
SMOKE_SCALE = {"points": 4096, "trajs": 2, "wps": 6, "depth": 4,
               "mpaccel_scenarios": 1, "mpaccel_points": 2048,
               "edges": 8, "edge_res": 16,
               "serve_clients": 4, "serve_requests": 8, "serve_queries": 12,
               "serve_max_wait_ms": 4.0}
SMOKE_BENCHES = ("fig11", "fig15", "table4", "batched", "ragged",
                 "fig_edges", "fig_bigscene", "fig_compress", "fig_serve")

_scene_cache = {}


def get_scene(name, n_points, depth, trajs, wps):
    key = (name, n_points, depth, trajs, wps)
    if key not in _scene_cache:
        sc = make_scene(name, num_points=n_points)
        tree = build_octree(sc.points, depth=depth)
        obbs = scene_trajectories(sc, num_trajectories=trajs, waypoints=wps)
        _scene_cache[key] = (sc, tree, obbs)
    return _scene_cache[key]


# ---------------------------------------------------------------------------
# Fig. 11 — collision detection speedup per environment x design arm
# ---------------------------------------------------------------------------

def fig11_collision_speedup(S):
    rows = {}
    persist_speedups = []
    for env in ENVIRONMENTS:
        _, tree, obbs = get_scene(env, S["points"], S["depth"], S["trajs"],
                                  S["wps"])
        base_cycles = None
        ref = None
        engines = {}
        for mode in ("naive", "rta_like", "staged_noexit", "predicated",
                     "wavefront_host", "wavefront", "wavefront_fused",
                     "wavefront_persistent"):
            eng = CollisionEngine(tree, EngineConfig(mode=mode))
            engines[mode] = eng
            col, c = eng.query(obbs)
            col2, c2 = eng.query(obbs)       # timed second run (post-jit)
            if ref is None:
                ref = np.asarray(col)
            assert (np.asarray(col2) == ref).all(), (env, mode)
            cycles = work_model_cycles(c2, mode)
            if mode == "naive":
                base_cycles = cycles
            speed = base_cycles / cycles
            # escalations: replays of the FIRST (cold) query; the timed
            # second run starts at the memoized clean capacity, so a
            # nonzero repeat count here means the memo regressed.
            emit(f"fig11/{env}/{mode}", c2.wall_time_s * 1e6,
                 f"model_speedup_vs_cuda={speed:.1f};collisions="
                 f"{int(ref.sum())};axis_exec={c2.axis_tests_executed};"
                 f"cold_escalations={c.escalations};"
                 f"escalations={c2.escalations}")
            rows[(env, mode)] = (c2, cycles)
        # headline: RC_CR_CU vs rta_like (paper: 3.1x), vs naive (14.8x)
        full = rows[(env, "wavefront_fused")][1]
        emit(f"fig11/{env}/headline", 0.0,
             f"vs_mochi={rows[(env, 'rta_like')][1]/full:.1f}x;"
             f"vs_cuda={rows[(env, 'naive')][1]/full:.1f}x;"
             f"vs_tta={rows[(env, 'staged_noexit')][1]/full:.1f}x")
        # Wall clock, interleaved best-of-N (single runs are too noisy for
        # the CI regression diff): device while_loop vs host-in-the-loop
        # resize at few repeats (an ~8x gap survives any noise), then the
        # close fused-vs-unfused A/B at many cheap repeats.
        walls_hd = time_group({
            "host": lambda: engines["wavefront_host"].query(obbs),
            "dev": lambda: engines["wavefront"].query(obbs)}, repeats=5)
        walls_df = time_group({
            "dev": lambda: engines["wavefront"].query(obbs),
            "fused": lambda: engines["wavefront_fused"].query(obbs),
            "persist": lambda: engines["wavefront_persistent"].query(obbs)},
            repeats=21)
        host_wall = walls_hd["host"]
        dev_wall = min(walls_hd["dev"], walls_df["dev"])
        fused_wall = walls_df["fused"]
        persist_wall = walls_df["persist"]
        emit(f"fig11/{env}/engine=device_wavefront", dev_wall * 1e6,
             f"wall_speedup_vs_host={host_wall/max(dev_wall, 1e-9):.1f}x")
        emit(f"fig11/{env}/engine=device_fused", fused_wall * 1e6,
             f"wall_speedup_vs_unfused="
             f"{dev_wall/max(fused_wall, 1e-9):.2f}x;"
             f"wall_speedup_vs_host="
             f"{host_wall/max(fused_wall, 1e-9):.1f}x")
        persist_speedups.append(fused_wall / max(persist_wall, 1e-9))
        emit(f"fig11/{env}/engine=device_persistent", persist_wall * 1e6,
             f"wall_speedup_vs_fused={persist_speedups[-1]:.2f}x;"
             f"wall_speedup_vs_host="
             f"{host_wall/max(persist_wall, 1e-9):.1f}x")
    emit("fig11/persistent_vs_fused_geomean", 0.0,
         f"geomean_wall_speedup="
         f"{float(np.exp(np.mean(np.log(persist_speedups)))):.2f}x;"
         f"envs={len(persist_speedups)}")
    # Retrace/replay observability: lru entries and per-key trace counts
    # of the traversal jit cache after the whole fig11 sweep — growth here
    # between runs means escalation replays or engine reconstructions
    # started retracing (BENCH artifacts record the trajectory).
    tc = traversal_cache_info()
    emit("fig11/traversal_cache", 0.0,
         f"entries={tc['entries']};hits={tc['hits']};"
         f"misses={tc['misses']};traces={sum(tc['traces'].values())}")


# ---------------------------------------------------------------------------
# Fig. 12 — unit utilization proxy (work distribution per design)
# ---------------------------------------------------------------------------

def fig12_unit_utilization(S):
    _, tree, obbs = get_scene("cubby", S["points"], S["depth"], S["trajs"],
                              S["wps"])
    for mode in ("staged_noexit", "predicated", "wavefront",
                 "wavefront_fused", "wavefront_persistent"):
        eng = CollisionEngine(tree, EngineConfig(mode=mode))
        _, c = eng.query(obbs)
        total = work_model_cycles(c, mode)
        icnt = c.bytes_moved * 0.05 / max(total, 1)
        box_normal = min(c.axis_tests_executed, c.nodes_traversed * 6)
        edge = max(c.axis_tests_executed - box_normal, 0)
        emit(f"fig12/{mode}", 0.0,
             f"icnt_frac={icnt:.2f};box_normal_tests={box_normal};"
             f"edge_tests={edge};bytes={c.bytes_moved}")


# ---------------------------------------------------------------------------
# Fig. 13 — sensitivity to collision-unit latency (work model)
# ---------------------------------------------------------------------------

def fig13_latency_sensitivity(S):
    from benchmarks import common
    _, tree, obbs = get_scene("cubby", S["points"], S["depth"], S["trajs"],
                              S["wps"])
    counters = {}
    for mode in ("predicated", "wavefront"):
        eng = CollisionEngine(tree, EngineConfig(mode=mode))
        _, counters[mode] = eng.query(obbs)
    base = common.CYCLES_AXIS
    for mult in (0.5, 1.0, 1.5, 2.0):
        common.CYCLES_AXIS = base * mult
        cr = work_model_cycles(counters["wavefront"], "wavefront")
        p = work_model_cycles(counters["predicated"], "predicated")
        emit(f"fig13/lat_{mult}x", 0.0,
             f"cond_return_cycles={cr:.3e};predication_cycles={p:.3e}")
    common.CYCLES_AXIS = base


# ---------------------------------------------------------------------------
# Fig. 14 — MPAccel small scenarios: avg/min/max speedup vs naive
# ---------------------------------------------------------------------------

def fig14_mpaccel(S):
    speeds = []
    for i in range(S["mpaccel_scenarios"]):
        sc = make_mpaccel_scenario(i, num_points=S["mpaccel_points"])
        tree = build_octree(sc.points, depth=5)
        obbs = scene_trajectories(sc, num_trajectories=4, waypoints=25)
        cyc = {}
        for mode in ("naive", "wavefront_fused"):
            eng = CollisionEngine(tree, EngineConfig(mode=mode))
            _, c = eng.query(obbs)
            cyc[mode] = work_model_cycles(c, mode)
        speeds.append(cyc["naive"] / cyc["wavefront_fused"])
    emit("fig14/mpaccel", 0.0,
         f"avg={np.mean(speeds):.1f}x;min={np.min(speeds):.1f}x;"
         f"max={np.max(speeds):.1f}x;"
         f"note=paper_sees_smaller_gains_on_small_scenes")


# ---------------------------------------------------------------------------
# Fig. 15 — latency distribution per exit condition (+ sphere ablation)
# ---------------------------------------------------------------------------

def fig15_exit_distribution(S):
    _, tree, obbs = get_scene("dresser", S["points"], S["depth"],
                              S["trajs"], S["wps"])
    for spheres in (False, True):
        eng = CollisionEngine(tree, EngineConfig(mode="wavefront",
                                                 use_spheres=spheres))
        _, c = eng.query(obbs)
        h = c.exit_histogram
        early = c.early_exit_fraction()
        emit(f"fig15/spheres_{spheres}", 0.0,
             f"bsphere={h[0]};isphere={h[1]};"
             f"box_normal={int(h[2:8].sum())};edge={int(h[8:17].sum())};"
             f"full={h[17]};early_exit_frac={early:.2f};"
             f"sphere_tests={c.sphere_tests}")


# ---------------------------------------------------------------------------
# Fig. 16 — energy model comparison
# ---------------------------------------------------------------------------

def fig16_energy(S):
    _, tree, obbs = get_scene("merged_cubby", S["points"], S["depth"],
                              S["trajs"], S["wps"])
    pj = {}
    for mode in ("naive", "rta_like", "wavefront_fused"):
        eng = CollisionEngine(tree, EngineConfig(mode=mode))
        _, c = eng.query(obbs)
        pj[mode] = work_model_energy_pj(c)
    emit("fig16/energy", 0.0,
         f"vs_cuda_savings={1-pj['wavefront_fused']/pj['naive']:.2f};"
         f"vs_mochi_savings={1-pj['wavefront_fused']/pj['rta_like']:.2f}")


# ---------------------------------------------------------------------------
# Table IV — P-Ray vs P-Sphere ball query
# ---------------------------------------------------------------------------

def table4_pray_psphere(S):
    sc, tree, _ = get_scene("cubby", S["points"], S["depth"], 1, 2)
    rs = np.random.RandomState(0)
    m = 512
    qidx = rs.choice(len(sc.points), m, replace=False)
    queries = jnp.asarray(sc.points[qidx])
    radius, k = 0.05, 32

    t = time.perf_counter()
    ps_idx, ps_cnt, c_ps = ball_query_psphere(tree, queries, radius, k)
    t_ps = time.perf_counter() - t
    t = time.perf_counter()
    pr_idx, pr_cnt, c_pr = ball_query_pray(jnp.asarray(sc.points), queries,
                                           radius, k, depth=4)
    t_pr = time.perf_counter() - t
    assert (np.asarray(ps_cnt) == np.asarray(pr_cnt)).all()
    emit("table4/p_ray", t_pr * 1e6,
         f"rays={len(sc.points)};spheres={m};tree_depth=4;"
         f"nodes={c_pr.nodes_traversed};"
         f"nodes_per_ray={c_pr.nodes_traversed/len(sc.points):.1f}")
    emit("table4/p_sphere", t_ps * 1e6,
         f"rays={m};spheres={len(sc.points)};tree_depth={tree.depth};"
         f"nodes={c_ps.nodes_traversed};"
         f"nodes_per_ray={c_ps.nodes_traversed/m:.1f};"
         f"speedup_vs_pray={t_pr/t_ps:.1f}x")
    # early-exit node saving (paper: 6x fewer nodes)
    _, _, c_ne = ball_query_psphere(tree, queries, radius, k,
                                    early_exit=False)
    emit("table4/early_exit", 0.0,
         f"nodes_with_ee={c_ps.nodes_traversed};"
         f"nodes_without={c_ne.nodes_traversed};"
         f"ratio={c_ne.nodes_traversed/max(c_ps.nodes_traversed,1):.1f}x")


# ---------------------------------------------------------------------------
# Fig. 17 — ball query radius sweep
# ---------------------------------------------------------------------------

def fig17_radius_sweep(S):
    sc, tree, _ = get_scene("cubby", S["points"], S["depth"], 1, 2)
    rs = np.random.RandomState(1)
    queries = jnp.asarray(
        sc.points[rs.choice(len(sc.points), 256, replace=False)])
    base = None
    for r in (0.05, 0.1, 0.2, 0.4):
        t = time.perf_counter()
        _, _, c = ball_query_psphere(tree, queries, r, 32)
        dt = time.perf_counter() - t
        if base is None:
            base = dt
        emit(f"fig17/psphere_r{r}", dt * 1e6,
             f"rel={dt/base:.2f};nodes={c.nodes_traversed}")


# ---------------------------------------------------------------------------
# Fig. 9 — sampling strategy: FPS vs random in the PointNet++ front end
# ---------------------------------------------------------------------------

def fig9_sampling(S):
    from repro.models.pointnet import init_pointnet, pointnet_encode
    rs = np.random.RandomState(0)
    cloud = jnp.asarray(rs.uniform(-1, 1, (2, 2048, 3)).astype(np.float32))
    params = init_pointnet(jax.random.PRNGKey(0))
    enc_fps = jax.jit(lambda p, c: pointnet_encode(p, c, "fps"))
    enc_rnd = jax.jit(lambda p, c, k: pointnet_encode(p, c, "random", k))
    key = jax.random.PRNGKey(1)
    t_fps = time_call(lambda: enc_fps(params, cloud).block_until_ready())
    t_rnd = time_call(
        lambda: enc_rnd(params, cloud, key).block_until_ready())
    pts = cloud[0]
    s_fps = float(sampling_spread(pts, farthest_point_sampling(pts, 256)))
    s_rnd = float(np.mean([float(sampling_spread(
        pts, random_sampling(jax.random.PRNGKey(s), 2048, 256)))
        for s in range(4)]))
    emit("fig9/fps", t_fps * 1e6, f"spread={s_fps:.4f}")
    emit("fig9/random", t_rnd * 1e6,
         f"spread={s_rnd:.4f};latency_saving={1-t_rnd/t_fps:.2f};"
         f"note=collision_gate_catches_quality_loss")


# ---------------------------------------------------------------------------
# Fig. 18 — full pipeline latency breakdown with collision gate
# ---------------------------------------------------------------------------

def fig18_pipeline(S):
    from repro.core.pipeline import plan_with_collision_gate
    from repro.models.planner import init_planner, rollout
    sc, tree, _ = get_scene("tabletop", S["points"], S["depth"], 1, 2)
    engine = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    params = init_planner(jax.random.PRNGKey(0))
    rs = np.random.RandomState(2)
    cloud = jnp.asarray(
        sc.points[rs.choice(len(sc.points), 2048, replace=False)])
    q0 = jnp.asarray(rs.uniform(-1, 1, 7).astype(np.float32))
    goal = jnp.asarray(rs.uniform(-1, 1, 7).astype(np.float32))
    fns = {"rollout": jax.jit(rollout, static_argnames=("num_steps",
                                                        "sampling"))}
    for sampling in ("fps", "random"):
        plan_with_collision_gate(params, fns, engine, cloud, q0, goal,
                                 num_steps=20, sampling=sampling,
                                 key=jax.random.PRNGKey(3))
        res2 = plan_with_collision_gate(params, fns, engine, cloud, q0,
                                        goal, num_steps=20,
                                        sampling=sampling,
                                        key=jax.random.PRNGKey(3))
        t = res2.timings
        emit(f"fig18/{sampling}", (t["plan_s"] + t["collision_s"]) * 1e6,
             f"plan_us={t['plan_s']*1e6:.0f};"
             f"collision_us={t['collision_s']*1e6:.0f};"
             f"collision_free={res2.collision_free}")


# ---------------------------------------------------------------------------
# Fig. 19 — MCL (DeliBot) with dynamic engine switching
# ---------------------------------------------------------------------------

def fig19_mcl(S):
    from repro.core.mcl import (choose_engine, init_particles,
                                make_corridor_world, mcl_step,
                                ray_cast_dense)
    grid = make_corridor_world(jax.random.PRNGKey(0), size=192)
    angles = jnp.linspace(-np.pi, np.pi, 24, endpoint=False)
    true_pose = jnp.asarray([5.0, 5.0, 0.4])
    obs, _ = ray_cast_dense(grid, jnp.tile(true_pose[None, :2], (24, 1)),
                            true_pose[2] + angles, 6.0)
    iters = 8
    results = {}
    for policy in ("dense", "compacted", "dynamic"):
        st = init_particles(jax.random.PRNGKey(1), grid, 192)
        total, cells_hist = 0.0, 1e9
        for it in range(iters):
            eng = (policy if policy != "dynamic"
                   else choose_engine(cells_hist, threshold=60.0))
            st, stats = mcl_step(jax.random.PRNGKey(10 + it), st, grid, obs,
                                 angles, jnp.zeros(3), eng, sigma=0.5)
            cells_hist = stats["cells_per_ray"]
            if it > 0:                     # skip compile iteration
                total += stats["time_s"]
        results[policy] = total
        emit(f"fig19/{policy}", total / max(iters - 1, 1) * 1e6,
             f"cumulative_s={total:.3f}")
    best_fixed = min(results["dense"], results["compacted"])
    emit("fig19/dynamic_vs_best_fixed", 0.0,
         f"speedup={best_fixed/max(results['dynamic'],1e-9):.2f}x")


# ---------------------------------------------------------------------------
# Batched throughput — whole trajectory batch in ONE compiled device call
# vs the host-loop engine iterating trajectory by trajectory
# ---------------------------------------------------------------------------

def batched_throughput(S):
    _, tree, obbs = get_scene("cubby", S["points"], S["depth"], S["trajs"],
                              S["wps"])
    # (trajs, wps*7) batch: one lane per trajectory, early exit per lane.
    B = S["trajs"]
    M = obbs.n // B
    batch = OBBs(center=obbs.center.reshape(B, M, 3),
                 half=obbs.half.reshape(B, M, 3),
                 rot=obbs.rot.reshape(B, M, 3, 3))
    host = CollisionEngine(tree, EngineConfig(mode="wavefront_host"))
    dev = CollisionEngine(tree, EngineConfig(mode="wavefront"))
    fused = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
    persist = CollisionEngine(tree, EngineConfig(mode="wavefront_persistent"))
    col_h, _ = host.query_batched(batch)          # warm + reference
    col_d, cd0 = dev.query_batched(batch)         # compile (cold counters)
    col_f, cf0 = fused.query_batched(batch)
    col_p, cp0 = persist.query_batched(batch)
    assert (col_d == col_h).all(), "batched verdict mismatch"
    assert (col_f == col_h).all(), "batched fused verdict mismatch"
    assert (col_p == col_h).all(), "batched persistent verdict mismatch"
    n = B * M
    walls_hd = time_group({"h": lambda: host.query_batched(batch),
                           "d": lambda: dev.query_batched(batch)},
                          repeats=5)
    walls_df = time_group({"d": lambda: dev.query_batched(batch),
                           "f": lambda: fused.query_batched(batch),
                           "p": lambda: persist.query_batched(batch)},
                          repeats=15)
    t_h = walls_hd["h"]
    t_d = min(walls_hd["d"], walls_df["d"])
    t_f = walls_df["f"]
    t_p = walls_df["p"]
    emit("batched/engine=wavefront_host", t_h * 1e6,
         f"queries={n};qps={n/max(t_h, 1e-9):.0f}")
    emit("batched/engine=device_wavefront", t_d * 1e6,
         f"queries={n};qps={n/max(t_d, 1e-9):.0f};"
         f"speedup_vs_host={t_h/max(t_d, 1e-9):.1f}x;"
         f"collisions={int(col_d.sum())};"
         f"cold_escalations={cd0.escalations}")
    emit("batched/engine=device_fused", t_f * 1e6,
         f"queries={n};qps={n/max(t_f, 1e-9):.0f};"
         f"speedup_vs_host={t_h/max(t_f, 1e-9):.1f}x;"
         f"speedup_vs_unfused={t_d/max(t_f, 1e-9):.2f}x;"
         f"collisions={int(col_f.sum())};"
         f"cold_escalations={cf0.escalations}")
    emit("batched/engine=device_persistent", t_p * 1e6,
         f"queries={n};qps={n/max(t_p, 1e-9):.0f};"
         f"speedup_vs_host={t_h/max(t_p, 1e-9):.1f}x;"
         f"speedup_vs_fused={t_f/max(t_p, 1e-9):.2f}x;"
         f"collisions={int(col_p.sum())};"
         f"cold_escalations={cp0.escalations}")
    tc = traversal_cache_info()
    emit("batched/traversal_cache", 0.0,
         f"entries={tc['entries']};hits={tc['hits']};"
         f"misses={tc['misses']};traces={sum(tc['traces'].values())}")


# ---------------------------------------------------------------------------
# Ragged multi-scene frontier — mixed-size scene batch in ONE compiled call
# vs the padded-vmap path that pays the widest scene for every lane
# ---------------------------------------------------------------------------

def ragged_scenes(S):
    from repro.core.octree import build_octree as _build
    from repro.core.wavefront import query_batched_scenes
    rs = np.random.RandomState(0)
    M = max(S["trajs"] * 4, 8)
    depth = max(S["depth"] - 2, 3)

    from repro.core.geometry import random_obbs

    def scene_set(sizes):
        trees, sets = [], []
        for i, n_pts in enumerate(sizes):
            pts = rs.uniform(-1, 1, (n_pts, 3)).astype(np.float32)
            trees.append(_build(pts, depth=depth))
            sets.append(random_obbs(jax.random.PRNGKey(i), M))
        stack = OBBs(center=jnp.stack([o.center for o in sets]),
                     half=jnp.stack([o.half for o in sets]),
                     rot=jnp.stack([o.rot for o in sets]))
        return trees, stack

    small = S["points"] // 16
    trees_s, stack_s = scene_set([small] * 3)             # small-only batch
    trees_m, stack_m = scene_set([small] * 3 + [S["points"]])   # + one big

    # The persistent arms force the Pallas kernel (interpret off-TPU): the
    # ragged mixed-size batch streams per-scene sub-extent windows, and
    # ragged_streamed additionally pins the streamed layout so the format
    # chooser picks a compressed row format.  Both must stay on the kernel
    # arm (ref_arm_fallbacks == 0) — no silent jnp-ref downgrade.
    arms = {
        "padded_wavefront": EngineConfig(mode="wavefront"),
        "ragged_persistent": EngineConfig(mode="wavefront_persistent",
                                          use_pallas_traverse=True),
        "ragged_streamed": EngineConfig(mode="wavefront_persistent",
                                        use_pallas_traverse=True,
                                        stream_meta=True),
    }
    walls, verdicts, counters = {}, {}, {}
    for name, cfg in arms.items():
        for tag, (trees, stack) in (("small", (trees_s, stack_s)),
                                    ("mixed", (trees_m, stack_m))):
            col, c = query_batched_scenes(trees, stack, cfg)  # warm/compile
            verdicts[(name, tag)] = np.asarray(col)
            counters[(name, tag)] = c
            walls[(name, tag)] = time_group(
                {"q": lambda t=trees, st=stack, c=cfg:
                 query_batched_scenes(t, st, c)}, repeats=7)["q"]
    for name in arms:
        for tag in ("small", "mixed"):
            assert (verdicts[(name, tag)]
                    == verdicts[("padded_wavefront", tag)]).all(), (name, tag)
            if name != "padded_wavefront":
                assert counters[(name, tag)].ref_arm_fallbacks == 0, \
                    f"ragged/{name}/{tag} fell back to the jnp ref arm"
        t_small, t_mixed = walls[(name, "small")], walls[(name, "mixed")]
        c = counters[(name, "mixed")]
        # padding evidence: how much does ONE big scene inflate the batch?
        emit(f"ragged/{name}", t_mixed * 1e6,
             f"small_batch_us={t_small*1e6:.0f};"
             f"big_scene_cost={t_mixed/max(t_small, 1e-9):.2f}x;"
             f"nodes={c.nodes_traversed};"
             f"meta_rows_streamed={c.meta_rows_streamed};"
             f"meta_bytes_streamed={c.meta_bytes_streamed};"
             f"ref_arm_fallbacks={c.ref_arm_fallbacks}")
    assert counters[("ragged_streamed", "mixed")].meta_rows_streamed > 0, \
        "ragged_streamed must stream metadata windows"
    t_pad, t_rag = (walls[("padded_wavefront", "mixed")],
                    walls[("ragged_persistent", "mixed")])
    pad_infl = (walls[("padded_wavefront", "mixed")]
                / max(walls[("padded_wavefront", "small")], 1e-9))
    rag_infl = (walls[("ragged_persistent", "mixed")]
                / max(walls[("ragged_persistent", "small")], 1e-9))
    emit("ragged/headline", 0.0,
         f"ragged_vs_padded={t_pad/max(t_rag, 1e-9):.2f}x;"
         f"pad_inflation={pad_infl:.2f}x;"
         f"ragged_inflation={rag_infl:.2f}x")


# ---------------------------------------------------------------------------
# fig_edges — PRM-style batch edge validation: swept-edge (CCD) first-hit
# bisection vs dense waypoint sampling at equal resolution
# ---------------------------------------------------------------------------

def fig_edges(S):
    from repro.core.pipeline import check_edges, check_trajectories
    from repro.core.sweep import edge_waypoints
    from repro.data.robotics import PANDA_JOINT_HI, PANDA_JOINT_LO
    sc, tree, _ = get_scene("cubby", S["points"], S["depth"], S["trajs"],
                            S["wps"])
    rs = np.random.RandomState(0)
    E, R = S["edges"], S["edge_res"]
    jlo, jhi = PANDA_JOINT_LO, PANDA_JOINT_HI
    # PRM edges: short joint-space hops between neighboring samples.
    qf = rs.uniform(jlo, jhi, (E, 7)).astype(np.float32)
    qt = np.clip(qf + rs.uniform(-0.35, 0.35, (E, 7)).astype(np.float32),
                 jlo, jhi)
    base = sc.robot_base
    # The CCD figure runs the persistent megakernel arm: owner-group tiling
    # puts each segment's links (and each edge's racing sub-intervals) in
    # one tile, and the in-kernel payload min-fold retires sibling lanes
    # the moment a group's verdict lands.  use_pallas_traverse=True forces
    # the Pallas kernel even off-TPU (interpret mode) — this figure must
    # never silently downgrade to the jnp ref arm (ref_arm_fallbacks gate).
    engine = CollisionEngine(tree, EngineConfig(
        mode="wavefront_persistent", use_pallas_traverse=True))
    # No-early-exit baseline (fig11's staged_noexit arm, the paper's
    # TTA-style machine): same bisection rounds, but every lane traverses
    # to frontier exhaustion — no in-traversal exit of any kind.
    noexit = CollisionEngine(tree, EngineConfig(mode="staged_noexit"))
    wps = jnp.asarray(edge_waypoints(qf, qt, R))

    res = check_edges(engine, qf, qt, resolution=R, base_pos=base)   # warm
    flags, cd = check_trajectories(engine, wps, base_pos=base)       # warm
    res_nx = check_edges(noexit, qf, qt, resolution=R, base_pos=base)
    # Owner-only ablation: same kernel engine, but owner groups / payload
    # minima reduce on the host AFTER boolean traversals (per-query exits
    # stay) — isolates the in-kernel owner-group early exit alone.
    res_ne = check_edges(engine, qf, qt, resolution=R, base_pos=base,
                         in_traversal_exit=False)
    dense = np.asarray(flags).any(axis=1)
    assert (~dense | res.collide).all(), "swept must upper-bound dense"
    for ab in (res_nx, res_ne):
        assert (ab.collide == res.collide).all() and \
            (ab.first_hit == res.first_hit).all(), \
            "no-exit ablation changed CCD verdicts"
    cs, cn, cx = res.counters, res_ne.counters, res_nx.counters
    assert cs.ref_arm_fallbacks == 0 and cd.ref_arm_fallbacks == 0, \
        "fig_edges must run the Pallas kernel arm (ref-arm fallback seen)"
    exit_ratio = cx.nodes_traversed / max(cs.nodes_traversed, 1)
    owner_ratio = cn.nodes_traversed / max(cs.nodes_traversed, 1)
    assert exit_ratio >= 1.5, \
        f"in-kernel early exit saved only {exit_ratio:.2f}x nodes " \
        f"({cx.nodes_traversed} no-exit vs {cs.nodes_traversed}), want 1.5x"
    walls = time_group(
        {"dense": lambda: check_trajectories(engine, wps, base_pos=base),
         "swept": lambda: check_edges(engine, qf, qt, resolution=R,
                                      base_pos=base),
         "noexit": lambda: check_edges(noexit, qf, qt, resolution=R,
                                       base_pos=base)},
        repeats=3)
    n_wp = E * (R + 1)
    emit("fig_edges/dense_waypoints", walls["dense"] * 1e6,
         f"edges={E};res={R};waypoints={n_wp};"
         f"axis_exec={cd.axis_tests_executed};nodes={cd.nodes_traversed};"
         f"colliding_edges={int(dense.sum())}")
    hits = res.first_hit[res.collide]
    emit("fig_edges/swept", walls["swept"] * 1e6,
         f"edges={E};res={R};axis_exec={cs.axis_tests_executed};"
         f"nodes={cs.nodes_traversed};"
         f"colliding_edges={int(res.collide.sum())};"
         f"mean_first_hit={float(hits.mean()) if hits.size else -1:.3f};"
         f"ref_arm_fallbacks={cs.ref_arm_fallbacks}")
    emit("fig_edges/owner_tiled", walls["swept"] * 1e6,
         f"edges={E};res={R};arm=persistent_kernel;"
         f"nodes_with_exit={cs.nodes_traversed};"
         f"nodes_no_exit={cx.nodes_traversed};"
         f"in_kernel_exit_node_saving={exit_ratio:.2f}x;"
         f"owner_exit_only_saving={owner_ratio:.2f}x;"
         f"ref_arm_fallbacks={cs.ref_arm_fallbacks}")
    emit("fig_edges/headline", 0.0,
         f"axis_tests_dense_over_swept="
         f"{cd.axis_tests_executed / max(cs.axis_tests_executed, 1):.2f}x;"
         f"nodes_dense_over_swept="
         f"{cd.nodes_traversed / max(cs.nodes_traversed, 1):.2f}x;"
         f"wall_dense_over_swept="
         f"{walls['dense'] / max(walls['swept'], 1e-9):.2f}x;"
         f"nodes_noexit_over_exit={exit_ratio:.2f}x")


# ---------------------------------------------------------------------------
# fig_bigscene — scene-size sweep past the metadata residency cap: the
# persistent megakernel switches to streamed HBM->VMEM metadata windows
# (DESIGN.md §3) instead of falling back to the per-level fused arm, and
# must hold its wall advantage there
# ---------------------------------------------------------------------------

def fig_bigscene(S):
    from repro.core.geometry import random_obbs
    from repro.kernels.persist.ops import meta_stream_bytes, meta_table_bytes
    rs = np.random.RandomState(5)
    depth = min(S["depth"] + 1, 8)
    M = max(S["trajs"] * S["wps"], 32)
    # Two uniform clouds: 1x sits at the residency limit (the budget is
    # set to exactly its table size), 6x points lands >= 4x the limit in
    # occupied nodes at this depth.
    trees = {}
    for tag, n_pts in (("small", S["points"]), ("big", 6 * S["points"])):
        pts = rs.uniform(-1, 1, (n_pts, 3)).astype(np.float32)
        trees[tag] = build_octree(pts, depth=depth,
                                  scene_lo=np.full(3, -1.0, np.float32),
                                  scene_size=2.0)
    table_bytes = {tag: meta_table_bytes(
        depth, max(len(l.codes) for l in t.levels))
        for tag, t in trees.items()}
    budget = table_bytes["small"]
    speedups = []
    for tag, tree in trees.items():
        obbs = random_obbs(jax.random.PRNGKey(11), M)
        fused = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))
        # fp32 pin: this figure isolates the LAYOUT switch (PR 5 baseline);
        # fig_compress sweeps the row formats on the same scenes.  The
        # kernel arm is forced (interpret off-TPU): past the residency
        # budget the megakernel streams fixed-size sub-level windows
        # instead of downgrading to the jnp ref arm.
        persist = CollisionEngine(tree, EngineConfig(
            mode="wavefront_persistent", vmem_budget=budget,
            meta_format="fp32", use_pallas_traverse=True))
        col_f, _ = fused.query(obbs)                  # compile + reference
        col_p, cp = persist.query(obbs)
        assert (np.asarray(col_p) == np.asarray(col_f)).all(), tag
        assert cp.ref_arm_fallbacks == 0, \
            f"fig_bigscene/{tag} fell back to the jnp ref arm"
        walls = time_group({"fused": lambda: fused.query(obbs),
                            "persist": lambda: persist.query(obbs)},
                           repeats=7)
        speedups.append(walls["fused"] / max(walls["persist"], 1e-9))
        emit(f"fig_bigscene/{tag}/fused", walls["fused"] * 1e6,
             f"queries={M};depth={depth};"
             f"table_bytes={table_bytes[tag]}")
        n_max = max(len(l.codes) for l in tree.levels)
        emit(f"fig_bigscene/{tag}/persistent", walls["persist"] * 1e6,
             f"queries={M};layout={persist.meta_layout};"
             f"meta_rows_streamed={cp.meta_rows_streamed};"
             f"meta_bytes_streamed={cp.meta_bytes_streamed};"
             f"window_bytes={meta_stream_bytes(n_max)};"
             f"overflow={cp.frontier_overflow};"
             f"ref_arm_fallbacks={cp.ref_arm_fallbacks};"
             f"speedup_vs_fused={speedups[-1]:.2f}x")
    emit("fig_bigscene/headline", 0.0,
         f"geomean_speedup_vs_fused="
         f"{float(np.exp(np.mean(np.log(speedups)))):.2f}x;"
         f"bigscene_over_budget="
         f"{table_bytes['big']/max(budget, 1):.1f}x;"
         f"mode_stays=wavefront_persistent")


# ---------------------------------------------------------------------------
# fig_compress — metadata row-format sweep (DESIGN.md §3/§4): streamed
# traversal on the fig_bigscene over-budget scene at fp32 vs bf16 vs u8
# rows.  Verdicts must be bitwise-identical; u8 must stream >= 3x fewer
# metadata bytes (it streams exactly 4x fewer: the row COUNT is
# format-independent and only the row width changes) at no wall cost.
# CI requires this row family (--require fig_compress).
# ---------------------------------------------------------------------------

def fig_compress(S):
    from repro.core.geometry import random_obbs
    from repro.kernels.persist.ops import (META_FORMAT_BYTES,
                                           meta_stream_bytes,
                                           meta_table_bytes)
    rs = np.random.RandomState(5)
    depth = min(S["depth"] + 1, 8)
    M = max(S["trajs"] * S["wps"], 32)
    # The fig_bigscene over-budget scene: 6x points at depth+1, budget set
    # to the small (1x) cloud's fp32 table so this one always streams.
    small = build_octree(
        rs.uniform(-1, 1, (S["points"], 3)).astype(np.float32), depth=depth,
        scene_lo=np.full(3, -1.0, np.float32), scene_size=2.0)
    tree = build_octree(
        rs.uniform(-1, 1, (6 * S["points"], 3)).astype(np.float32),
        depth=depth, scene_lo=np.full(3, -1.0, np.float32), scene_size=2.0)
    budget = meta_table_bytes(depth, max(len(l.codes) for l in small.levels))
    n_max = max(len(l.codes) for l in tree.levels)
    obbs = random_obbs(jax.random.PRNGKey(11), M)
    ref_v, _ = CollisionEngine(
        tree, EngineConfig(mode="wavefront_fused")).query(obbs)
    stats, walls_by_fmt = {}, {}
    for fmt in META_FORMATS:
        eng = CollisionEngine(tree, EngineConfig(
            mode="wavefront_persistent", vmem_budget=budget,
            stream_meta=True, meta_format=fmt))
        assert eng.meta_layout == "streamed", fmt
        v, c = eng.query(obbs)                        # compile + reference
        assert (np.asarray(v) == np.asarray(ref_v)).all(), fmt
        assert c.meta_bytes_streamed == \
            c.meta_rows_streamed * META_FORMAT_BYTES[fmt], fmt
        stats[fmt] = c
        walls_by_fmt[fmt] = eng
    walls = time_group(
        {fmt: (lambda e=eng: e.query(obbs))
         for fmt, eng in walls_by_fmt.items()}, repeats=7)
    for fmt in META_FORMATS:
        c = stats[fmt]
        emit(f"fig_compress/{fmt}", walls[fmt] * 1e6,
             f"queries={M};depth={depth};layout=streamed;"
             f"meta_rows_streamed={c.meta_rows_streamed};"
             f"meta_bytes_streamed={c.meta_bytes_streamed};"
             f"window_bytes={meta_stream_bytes(n_max, fmt)};"
             f"nodes={c.nodes_traversed};"
             f"bytes_vs_fp32="
             f"{stats['fp32'].meta_bytes_streamed / max(c.meta_bytes_streamed, 1):.2f}x")
    # Scene capacity per VMEM byte under the RESIDENT layout scales
    # inversely with row width: rows-per-budget at each format.
    cap = {fmt: budget // ((depth + 1) * META_FORMAT_BYTES[fmt])
           for fmt in META_FORMATS}
    emit("fig_compress/headline", 0.0,
         f"u8_bytes_reduction="
         f"{stats['fp32'].meta_bytes_streamed / max(stats['u8'].meta_bytes_streamed, 1):.2f}x;"
         f"rows_equal={int(stats['fp32'].meta_rows_streamed == stats['u8'].meta_rows_streamed)};"
         f"verdicts=bitwise_identical;"
         f"scene_per_vmem_byte_u8_vs_fp32={cap['u8'] / max(cap['fp32'], 1):.2f}x;"
         f"wall_u8_over_fp32={walls['u8'] / max(walls['fp32'], 1e-9):.2f}x")
    assert stats["fp32"].meta_bytes_streamed \
        >= 3 * stats["u8"].meta_bytes_streamed, "u8 must cut bytes >= 3x"


# ---------------------------------------------------------------------------
# fig_serve — collision service SLOs (DESIGN.md §6): N closed-loop clients
# submit small query sets through the continuous batcher over one engine;
# reports client-observed p50/p99 latency, queries/sec, and batching
# effectiveness.  CI requires this row family (--require fig_serve).
# ---------------------------------------------------------------------------

def fig_serve(S):
    from repro.launch.serve import run_service
    _, tree, _ = get_scene(ENVIRONMENTS[0], S["points"], S["depth"],
                           S["trajs"], S["wps"])
    rep = run_service(tree, clients=S["serve_clients"],
                      requests=S["serve_requests"],
                      queries_per_request=S["serve_queries"],
                      max_wait_ms=S["serve_max_wait_ms"])
    emit("fig_serve/latency", rep["p50_ms"] * 1e3,
         f"p50_ms={rep['p50_ms']:.2f};p99_ms={rep['p99_ms']:.2f};"
         f"clients={rep['clients']};requests={rep['requests']};"
         f"queries_per_request={S['serve_queries']};"
         f"max_wait_ms={S['serve_max_wait_ms']}")
    emit("fig_serve/throughput", 0.0,
         f"qps={rep['qps']:.0f};rps={rep['rps']:.0f};"
         f"queries={rep['queries']};wall_s={rep['wall_s']:.2f}")
    emit("fig_serve/batching", 0.0,
         f"launches={rep['launches']};"
         f"req_per_launch={rep['mean_requests_per_launch']:.1f};"
         f"live_q_per_launch={rep['mean_live_queries_per_launch']:.0f};"
         f"pad_fraction={rep['pad_fraction']:.2f}")
    # Reliability counters (DESIGN.md §7): all zero on this healthy run —
    # the row existing is the point (check_regression would flag a chaos-
    # mode counter leaking into the clean-path service).
    emit("fig_serve/reliability", 0.0,
         f"submitted={rep['submitted']};completed={rep['requests']};"
         f"failed={rep['failed']};rejected={rep['rejected']};"
         f"retried={rep['retried']};"
         f"deadline_missed={rep['deadline_missed']};"
         f"launch_splits={rep['launch_splits']};"
         f"worker_restarts={rep['worker_restarts']};"
         f"reshards={rep['reshards']};"
         f"shards_lost={rep['shards_lost']};"
         f"shard_rescales={rep['shard_rescales']};"
         f"degraded_launches={rep['degraded_launches']}")


# ---------------------------------------------------------------------------
# Roofline table (reads the dry-run artifacts; §Roofline source of truth)
# ---------------------------------------------------------------------------

def roofline_table(S):
    d = os.path.join(os.path.dirname(__file__), "results", "dryrun")
    if not os.path.isdir(d):
        emit("roofline/missing", 0.0, "run repro.lm.dryrun first")
        return
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(d, fn)) as f:
            r = json.load(f)
        if r.get("status") == "skipped":
            emit(f"roofline/{r['cell']}", 0.0, f"skipped:{r['reason'][:60]}")
            continue
        if r.get("status") != "ok":
            emit(f"roofline/{r['cell']}", 0.0, "ERROR")
            continue
        emit(f"roofline/{r['cell']}", r["compile_s"] * 1e6,
             f"compute_s={r['compute_s']:.3f};memory_s={r['memory_s']:.3f};"
             f"collective_s={r['collective_s']:.3f};"
             f"dominant={r['dominant']};"
             f"useful_ratio={r['useful_flops_ratio']:.2f};"
             f"mem_gb={r['peak_mem_per_chip']/1e9:.1f}")


BENCHES = {
    "fig9": fig9_sampling,
    "fig11": fig11_collision_speedup,
    "fig12": fig12_unit_utilization,
    "fig13": fig13_latency_sensitivity,
    "fig14": fig14_mpaccel,
    "fig15": fig15_exit_distribution,
    "fig16": fig16_energy,
    "table4": table4_pray_psphere,
    "fig17": fig17_radius_sweep,
    "fig18": fig18_pipeline,
    "fig19": fig19_mcl,
    "batched": batched_throughput,
    "ragged": ragged_scenes,
    "fig_edges": fig_edges,
    "fig_bigscene": fig_bigscene,
    "fig_compress": fig_compress,
    "fig_serve": fig_serve,
    "roofline": roofline_table,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale inputs (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny scene, 1 repeat, writes artifacts")
    ap.add_argument("--out", default=None,
                    help="directory for results.csv/results.json artifacts")
    args = ap.parse_args()
    if args.smoke:
        S = SMOKE_SCALE
        names = args.only.split(",") if args.only else list(SMOKE_BENCHES)
        if args.out is None:
            args.out = os.path.join(os.path.dirname(__file__), "results",
                                    "smoke")
    else:
        S = FULL_SCALE if args.full else SCALE
        names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    errors = 0
    for name in names:
        t0 = time.time()
        try:
            BENCHES[name](S)
        except Exception as e:  # keep the suite going
            import traceback
            traceback.print_exc()
            emit(f"{name}/ERROR", 0.0, repr(e)[:120])
            errors += 1
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if args.out:
        write_results(args.out)
        print(f"# artifacts written to {args.out}", flush=True)
    if args.smoke and errors:
        # CI gate: a smoke run with crashed benches must fail the job, not
        # just leave ERROR rows in the artifact.
        raise SystemExit(f"{errors} benchmark(s) failed")


if __name__ == "__main__":
    main()
