"""End-to-end driver: train the MpiNet-lite neural planner and evaluate it
WITH the explicit collision gate (the paper's full pipeline).

    PYTHONPATH=src python examples/train_planner.py            # ~2 min CPU
    PYTHONPATH=src python examples/train_planner.py --full     # ~100M params

Stages:
  1. Build a synthetic Cubby scene + octree (repro.data.robotics).
  2. Generate expert trajectories (goal-seeking with collision-aware
     rejection) and behaviour-clone the planner on (cloud, q, goal) -> dq.
  3. Evaluate rollouts; every plan passes through the explicit collision
     gate (core/pipeline.py) — the paper's safety argument in action.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import arm_link_obbs
from repro.core.octree import build_octree
from repro.core.pipeline import check_trajectory, plan_with_collision_gate
from repro.core.wavefront import CollisionEngine, EngineConfig
from repro.data.robotics import make_scene
from repro.models.planner import init_planner, planner_loss, rollout
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def make_expert_data(engine, scene, n_episodes, steps, rs):
    """Greedy goal-seeking expert with collision-aware step rejection."""
    lo = np.asarray([-2.8, -1.7, -2.8, -3.0, -2.8, 0.0, -2.8], np.float32)
    hi = np.asarray([2.8, 1.7, 2.8, -0.1, 2.8, 3.7, 2.8], np.float32)
    qs, goals, deltas = [], [], []
    for _ in range(n_episodes):
        q = rs.uniform(lo, hi).astype(np.float32)
        goal = rs.uniform(lo, hi).astype(np.float32)
        for _ in range(steps):
            step_v = np.clip(goal - q, -0.4, 0.4)
            cand = q + step_v
            flags, _ = check_trajectory(engine, jnp.asarray(cand[None]))
            if bool(np.asarray(flags)[0]):
                # collision: deflect with a random detour step
                step_v = rs.uniform(-0.3, 0.3, 7).astype(np.float32)
                cand = q + step_v
            qs.append(q.copy())
            goals.append(goal.copy())
            deltas.append(step_v.astype(np.float32))
            q = cand
    return (np.stack(qs), np.stack(goals), np.stack(deltas))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M-param planner, more data/steps")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--sampling", default="random",
                    choices=["random", "fps"])
    args = ap.parse_args()

    widen = 10 if args.full else 1           # 10x MLP ≈ 100M params
    n_eps = 24 if args.full else 6
    train_steps = args.steps or (300 if args.full else 60)
    cloud_pts = 1024

    rs = np.random.RandomState(0)
    print("building scene + octree ...")
    scene = make_scene("cubby", num_points=65536)
    tree = build_octree(scene.points, depth=6)
    engine = CollisionEngine(tree, EngineConfig(mode="wavefront_fused"))

    print("generating expert data ...")
    qs, goals, deltas = make_expert_data(engine, scene, n_eps, 20, rs)
    cloud = jnp.asarray(scene.points[
        rs.choice(len(scene.points), cloud_pts, replace=False)])
    n = len(qs)
    print(f"  {n} expert tuples")

    params = init_planner(jax.random.PRNGKey(0), widen=widen)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"planner params: {n_params/1e6:.1f}M")

    opt_cfg = OptConfig(lr=3e-4, warmup_steps=10, total_steps=train_steps,
                        weight_decay=0.01)
    opt_state = init_opt_state(params, opt_cfg)
    B = 32
    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, b, k: planner_loss(p, b, args.sampling, k)[0]))

    t0 = time.time()
    for step in range(train_steps):
        idx = rs.randint(0, n, B)
        batch = {"cloud": jnp.broadcast_to(cloud[None], (B,) + cloud.shape),
                 "q": jnp.asarray(qs[idx]), "goal": jnp.asarray(goals[idx]),
                 "expert_delta": jnp.asarray(deltas[idx])}
        loss, grads = loss_grad(params, batch,
                                jax.random.PRNGKey(1000 + step))
        params, opt_state, _ = adamw_update(params, grads, opt_state,
                                            opt_cfg)
        if step % max(train_steps // 10, 1) == 0:
            print(f"step {step:4d}  bc-loss {float(loss):.4f}  "
                  f"({time.time()-t0:.0f}s)")

    print("\nevaluating with the explicit collision gate ...")
    fns = {"rollout": jax.jit(rollout,
                              static_argnames=("num_steps", "sampling"))}
    ok, caught = 0, 0
    for ep in range(8):
        q0 = jnp.asarray(rs.uniform(-1.5, 1.5, 7).astype(np.float32))
        goal = jnp.asarray(rs.uniform(-1.5, 1.5, 7).astype(np.float32))
        res = plan_with_collision_gate(params, fns, engine, cloud, q0, goal,
                                       num_steps=20,
                                       sampling=args.sampling,
                                       key=jax.random.PRNGKey(ep))
        reached = float(np.linalg.norm(res.trajectory[-1]
                                       - np.asarray(goal))) < 0.5
        ok += res.collision_free and reached
        caught += not res.collision_free
        print(f"  ep{ep}: reached={reached} "
              f"collision_free={res.collision_free} "
              f"plan={res.timings['plan_s']*1e3:.0f}ms "
              f"gate={res.timings['collision_s']*1e3:.0f}ms")
    print(f"\nsuccess(collision-free & reached)={ok}/8; "
          f"unsafe plans caught by the gate={caught}/8")


if __name__ == "__main__":
    main()
