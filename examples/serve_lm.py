"""Batched LM serving demo: prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch hymba_1_5b

Runs the reduced config of any assigned arch (including the SSM/hybrid ones
whose decode is O(1)-state), reports prefill and per-token decode latency,
and verifies the decoded logits against the teacher-forced forward.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba_1_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=48)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.num_experts:
        cfg = cfg.replace(moe_capacity_factor=8.0)
    rng = np.random.RandomState(0)
    B, S = args.batch, args.prompt
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(rng.normal(
            size=(B, cfg.num_patches, cfg.d_model)).astype(np.float32))
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(
            size=(B, S, cfg.d_model)).astype(np.float32))

    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(api.make_prefill_fn(cfg, max_len=S + args.tokens + 8))
    decode = jax.jit(api.make_decode_fn(cfg))

    logits, caches = prefill(params, batch)      # compile
    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    offset = cfg.num_patches if cfg.family == "vlm" else 0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = jnp.asarray(S + offset + i, jnp.int32)
        logits, caches = decode(params, tok, pos, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_dec = time.perf_counter() - t0
    print(f"arch={cfg.name} B={B} prompt={S}")
    print(f"prefill: {t_prefill*1e3:.1f} ms")
    print(f"decode:  {t_dec/max(args.tokens-1,1)*1e3:.2f} ms/token "
          f"({args.tokens-1} steps)")
    print("greedy sample (seq 0):", [int(t[0]) for t in toks[:16]])


if __name__ == "__main__":
    main()
