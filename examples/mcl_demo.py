"""Monte Carlo Localization with dynamic engine switching (paper §VI-C).

    PYTHONPATH=src python examples/mcl_demo.py

A DeliBot-style robot localizes on a synthetic floor plan.  Each filter
iteration chooses between the dense masked marcher ("CUDA cores") and the
compacted wavefront marcher ("RoboCore") using the paper's heuristic: mean
cells traversed per ray in the previous iteration vs a threshold.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mcl import (choose_engine, init_particles,
                            make_corridor_world, mcl_step, ray_cast_dense)


def main():
    grid = make_corridor_world(jax.random.PRNGKey(0), size=192)
    angles = jnp.linspace(-np.pi, np.pi, 32, endpoint=False)
    true_pose = jnp.asarray([5.0, 5.0, 0.4])
    obs, _ = ray_cast_dense(grid, jnp.tile(true_pose[None, :2], (32, 1)),
                            true_pose[2] + angles, 6.0)
    st = init_particles(jax.random.PRNGKey(1), grid, 256)
    cells_per_ray = 1e9
    print(f"{'iter':>4} {'engine':>10} {'cells/ray':>10} {'ms':>8} "
          f"{'mean err (m)':>13}")
    for it in range(10):
        eng = choose_engine(cells_per_ray, threshold=60.0)
        st, stats = mcl_step(jax.random.PRNGKey(100 + it), st, grid, obs,
                             angles, jnp.zeros(3), eng, sigma=0.5)
        cells_per_ray = stats["cells_per_ray"]
        err = float(jnp.mean(jnp.linalg.norm(
            st.particles[:, :2] - true_pose[None, :2], axis=-1)))
        print(f"{it:>4} {stats['engine']:>10} {cells_per_ray:>10.1f} "
              f"{stats['time_s']*1e3:>8.1f} {err:>13.3f}")


if __name__ == "__main__":
    main()
