"""Collision-engine demo: every RoboGPU design arm on one scene.

    PYTHONPATH=src python examples/collision_demo.py [--env tabletop]

Prints, per arm, measured wall time + the architecture-neutral work model
(axis tests executed vs decoded, nodes traversed, modeled bytes, exit
histogram) — paper Figs. 11/12/15 in miniature.
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.octree import build_octree
from repro.core.wavefront import CollisionEngine, EngineConfig
from repro.data.robotics import make_scene, scene_trajectories


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="tabletop")
    ap.add_argument("--points", type=int, default=65536)
    ap.add_argument("--spheres", action="store_true",
                    help="enable MPAccel sphere pre-tests")
    args = ap.parse_args()

    scene = make_scene(args.env, num_points=args.points)
    tree = build_octree(scene.points, depth=6)
    obbs = scene_trajectories(scene, num_trajectories=6, waypoints=30)
    print(f"env={args.env}: {args.points} points, {tree.num_leaves} leaves, "
          f"{obbs.n} OBBs\n")
    header = (f"{'arm':<18} {'time(ms)':>9} {'nodes':>9} {'axis exec':>10} "
              f"{'decoded':>9} {'MB moved':>9} {'early%':>7}")
    print(header)
    print("-" * len(header))
    ref = None
    for mode in ("naive", "rta_like", "staged_noexit", "predicated",
                 "wavefront_host", "wavefront", "wavefront_fused",
                 "wavefront_persistent"):
        eng = CollisionEngine(tree, EngineConfig(mode=mode,
                                                 use_spheres=args.spheres))
        col, _ = eng.query(obbs)          # warmup/compile
        col, c = eng.query(obbs)
        if ref is None:
            ref = np.asarray(col)
        assert (np.asarray(col) == ref).all(), mode
        print(f"{mode:<18} {c.wall_time_s*1e3:>9.1f} "
              f"{c.nodes_traversed:>9} {c.axis_tests_executed:>10} "
              f"{c.axis_tests_decoded:>9} {c.bytes_moved/1e6:>9.1f} "
              f"{c.early_exit_fraction()*100:>6.1f}%")
    print(f"\ncolliding OBBs: {int(ref.sum())}/{len(ref)}")
    print("exit histogram (wavefront):", )
    eng = CollisionEngine(tree, EngineConfig(mode="wavefront",
                                             use_spheres=args.spheres))
    _, c = eng.query(obbs)
    names = (["bsphere", "isphere"] + [f"axis{i}" for i in range(15)]
             + ["full"])
    for name, count in zip(names, c.exit_histogram):
        if count:
            print(f"  {name:<8} {int(count)}")


if __name__ == "__main__":
    main()
