"""Quickstart: train a small LM with the full substrate in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch glm4_9b]

Uses the reduced (smoke) config of any assigned architecture, the real
sharded train step (on whatever devices exist), checkpointing, and the
prefetching loader.  Loss should drop visibly within 30 steps.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec, get_smoke_config
from repro.data.pipeline import batch_iterator
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models import api
from repro.parallel import sharding as shd
from repro.train import ft
from repro.train import optimizer as opt_mod
from repro.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    shape = ShapeSpec("quick", seq_len=64, global_batch=8, kind="train")
    opt_cfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=5,
                                total_steps=args.steps)
    mesh = make_host_mesh()
    step, pspecs, ospecs, bspecs = train_loop.make_sharded_train_step(
        cfg, mesh, opt_cfg, shape)
    with use_mesh(mesh):
        params = jax.device_put(api.init_params(cfg, jax.random.PRNGKey(0)),
                                shd.named(mesh, pspecs))
        opt_state = opt_mod.init_opt_state(params, opt_cfg)
        loader = ft.PrefetchingLoader(batch_iterator(cfg, shape))
        first = None
        for i in range(args.steps):
            batch = jax.device_put(loader.next_batch(),
                                   shd.named(mesh, bspecs))
            params, opt_state, m = step(params, opt_state, batch)
            loss = float(m["loss"])
            first = first or loss
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:3d}  loss {loss:.4f}")
        print(f"loss: {first:.3f} -> {loss:.3f} "
              f"({'improved' if loss < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
