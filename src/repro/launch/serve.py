"""Collision-serving harness: N concurrent planner clients, one engine.

The service stack (DESIGN.md §6): each synthetic client is a closed-loop
planner issuing small query sets (``plan_queries`` over a handful of link
OBBs); a :class:`repro.engine.batcher.RequestBatcher` coalesces whatever
is in flight into single engine launches — optionally sharded over the
device mesh (``--shards``) — and each client blocks on its ticket.  The
harness reports the SLO quantities (:data:`SLO_METRICS`): client-observed
p50/p99 latency and sustained queries/sec, plus batching effectiveness
and the reliability counters (:data:`RELIABILITY_METRICS`, DESIGN.md §7).

  PYTHONPATH=src python -m repro.launch.serve --clients 8 --requests 32
  ... --shards 4          # shard the coalesced pool over 4 devices
  ... --chaos             # inject faults; the SLO table must degrade
                          # gracefully: shed/retried/deadline-missed are
                          # counted, no ticket hangs, nothing is dropped

Chaos mode wraps the engine in :class:`repro.engine.faults.FaultyEngine`
(malformed plans, engine exceptions, launch stalls, simulated OOM at the
``FaultPlan`` rates) and runs every client with a deadline and a launch
timeout; every submit must still resolve — to a verdict or a typed
error — which the harness asserts by accounting for all of them.
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import List, Optional

import numpy as np

import jax

from repro.core.geometry import random_obbs
from repro.core.octree import Octree, build_octree
from repro.engine.batcher import (RequestBatcher, RequestStats, ServiceError,
                                  _pad_bucket)
from repro.engine.executor import CollisionEngine, EngineConfig
from repro.engine.faults import FaultPlan, FaultyEngine, poison_obbs
from repro.engine.plan import PlanValidationError, plan_queries

#: SLO quantities the harness reports (drift-guarded against the
#: DESIGN.md §6 SLO table): client-observed latency percentiles over
#: ``total_s`` (admission wait + shared engine call) and sustained
#: throughput over the timed window.
SLO_METRICS = ("p50_ms", "p99_ms", "qps")

#: Reliability counters in every report (drift-guarded against the
#: DESIGN.md §7 reliability table): requests shed at admission, transient
#: launch retries, pre-launch deadline kills, bisect-retry splits,
#: watchdog worker restarts, device-loss re-shard relaunches and the
#: shard devices lost to them, elastic shard-width rescales, and
#: launches served in declared degraded mode.  All zero on a healthy,
#: unloaded run.
RELIABILITY_METRICS = ("rejected", "retried", "deadline_missed",
                       "launch_splits", "worker_restarts", "reshards",
                       "shards_lost", "shard_rescales",
                       "degraded_launches")


def run_service(octree: Octree, *, clients: int = 8, requests: int = 32,
                queries_per_request: int = 12, max_batch: int = 1024,
                max_wait_ms: float = 2.0, mode: str = "wavefront_fused",
                shards: Optional[int] = None, seed: int = 0,
                engine: Optional[CollisionEngine] = None,
                deadline_ms: Optional[float] = None,
                max_queue: int = 4096,
                launch_timeout_s: Optional[float] = None,
                max_retries: int = 2,
                max_queue_work: Optional[int] = None,
                degrade_queue: Optional[int] = None,
                degraded_max_depth: Optional[int] = None,
                autoscale_shards: bool = False,
                target_p99_ms: Optional[float] = None,
                chaos: Optional[FaultPlan] = None) -> dict:
    """Drive ``clients`` closed-loop clients, ``requests`` requests each.

    Every request is ``queries_per_request`` random OBBs against the bound
    scene.  Returns a report dict: the :data:`SLO_METRICS` quantities over
    the requests that completed, requests/sec, batching effectiveness
    (mean requests and live queries per launch, pad fraction), the
    :data:`RELIABILITY_METRICS` counters, a per-error-type breakdown of
    failed requests, and the aggregate engine counters.

    With ``chaos`` set, the engine is wrapped in a
    :class:`repro.engine.faults.FaultyEngine` and each client corrupts a
    ``malformed_rate`` fraction of its own requests pre-submit; the
    harness asserts that EVERY submitted request resolved (verdict or
    typed error) — a hung or silently dropped ticket fails the run.
    """
    if engine is None:
        engine = CollisionEngine(octree, EngineConfig(mode=mode,
                                                      shards=shards))
    # Pre-generate every request's OBBs so the timed window measures the
    # service, not the client-side random number generation.
    keys = jax.random.split(jax.random.PRNGKey(seed), clients * requests)
    reqs = [random_obbs(k, queries_per_request) for k in keys]
    stats: List[List[RequestStats]] = [[] for _ in range(clients)]
    #: error-type name -> count, over every request that resolved typed.
    failures: dict = {}
    fail_lock = threading.Lock()
    errors: List[BaseException] = []

    # Warm the jit cache outside the timed window: the batcher pads every
    # pool to a pow2 bucket, so pre-executing one pool per bucket width
    # the coalesced launches can hit keeps compiles out of the latency
    # percentiles.  Warmup runs on the INNER engine so chaos injection
    # rates apply only to the timed window.
    top = _pad_bucket(min(max(clients * requests, 1) * queries_per_request,
                          max_batch + queries_per_request))
    width = _pad_bucket(1)
    while width <= top:
        engine.execute(plan_queries(
            random_obbs(jax.random.PRNGKey(seed + 1), width)))
        width <<= 1

    served = FaultyEngine(engine, chaos) if chaos is not None else engine

    def tally(e: BaseException) -> None:
        with fail_lock:
            failures[type(e).__name__] = \
                failures.get(type(e).__name__, 0) + 1

    with RequestBatcher(served, max_batch=max_batch,
                        max_wait_ms=max_wait_ms, max_queue=max_queue,
                        launch_timeout_s=launch_timeout_s,
                        max_retries=max_retries,
                        max_queue_work=max_queue_work,
                        degrade_queue=degrade_queue,
                        degraded_max_depth=degraded_max_depth,
                        autoscale_shards=autoscale_shards,
                        target_p99_ms=target_p99_ms) as batcher:
        batcher.submit(plan_queries(reqs[0])).result(timeout=600)
        launches0 = batcher.num_launches

        def client(ci: int):
            try:
                for ri in range(requests):
                    obbs = reqs[ci * requests + ri]
                    if chaos is not None:
                        kind = chaos.draw_malformed()
                        if kind is not None:
                            obbs = poison_obbs(obbs, kind)
                    try:
                        ticket = batcher.submit(plan_queries(obbs),
                                                deadline_ms=deadline_ms)
                        _, st = ticket.result(timeout=600)
                        stats[ci].append(st)
                    except (ServiceError, PlanValidationError) as e:
                        if chaos is None:
                            raise        # healthy runs tolerate nothing
                        tally(e)
                    except RuntimeError as e:
                        if chaos is None:
                            raise
                        tally(e)         # injected engine faults
            except BaseException as e:              # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        totals = batcher.totals
        launches = batcher.num_launches - launches0
    if errors:
        raise errors[0]

    flat = [s for per_client in stats for s in per_client]
    n_ok = len(flat)
    n_failed = sum(failures.values())
    n_sub = clients * requests
    # The §7 no-lost-tickets contract: every request either completed or
    # resolved to a typed error the client saw.
    assert n_ok + n_failed == n_sub, \
        f"{n_sub - n_ok - n_failed} requests vanished (hung or dropped)"
    lat_ms = np.asarray([s.total_s for s in flat]) * 1e3
    n_q = n_ok * queries_per_request
    return {
        "p50_ms": float(np.percentile(lat_ms, 50)) if n_ok else float("nan"),
        "p99_ms": float(np.percentile(lat_ms, 99)) if n_ok else float("nan"),
        "qps": n_q / wall,
        "rps": n_ok / wall,
        "wall_s": wall,
        "clients": clients,
        "submitted": n_sub,
        "requests": n_ok,
        "failed": n_failed,
        "failures": dict(failures),
        "queries": n_q,
        "launches": launches,
        "mean_requests_per_launch": float(np.mean(
            [s.batch_requests for s in flat])) if n_ok else 0.0,
        "mean_live_queries_per_launch": n_q / max(launches, 1),
        "pad_fraction": totals.pad_queries / max(totals.num_queries, 1),
        "rejected": totals.rejected,
        "retried": totals.retried,
        "deadline_missed": totals.deadline_missed,
        "launch_splits": totals.launch_splits,
        "worker_restarts": totals.worker_restarts,
        "reshards": totals.reshards,
        "shards_lost": totals.shards_lost,
        "shard_rescales": totals.shard_rescales,
        "degraded_launches": totals.degraded_launches,
        "degraded_requests": sum(1 for s in flat if s.degraded),
        "counters": totals,
    }


def default_fault_plan(seed: int = 0) -> FaultPlan:
    """The ``--chaos`` rates: every §7 failure mode fires on a smoke-sized
    run, while most launches stay healthy so the SLO percentiles remain
    meaningful.  ``device_loss_rate`` only bites on sharded engines (the
    injector seam lives inside ``_exec_sharded``); single-device chaos
    runs simply never draw it."""
    return FaultPlan(malformed_rate=0.08, exception_rate=0.06,
                     oom_rate=0.05, stall_rate=0.02, crash_rate=0.01,
                     device_loss_rate=0.03, stall_s=2.5, seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per client")
    ap.add_argument("--queries", type=int, default=12,
                    help="query OBBs per request")
    ap.add_argument("--max-batch", type=int, default=1024)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--mode", default="wavefront_fused")
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency budget (typed rejection)")
    ap.add_argument("--launch-timeout-s", type=float, default=None,
                    help="liveness bound on one engine call")
    ap.add_argument("--chaos", action="store_true",
                    help="inject faults (FaultPlan) and report graceful "
                         "degradation; implies a deadline and launch "
                         "timeout unless given explicitly")
    ap.add_argument("--max-queue-work", type=int, default=None,
                    help="work-based admission cap: shed when queued "
                         "scene_nodes x queries would exceed this")
    ap.add_argument("--degrade-queue", type=int, default=None,
                    help="queue depth past which launches run in declared "
                         "degraded mode instead of shedding")
    ap.add_argument("--degraded-max-depth", type=int, default=None,
                    help="traversal depth cap used by degraded launches "
                         "(default: scene depth - 1)")
    ap.add_argument("--autoscale", action="store_true",
                    help="let the batcher rescale EngineConfig.shards "
                         "between launches (sharded engines only)")
    ap.add_argument("--target-p99-ms", type=float, default=None,
                    help="latency SLO the autoscaler steers toward")
    ap.add_argument("--soak-s", type=float, default=None,
                    help="repeat the whole run (fresh seed each pass) "
                         "until this much wall time has elapsed; reports "
                         "aggregate per-pass reliability counters")
    args = ap.parse_args()
    deadline_ms = args.deadline_ms
    launch_timeout_s = args.launch_timeout_s
    if args.chaos:
        if deadline_ms is None:
            deadline_ms = 2000.0
        if launch_timeout_s is None:
            launch_timeout_s = 1.0

    rs = np.random.RandomState(args.seed)
    pts = rs.uniform(-1, 1, (args.points, 3)).astype(np.float32)
    tree = build_octree(pts, depth=args.depth)

    # --soak-s repeats the whole closed-loop run (fresh seed per pass, so
    # the chaos draw sequence differs) until the wall clock budget runs
    # out — the CI soak profile drives device-loss recovery through many
    # re-shard cycles instead of the one-shot PR smoke.
    t_start = time.perf_counter()
    passes = 0
    while True:
        seed = args.seed + passes
        chaos = default_fault_plan(seed) if args.chaos else None
        rep = run_service(
            tree, clients=args.clients, requests=args.requests,
            queries_per_request=args.queries, max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms, mode=args.mode,
            shards=args.shards, seed=seed, deadline_ms=deadline_ms,
            launch_timeout_s=launch_timeout_s,
            max_queue_work=args.max_queue_work,
            degrade_queue=args.degrade_queue,
            degraded_max_depth=args.degraded_max_depth,
            autoscale_shards=args.autoscale,
            target_p99_ms=args.target_p99_ms, chaos=chaos)
        passes += 1
        if args.soak_s is not None:
            print(f"--- soak pass {passes} "
                  f"({time.perf_counter() - t_start:.1f}s elapsed) ---")
        _print_report(rep)
        if args.soak_s is None or \
                time.perf_counter() - t_start >= args.soak_s:
            break
    if args.soak_s is not None:
        print(f"soak: {passes} passes, every submit resolved, "
              f"{time.perf_counter() - t_start:.1f}s total")


def _print_report(rep: dict) -> None:
    print(f"served {rep['requests']}/{rep['submitted']} requests "
          f"/ {rep['queries']} queries from {rep['clients']} clients "
          f"in {rep['wall_s']:.2f}s")
    print(f"latency p50 {rep['p50_ms']:.2f} ms  p99 {rep['p99_ms']:.2f} ms")
    print(f"throughput {rep['qps']:.0f} queries/s  {rep['rps']:.0f} req/s")
    print(f"batching: {rep['launches']} launches, "
          f"{rep['mean_requests_per_launch']:.1f} req/launch, "
          f"pad fraction {rep['pad_fraction']:.2f}")
    print(f"reliability: rejected {rep['rejected']}  "
          f"retried {rep['retried']}  "
          f"deadline_missed {rep['deadline_missed']}  "
          f"launch_splits {rep['launch_splits']}  "
          f"worker_restarts {rep['worker_restarts']}  "
          f"reshards {rep['reshards']}  "
          f"shards_lost {rep['shards_lost']}  "
          f"shard_rescales {rep['shard_rescales']}  "
          f"degraded_launches {rep['degraded_launches']}")
    if rep["degraded_requests"]:
        print(f"degraded (declared, conservative-superset verdicts): "
              f"{rep['degraded_requests']} requests")
    if rep["failed"]:
        kinds = ", ".join(f"{k}={v}" for k, v in
                          sorted(rep["failures"].items()))
        print(f"failed typed (no hangs, no drops): {rep['failed']} "
              f"[{kinds}]")


if __name__ == "__main__":
    main()
