"""Serving launcher: prefill + batched greedy decode with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.models import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full
           else get_smoke_config(args.arch))
    rng = np.random.RandomState(0)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(rng.normal(
            size=(B, cfg.num_patches, cfg.d_model)).astype(np.float32))
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(
            size=(B, S, cfg.d_model)).astype(np.float32))

    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(api.make_prefill_fn(cfg, max_len=S + args.tokens + 8))
    decode = jax.jit(api.make_decode_fn(cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    offset = cfg.num_patches if cfg.family == "vlm" else 0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = jnp.asarray(S + offset + i, jnp.int32)
        logits, caches = decode(params, tok, pos, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    toks = np.stack([np.asarray(t) for t in out], 1)
    print(f"prefill {B}x{S}: {t_prefill*1e3:.1f} ms; "
          f"decode {args.tokens} steps: {t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.tokens-1,1)*1e3:.1f} ms/tok)")
    print("sample:", toks[0][:16].tolist())


if __name__ == "__main__":
    main()
