"""Collision-serving harness: N concurrent planner clients, one engine.

The service stack (DESIGN.md §6): each synthetic client is a closed-loop
planner issuing small query sets (``plan_queries`` over a handful of link
OBBs); a :class:`repro.engine.batcher.RequestBatcher` coalesces whatever
is in flight into single engine launches — optionally sharded over the
device mesh (``--shards``) — and each client blocks on its ticket.  The
harness reports the SLO quantities (:data:`SLO_METRICS`): client-observed
p50/p99 latency and sustained queries/sec, plus batching effectiveness.

  PYTHONPATH=src python -m repro.launch.serve --clients 8 --requests 32
  ... --shards 4          # shard the coalesced pool over 4 devices
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import List, Optional

import numpy as np

import jax

from repro.core.geometry import random_obbs
from repro.core.octree import Octree, build_octree
from repro.engine.batcher import RequestBatcher, RequestStats, _pad_bucket
from repro.engine.executor import CollisionEngine, EngineConfig
from repro.engine.plan import plan_queries

#: SLO quantities the harness reports (drift-guarded against the
#: DESIGN.md §6 SLO table): client-observed latency percentiles over
#: ``total_s`` (admission wait + shared engine call) and sustained
#: throughput over the timed window.
SLO_METRICS = ("p50_ms", "p99_ms", "qps")


def run_service(octree: Octree, *, clients: int = 8, requests: int = 32,
                queries_per_request: int = 12, max_batch: int = 1024,
                max_wait_ms: float = 2.0, mode: str = "wavefront_fused",
                shards: Optional[int] = None, seed: int = 0,
                engine: Optional[CollisionEngine] = None) -> dict:
    """Drive ``clients`` closed-loop clients, ``requests`` requests each.

    Every request is ``queries_per_request`` random OBBs against the bound
    scene.  Returns a report dict: the :data:`SLO_METRICS` quantities,
    requests/sec, batching effectiveness (mean requests and live queries
    per launch, pad fraction), and the aggregate engine counters.
    """
    if engine is None:
        engine = CollisionEngine(octree, EngineConfig(mode=mode,
                                                      shards=shards))
    # Pre-generate every request's OBBs so the timed window measures the
    # service, not the client-side random number generation.
    keys = jax.random.split(jax.random.PRNGKey(seed), clients * requests)
    plans = [plan_queries(random_obbs(k, queries_per_request))
             for k in keys]
    stats: List[List[RequestStats]] = [[] for _ in range(clients)]
    errors: List[BaseException] = []

    # Warm the jit cache outside the timed window: the batcher pads every
    # pool to a pow2 bucket, so pre-executing one pool per bucket width
    # the coalesced launches can hit keeps compiles out of the latency
    # percentiles.
    top = _pad_bucket(min(max(clients * requests, 1) * queries_per_request,
                          max_batch + queries_per_request))
    width = _pad_bucket(1)
    while width <= top:
        engine.execute(plan_queries(
            random_obbs(jax.random.PRNGKey(seed + 1), width)))
        width <<= 1

    with RequestBatcher(engine, max_batch=max_batch,
                        max_wait_ms=max_wait_ms) as batcher:
        batcher.submit(plans[0]).result(timeout=600)   # thread-path warmup
        launches0 = batcher.num_launches

        def client(ci: int):
            try:
                for ri in range(requests):
                    ticket = batcher.submit(plans[ci * requests + ri])
                    _, st = ticket.result(timeout=600)
                    stats[ci].append(st)
            except BaseException as e:              # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        totals = batcher.totals
        launches = batcher.num_launches - launches0
    if errors:
        raise errors[0]

    flat = [s for per_client in stats for s in per_client]
    lat_ms = np.asarray([s.total_s for s in flat]) * 1e3
    n_req = len(flat)
    n_q = n_req * queries_per_request
    mean_req_per_launch = np.mean([s.batch_requests for s in flat])
    return {
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "qps": n_q / wall,
        "rps": n_req / wall,
        "wall_s": wall,
        "clients": clients,
        "requests": n_req,
        "queries": n_q,
        "launches": launches,
        "mean_requests_per_launch": float(mean_req_per_launch),
        "mean_live_queries_per_launch": n_q / max(launches, 1),
        "pad_fraction": totals.pad_queries / max(totals.num_queries, 1),
        "counters": totals,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per client")
    ap.add_argument("--queries", type=int, default=12,
                    help="query OBBs per request")
    ap.add_argument("--max-batch", type=int, default=1024)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--mode", default="wavefront_fused")
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rs = np.random.RandomState(args.seed)
    pts = rs.uniform(-1, 1, (args.points, 3)).astype(np.float32)
    tree = build_octree(pts, depth=args.depth)
    rep = run_service(
        tree, clients=args.clients, requests=args.requests,
        queries_per_request=args.queries, max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms, mode=args.mode, shards=args.shards,
        seed=args.seed)
    print(f"served {rep['requests']} requests / {rep['queries']} queries "
          f"from {rep['clients']} clients in {rep['wall_s']:.2f}s")
    print(f"latency p50 {rep['p50_ms']:.2f} ms  p99 {rep['p99_ms']:.2f} ms")
    print(f"throughput {rep['qps']:.0f} queries/s  {rep['rps']:.0f} req/s")
    print(f"batching: {rep['launches']} launches, "
          f"{rep['mean_requests_per_launch']:.1f} req/launch, "
          f"pad fraction {rep['pad_fraction']:.2f}")


if __name__ == "__main__":
    main()
