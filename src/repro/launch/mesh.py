"""Production mesh construction.

A FUNCTION (not module-level state) so importing this module never touches
jax device initialization.  Single pod: 256 chips as (16 data, 16 model);
multi-pod: 2 pods x 256 chips as (2 pod, 16 data, 16 model) with `pod` as
an extra FSDP/DP axis (DCN-ish) — the dry-run proves the `pod` axis shards.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    jax >= 0.5 spells this ``jax.set_mesh``; on older versions the Mesh
    object itself is the context manager.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh
