"""Collision engine: query-plan lowering + mode-dispatching executor.

``plan`` lowers every front-end batch shape (single set, (B, M) batch,
ragged multi-scene, trajectory, swept edge) to one canonical flat pool
with scene / owner / payload lanes; ``executor`` owns mode dispatch, the
traversal cache, capacity escalation, and counter assembly for every plan
alike.  ``repro.core.wavefront`` re-exports this package's public names
for compatibility.

The typed :class:`ServiceError` hierarchy the batcher resolves tickets
with (DESIGN.md §7) is exported here too, so clients catch
``repro.engine.Overloaded`` / ``DeviceLost`` / ... without importing
``engine.batcher`` internals.
"""
from repro.engine.batcher import (BatcherClosed, DeadlineExceeded,
                                  DeviceLost, LaunchStalled, Overloaded,
                                  RequestBatcher, RequestStats,
                                  ServiceError, WorkerDied)
from repro.engine.executor import (CSR_MODES, DEPTH_CAP_MODES, DEVICE_MODES,
                                   MODES, CollisionEngine, EngineConfig,
                                   frontier_capacity_bound,
                                   query_batched_scenes,
                                   traversal_cache_info)
from repro.engine.plan import (PAYLOAD_INF, QueryPlan, WORKLOADS, plan_batch,
                               plan_edges, plan_queries, plan_scenes,
                               plan_trajectory)

__all__ = [
    "BatcherClosed", "CSR_MODES", "CollisionEngine", "DEPTH_CAP_MODES",
    "DEVICE_MODES", "DeadlineExceeded", "DeviceLost", "EngineConfig",
    "LaunchStalled", "MODES", "Overloaded", "PAYLOAD_INF", "QueryPlan",
    "RequestBatcher", "RequestStats", "ServiceError", "WORKLOADS",
    "WorkerDied", "frontier_capacity_bound", "plan_batch", "plan_edges",
    "plan_queries", "plan_scenes", "plan_trajectory",
    "query_batched_scenes", "traversal_cache_info",
]
