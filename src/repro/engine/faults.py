"""Fault injection for the collision service (DESIGN.md §7).

The reliability layer (validation at submit, bisect-retry, deadlines,
backpressure, the launch watchdog — see :mod:`repro.engine.batcher`)
only earns trust if it is exercised against the failures it claims to
contain.  This module is the chaos harness: a :class:`FaultPlan`
describes WHAT to inject (malformed plans, engine exceptions, artificial
launch stalls, simulated device OOM) and at WHAT rate, and a
:class:`FaultyEngine` wraps any :class:`repro.engine.executor.
CollisionEngine` to apply those faults at the execute boundary — the
exact seam where a real device failure (XLA RESOURCE_EXHAUSTED, a hung
collective, a poisoned launch) would surface to the service.

Determinism: every injection decision comes from one seeded
``numpy.random.RandomState``, so a chaos test that fails replays
bit-identically from its seed.  The chaos suite (``tests/test_faults.py``)
and ``launch/serve.py --chaos`` both drive the service through this
wrapper and assert the §7 contract: no ticket ever hangs, every submit
resolves to a verdict or a typed error, and a poisoned request never
fails an innocent co-batched one.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Tuple

import numpy as np

from repro.core.counters import Counters
from repro.core.geometry import OBBs
from repro.engine.executor import CollisionEngine
from repro.engine.plan import QueryPlan, plan_queries

#: Failure modes the service contains, one per row of the DESIGN.md §7
#: failure-mode table and the README reliability table (drift-guarded by
#: tests/test_docs_modes like ADMISSION_KNOBS/SLO_METRICS).
FAILURE_MODES = ("malformed_plan", "engine_exception", "worker_death",
                 "launch_stall", "device_oom", "overload", "deadline_miss",
                 "device_loss")

#: Ways :func:`poison_obbs` can corrupt a request, each one a condition
#: ``repro.engine.plan.validate_plan`` must catch at submit.
POISON_KINDS = ("nan_center", "inf_half", "zero_half", "wrong_dtype")


class SimulatedOOM(RuntimeError):
    """Injected stand-in for the runtime's RESOURCE_EXHAUSTED: transient —
    the batcher retries it with backoff at reduced pool width."""

    transient = True

    def __init__(self, width: int):
        super().__init__(
            f"RESOURCE_EXHAUSTED (injected): simulated device OOM on a "
            f"{width}-slot pool")


class InjectedFault(RuntimeError):
    """Injected non-transient engine exception (a poisoned launch): the
    batcher bisect-retries the batch to isolate the poisoned request."""


class SimulatedDeviceLoss(RuntimeError):
    """Injected stand-in for the runtime's DEVICE_LOST: ``lost`` shard
    devices dropped out of the collision mesh mid-launch.  The sharded
    executor classifies it (``device_loss`` attribute or a DEVICE_LOST
    token in the message, matching how XLA surfaces real device loss),
    re-shards the flat pair pool over the surviving device set, and
    relaunches; only a mesh with no survivors propagates it to the
    batcher, which fails the batch with the typed ``DeviceLost`` error."""

    device_loss = True

    def __init__(self, lost: int, shards: int):
        super().__init__(
            f"DEVICE_LOST (injected): {lost} of {shards} shard devices "
            f"dropped out of the collision mesh mid-launch")
        self.lost = int(lost)
        self.shards = int(shards)


class WorkerKill(BaseException):
    """Injected worker-thread death: derives from ``BaseException`` and is
    flagged ``fatal`` so the batcher's per-launch containment re-raises it
    and the worker thread dies WITHOUT resolving its tickets — exactly the
    silent-death scenario the liveness watchdog exists to detect."""

    fatal = True


@dataclasses.dataclass
class FaultPlan:
    """Injection rates/points for one chaos run.

    Rates are per engine call (``oom_rate``/``exception_rate``/
    ``stall_rate``/``crash_rate``) or per client request
    (``malformed_rate``, applied by the chaos clients in
    ``launch/serve.py`` before submit).  ``poison_nan`` is the targeted
    variant: any pool containing a non-finite OBB raises
    :class:`InjectedFault`, which is how the bisect-isolation tests model
    "this one request crashes any launch it rides in".
    """

    malformed_rate: float = 0.0    # corrupt client plans pre-submit
    exception_rate: float = 0.0    # non-transient engine exception
    oom_rate: float = 0.0          # transient SimulatedOOM
    stall_rate: float = 0.0        # artificial launch stall
    crash_rate: float = 0.0        # kill the worker thread (WorkerKill)
    device_loss_rate: float = 0.0  # drop shard devices from the mesh
    #                                (sharded engines only; fires at the
    #                                per-attempt injector seam inside
    #                                _exec_sharded, so the recovery path —
    #                                not just the batcher — is exercised)
    devices_lost: int = 1          # shard devices dropped per injection
    stall_s: float = 0.5           # injected stall duration
    poison_nan: bool = False       # any non-finite pool raises
    max_faults: Optional[int] = None   # stop injecting after this many
    seed: int = 0

    def __post_init__(self):
        for f in ("malformed_rate", "exception_rate", "oom_rate",
                  "stall_rate", "crash_rate", "device_loss_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")
        self._rs = np.random.RandomState(self.seed)
        self._lock = threading.Lock()
        self.num_injected = 0

    # -- decision points (deterministic given seed + call order) ----------
    def _fire(self, rate: float) -> bool:
        with self._lock:
            if self.max_faults is not None \
                    and self.num_injected >= self.max_faults:
                return False
            hit = rate > 0.0 and self._rs.uniform() < rate
            if hit:
                self.num_injected += 1
            return hit

    def draw_malformed(self) -> Optional[str]:
        """Client-side decision: corrupt this request?  Returns a poison
        kind or None."""
        if not self._fire(self.malformed_rate):
            return None
        with self._lock:
            return POISON_KINDS[self._rs.randint(len(POISON_KINDS))]


def poison_obbs(obbs: OBBs, kind: str, slot: int = 0) -> OBBs:
    """Corrupt one query slot of an OBB set in the named way.

    Every kind is a condition :func:`repro.engine.plan.validate_plan`
    rejects at submit — the chaos clients use this to prove malformed
    requests die at admission, not inside a shared launch.
    """
    c = np.array(obbs.center, np.float32, copy=True)
    h = np.array(obbs.half, np.float32, copy=True)
    r = np.array(obbs.rot, np.float32, copy=True)
    if kind == "nan_center":
        c[slot] = np.nan
    elif kind == "inf_half":
        h[slot, 0] = np.inf
    elif kind == "zero_half":
        h[slot] = 0.0
    elif kind == "wrong_dtype":
        h = h.astype(np.float64)
    else:
        raise ValueError(
            f"unknown poison kind {kind!r}; allowed: "
            f"{', '.join(POISON_KINDS)}")
    return OBBs(center=c, half=h, rot=r)


def poisoned_plan(obbs: OBBs, kind: str, slot: int = 0) -> QueryPlan:
    """A lowered plan carrying one poisoned query slot."""
    return plan_queries(poison_obbs(obbs, kind, slot))


class FaultyEngine:
    """CollisionEngine wrapper injecting a :class:`FaultPlan` at execute.

    Duck-types the slice of the engine surface the batcher touches
    (``execute``, ``octree``, ``cfg``), so it drops into
    :class:`repro.engine.batcher.RequestBatcher` and
    ``launch/serve.py --chaos`` unchanged.  Injection order per call:
    crash, stall, OOM, exception — a stall can therefore be followed by a
    clean result (the watchdog, not the engine, decides it took too long).
    """

    def __init__(self, engine: CollisionEngine, faults: FaultPlan):
        self.inner = engine
        self.faults = faults
        self.calls = 0
        self.injected = {"exception": 0, "oom": 0, "stall": 0, "crash": 0,
                         "poison": 0, "device_loss": 0}
        if faults.device_loss_rate > 0.0:
            # Device loss must fire INSIDE the sharded launch attempt (the
            # recovery loop lives in _exec_sharded, below the execute
            # boundary every other fault uses), so it rides the engine's
            # per-attempt injector seam.
            engine.device_fault_injector = self._lose_devices

    def _lose_devices(self, shards: int) -> None:
        f = self.faults
        if shards > 0 and f._fire(f.device_loss_rate):
            self.injected["device_loss"] += 1
            raise SimulatedDeviceLoss(min(f.devices_lost, shards), shards)

    # The batcher reads these off the engine it serves.
    @property
    def octree(self):
        return self.inner.octree

    @property
    def cfg(self):
        return self.inner.cfg

    @property
    def scene_nodes(self):
        return self.inner.scene_nodes

    @property
    def active_shards(self):
        return self.inner.active_shards

    @property
    def supports_depth_cap(self):
        return self.inner.supports_depth_cap

    def set_shards(self, shards: int) -> None:
        self.inner.set_shards(shards)

    def rebind_octrees(self, octree) -> None:
        self.inner.rebind_octrees(octree)

    def execute(self, plan: QueryPlan,
                max_depth: Optional[int] = None) -> Tuple[np.ndarray,
                                                          Counters]:
        self.calls += 1
        f = self.faults
        if f.poison_nan and not bool(
                np.isfinite(np.asarray(plan.obb_c)).all()
                and np.isfinite(np.asarray(plan.obb_h)).all()):
            self.injected["poison"] += 1
            raise InjectedFault(
                "injected: non-finite OBB poisoned this launch")
        if f._fire(f.crash_rate):
            self.injected["crash"] += 1
            raise WorkerKill("injected: worker thread killed mid-launch")
        if f._fire(f.stall_rate):
            self.injected["stall"] += 1
            time.sleep(f.stall_s)
        if f._fire(f.oom_rate):
            self.injected["oom"] += 1
            raise SimulatedOOM(plan.num_queries)
        if f._fire(f.exception_rate):
            self.injected["exception"] += 1
            raise InjectedFault("injected: engine exception mid-launch")
        # Like the batcher, only forward max_depth when set, so wrapped
        # duck-typed engines with an execute(plan)-only signature keep
        # working un-degraded.
        if max_depth is None:
            return self.inner.execute(plan)
        return self.inner.execute(plan, max_depth=max_depth)


__all__ = ["FAILURE_MODES", "FaultPlan", "FaultyEngine", "InjectedFault",
           "POISON_KINDS", "SimulatedDeviceLoss", "SimulatedOOM",
           "WorkerKill", "poison_obbs", "poisoned_plan"]
