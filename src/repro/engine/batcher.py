"""Async request aggregator: continuous batching for the collision engine.

The serving problem (DESIGN.md §6): planner clients issue many SMALL query
sets — a dozen link OBBs per motion-plan step — while the engine's
throughput comes from LARGE flat pools that keep the persistent megakernel
saturated.  The :class:`RequestBatcher` bridges the two: client threads
``submit`` plans and block on a ticket; a single worker thread coalesces
whatever is queued into ONE flat pool, launches it as one engine execute,
and routes each slice of the verdict back through the submitting plan's
own un-flattening recipe.

Admission policy (the knobs in :data:`ADMISSION_KNOBS`, drift-guarded
against DESIGN.md §6):

* ``max_batch`` — launch as soon as the coalesced pool holds this many
  query slots (one oversized request still launches alone);
* ``max_wait_ms`` — never hold the FIRST queued request longer than this
  before launching, whatever the pool size;
* ``max_queue`` — bounded admission: a submit that finds this many
  requests already queued is shed immediately with :class:`Overloaded`
  instead of growing an unbounded backlog;
* ``launch_timeout_s`` — liveness bound on one engine call: the launch
  runs on a monitored thread, and a call that outlives the bound fails
  its batch with :class:`LaunchStalled` instead of hanging every client;
* ``max_retries`` — transient launch failures (RESOURCE_EXHAUSTED /
  simulated OOM) retry up to this many times with exponential backoff,
  shrinking an oversized pow2 pad bucket toward the exact pool width;
* ``max_queue_work`` — work-based admission (service v2): bound the
  queued PREDICTED WORK (scene node count x query count,
  :meth:`repro.engine.plan.QueryPlan.work_units`) instead of only the
  request count — one 10k-query sweep costs what it costs, not "1";
* ``degrade_queue`` — graceful degradation: at this queue depth (or
  after device loss shrank the mesh) launches run DEGRADED — halved pad
  bucket, depth-capped traversal — and say so (``RequestStats.degraded``)
  rather than shedding;
* ``degraded_max_depth`` — the traversal depth cap degraded launches use
  (default: one level above the scene's leaves; conservative-superset
  verdicts, never a missed collision);
* ``target_p99_ms`` — the elastic-width SLO: with ``autoscale_shards``
  the batcher resizes the engine's collision mesh between launches when
  the windowed p99 (or queue depth) drifts past it.

Reliability contract (DESIGN.md §7): every ``submit`` resolves — to a
verdict, or to a typed :class:`ServiceError` — and a poisoned request
never fails an innocent co-batched one:

* plans are validated at submit (:func:`repro.engine.plan.validate_plan`)
  so malformed OBBs die at admission, not inside a shared launch;
* a launch that still fails **bisect-retries**: the batch splits in half
  and each half relaunches, recursively, until the poisoned request is
  isolated and errors alone (``Counters.launch_splits`` counts splits);
* per-request deadlines (``submit(..., deadline_ms=...)``) shed requests
  whose deadline cannot be met — queued time plus the EWMA of recent
  launch exec times already past due — with :class:`DeadlineExceeded`
  BEFORE wasting a launch on them;
* a watchdog thread detects a dead worker, fails its unresolved in-flight
  tickets with :class:`WorkerDied`, and restarts the worker so the
  service self-heals (``Counters.worker_restarts``);
* device loss inside the sharded mesh is recovered BELOW the batcher
  (``_exec_sharded`` re-shards over the survivors, bitwise-identical —
  ``Counters.reshards``); only a mesh with no survivors surfaces here,
  failing the whole batch with :class:`DeviceLost` (never bisected: the
  loss is not attributable to any one request);
* scene swaps route through the worker (:meth:`RequestBatcher.rebind`),
  so a ``rebind_octrees`` can never race a live launch's traversal-cache
  or capacity-memo state;
* ``close()`` fails everything still queued (or racing the drain) with
  :class:`BatcherClosed`; submit after close raises the same type.

The coalesced pool pads up to a power-of-two bucket (``pad_pow2``) with
degenerate OBBs far outside the scene — they fail the root test and die
at level 0 — so the engine's jit cache sees O(log max_batch) distinct
pool widths instead of one per arrival pattern.  The pad count is
reported in ``Counters.pad_queries``.

Per-request latency accounting (:class:`RequestStats`): ``wait_s`` is
admission (submit -> launch), ``exec_s`` the shared engine call,
``total_s`` their sum — the quantities the serve harness turns into
p50/p99 SLO rows — plus the reliability fields ``retries`` (transient
relaunches the request rode through) and ``splits`` (bisect depth).
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import queue
import threading
import time
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core.counters import Counters
from repro.core.geometry import OBBs
from repro.engine.executor import CollisionEngine, device_loss_count
from repro.engine.plan import (PlanValidationError, QueryPlan, plan_queries,
                               validate_plan)

#: Admission-policy knobs of the batcher (drift-guarded against the
#: DESIGN.md §6 admission table).
ADMISSION_KNOBS = ("max_batch", "max_wait_ms", "max_queue",
                   "launch_timeout_s", "max_retries", "max_queue_work",
                   "degrade_queue", "degraded_max_depth", "target_p99_ms")

#: Lifecycle of a submitted request's ticket (:attr:`BatchTicket.state`).
TICKET_STATES = ("queued", "launched", "done")

logger = logging.getLogger(__name__)


class ServiceError(RuntimeError):
    """Base of every typed error the service resolves a ticket with."""


class BatcherClosed(ServiceError):
    """The batcher shut down before (or while) this request could launch."""


class Overloaded(ServiceError):
    """Admission queue is full: the request was shed at submit."""


class DeadlineExceeded(ServiceError):
    """The request's deadline could not be met; it was never launched."""


class LaunchStalled(ServiceError):
    """An engine call outlived ``launch_timeout_s``; the batch was failed
    so no client hangs on a wedged device."""


class WorkerDied(ServiceError):
    """The worker thread died mid-launch; the watchdog failed this ticket
    and restarted the worker."""


class DeviceLost(ServiceError):
    """The sharded collision mesh lost devices and had NO survivors to
    re-shard onto (a recoverable loss never reaches clients — the
    executor relaunches on the surviving set, bitwise-identical).  The
    whole batch fails typed, never bisected: device loss is not
    attributable to any one request."""


@dataclasses.dataclass
class RequestStats:
    """Latency + batching accounting for one submitted request."""

    wait_s: float          # submit -> batch launch (admission queueing)
    exec_s: float          # the shared engine call the request rode in
    total_s: float         # wait_s + exec_s (client-observed latency)
    batch_requests: int    # requests coalesced into the launch
    batch_queries: int     # live query slots in the coalesced pool
    pad_queries: int       # dead pow2-bucket pad slots in the pool
    retries: int = 0       # transient-failure relaunches before success
    splits: int = 0        # bisect-retry depth the request rode through
    degraded: bool = False  # served in declared degraded mode (halved pad
    #                         bucket + depth-capped traversal): verdicts
    #                         are a conservative superset — no silent
    #                         quality loss, the response says what it is


class BatchTicket:
    """Handle returned by :meth:`RequestBatcher.submit`.

    Resolution is idempotent and first-wins: whichever of the worker, the
    bisect-retry path, or the watchdog resolves the ticket first decides
    the outcome, so an abandoned stalled launch completing late can never
    overwrite the error the client already saw.
    """

    def __init__(self):
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._value: Optional[np.ndarray] = None
        self._stats: Optional[RequestStats] = None
        self._error: Optional[BaseException] = None
        self._state = "queued"

    @property
    def state(self) -> str:
        """``"queued"`` (awaiting admission), ``"launched"`` (riding an
        engine call), or ``"done"`` (:meth:`result` will not block)."""
        return self._state

    def result(self, timeout: Optional[float] = None
               ) -> Tuple[np.ndarray, RequestStats]:
        """Block until the request resolves; returns (un-flattened
        verdicts, per-request stats) or raises the typed error the
        request failed with.  Safe to call again after a
        :class:`TimeoutError` — the ticket stays live until resolved.
        """
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"collision request not done after {timeout}s "
                f"(state: {self._state})")
        if self._error is not None:
            raise self._error
        return self._value, self._stats

    def done(self) -> bool:
        return self._event.is_set()

    # -- resolution (batcher-internal, first call wins) -------------------
    def _mark_launched(self) -> None:
        with self._lock:
            if not self._event.is_set():
                self._state = "launched"

    def _resolve(self, value, stats: RequestStats) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._value, self._stats = value, stats
            self._state = "done"
            self._event.set()
            return True

    def _fail(self, error: BaseException) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._error = error
            self._state = "done"
            self._event.set()
            return True


@dataclasses.dataclass
class _Pending:
    plan: QueryPlan
    ticket: BatchTicket
    t_submit: float
    t_deadline: Optional[float] = None   # absolute perf_counter deadline
    work: int = 0                        # predicted work units (admission)


@dataclasses.dataclass
class _Rebind:
    """A scene swap queued behind the in-flight requests: the worker
    executes it between launches, so ``rebind_octrees`` can never race a
    live launch (satellite of DESIGN.md §7's isolation story)."""

    octree: object
    event: threading.Event
    error: Optional[BaseException] = None


_STOP = object()

#: Launches between elastic-width changes: long enough for the latency
#: window to reflect the new mesh before the next decision.
_RESCALE_COOLDOWN = 4


def _pad_bucket(n: int, floor: int = 64) -> int:
    b = floor
    while b < n:
        b <<= 1
    return b


def _is_transient(e: BaseException) -> bool:
    """Transient = worth retrying the SAME batch: allocator pressure, not
    a poisoned request.  Matches the runtime's RESOURCE_EXHAUSTED string
    (real XLA OOMs) and anything flagged ``transient`` (injected ones)."""
    return bool(getattr(e, "transient", False)) \
        or "RESOURCE_EXHAUSTED" in str(e)


class RequestBatcher:
    """Coalesce concurrent small plans into single engine launches.

    ``engine`` is any :class:`repro.engine.executor.CollisionEngine`
    bound to ONE scene — including a sharded one (``cfg.shards``), which
    is how the service stacks continuous batching on top of the device
    mesh — or a :class:`repro.engine.faults.FaultyEngine` wrapping one
    (chaos mode).  Accepts boolean single-scene plans of any workload
    kind; the verdicts come back through each plan's own ``unflatten``
    recipe, so a trajectory client gets per-waypoint flags while an OBB
    client gets per-query booleans out of the same coalesced launch.
    """

    def __init__(self, engine: CollisionEngine, max_batch: int = 1024,
                 max_wait_ms: float = 2.0, pad_pow2: bool = True,
                 max_queue: int = 4096,
                 launch_timeout_s: Optional[float] = None,
                 max_retries: int = 2, retry_backoff_ms: float = 1.0,
                 max_queue_work: Optional[int] = None,
                 degrade_queue: Optional[int] = None,
                 degraded_max_depth: Optional[int] = None,
                 autoscale_shards: bool = False,
                 target_p99_ms: Optional[float] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if max_queue_work is not None and max_queue_work < 1:
            raise ValueError(
                f"max_queue_work must be >= 1, got {max_queue_work}")
        if degrade_queue is not None and degrade_queue < 1:
            raise ValueError(
                f"degrade_queue must be >= 1, got {degrade_queue}")
        if degraded_max_depth is not None and degraded_max_depth < 1:
            raise ValueError(
                f"degraded_max_depth must be >= 1, got {degraded_max_depth}")
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.pad_pow2 = pad_pow2
        self.max_queue = max_queue
        self.launch_timeout_s = launch_timeout_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_ms / 1e3
        self.max_queue_work = max_queue_work
        self.degrade_queue = degrade_queue
        self.degraded_max_depth = degraded_max_depth
        self.autoscale_shards = autoscale_shards
        self.target_p99_ms = target_p99_ms
        #: Aggregate engine counters over every launch (includes pads),
        #: plus the §7 reliability counters (rejected/retried/
        #: deadline_missed/launch_splits/worker_restarts/reshards/
        #: shards_lost/shard_rescales/degraded_launches).
        self.totals = Counters()
        self.num_launches = 0
        self._queue: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._closed = False
        self._closed_event = threading.Event()
        # Requests the CURRENT launch is carrying: the watchdog fails the
        # unresolved ones if the worker dies under them.
        self._inflight: List[_Pending] = []
        # Deadline-shedding estimates, PER pow2 pad bucket: one global
        # EWMA made a 64-wide launch after a 1024-wide one inherit a
        # wildly pessimistic estimate and over-shed.  Buckets the service
        # has not measured yet fall back to the work-rate EWMA
        # (seconds per predicted work unit), which scales the estimate
        # with the bucket instead of pinning it to the largest one seen.
        self._exec_ewma: dict = {}
        self._work_rate: Optional[float] = None
        # Predicted work units currently queued (work-based admission).
        self._queued_work = 0
        # Queue depth observed as the current launch formed (see
        # _run_inner); feeds the degrade decision alongside live qsize.
        self._pressure = 0
        # Launch threads abandoned by the stall watchdog, still running
        # their engine call; close() bounded-joins them so a process
        # exiting right after a stall doesn't tear down the interpreter
        # under a live XLA computation.
        self._abandoned: List[threading.Thread] = []
        # Client-observed latencies of recent requests: the autoscaler's
        # p99 window.
        self._lat_window: collections.deque = collections.deque(maxlen=64)
        self._last_rescale_launch = -_RESCALE_COOLDOWN
        self._worker = self._start_worker()
        self._watchdog = threading.Thread(target=self._watch, daemon=True,
                                          name="collision-watchdog")
        self._watchdog.start()

    def _start_worker(self) -> threading.Thread:
        t = threading.Thread(target=self._run, daemon=True,
                             name="collision-batcher")
        t.start()
        return t

    # ------------------------------------------------------------------
    def submit(self, plan_or_obbs, deadline_ms: Optional[float] = None,
               validate: bool = True) -> BatchTicket:
        """Enqueue one request; returns a ticket to block on.

        Takes a lowered boolean plan, or bare :class:`OBBs` as shorthand
        for ``plan_queries``.  ``deadline_ms`` is a client-observed
        latency budget from NOW: a request the batcher cannot launch in
        time fails fast with :class:`DeadlineExceeded` instead of riding
        a launch whose result nobody wants.  ``validate=False`` skips the
        malformed-plan admission check (trusted in-process callers only;
        the chaos suite uses it to prove what validation protects
        against).

        Raises :class:`BatcherClosed` after :meth:`close`,
        :class:`Overloaded` when the admission queue is full, and
        :class:`repro.engine.plan.PlanValidationError` for malformed
        plans — all before the request can touch a shared launch.
        """
        t_submit = time.perf_counter()
        plan = (plan_queries(plan_or_obbs)
                if isinstance(plan_or_obbs, OBBs) else plan_or_obbs)
        if plan.grouped:
            raise ValueError(
                "the batcher coalesces boolean plans; owner/payload "
                "verdict groups cannot share a pool with other requests")
        if plan.num_scenes != 1:
            raise ValueError(
                "the batcher serves single-scene plans against the "
                "engine's bound scene")
        if self._closed:
            raise BatcherClosed("batcher is closed")
        if validate:
            try:
                validate_plan(plan)
            except PlanValidationError:
                with self._lock:
                    self.totals.rejected += 1
                raise
        if self._queue.qsize() >= self.max_queue:
            with self._lock:
                self.totals.rejected += 1
            raise Overloaded(
                f"admission queue full ({self.max_queue} requests "
                f"queued); shedding new arrivals")
        work = plan.work_units(self._scene_nodes())
        if self.max_queue_work is not None:
            with self._lock:
                # One oversized request with an empty queue still admits
                # (like an over-max_batch request still launching alone);
                # the bound sheds ADDITIONAL work on top of a backlog.
                shed = (self._queued_work > 0
                        and self._queued_work + work > self.max_queue_work)
                if shed:
                    self.totals.rejected += 1
            if shed:
                raise Overloaded(
                    f"admission queue holds {self._queued_work} predicted "
                    f"work units; adding {work} would exceed "
                    f"max_queue_work={self.max_queue_work} — shedding")
        deadline = (None if deadline_ms is None
                    else t_submit + deadline_ms / 1e3)
        pending = _Pending(plan, BatchTicket(), t_submit, deadline, work)
        with self._lock:
            self._queued_work += work
        self._queue.put(pending)
        if self._closed:
            # Raced close(): the final drain may already have run past
            # the queue, so fail the ticket here (first-wins makes a
            # double fail harmless) and surface the typed error.
            if pending.ticket._fail(BatcherClosed(
                    "batcher closed while this request was being "
                    "submitted")):
                with self._lock:
                    self.totals.rejected += 1
            raise BatcherClosed("batcher is closed")
        return pending.ticket

    def close(self, timeout: float = 30.0) -> None:
        """Launch what is already queued, then stop the worker; everything
        that cannot launch fails with :class:`BatcherClosed` — no ticket
        is ever silently dropped."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._queue.put(_STOP)
        self._worker.join(timeout)
        self._closed_event.set()
        self._watchdog.join(timeout)
        # Bounded wait for launches the stall watchdog abandoned (their
        # results were already discarded by first-wins resolution); a
        # genuinely wedged one stays daemon and cannot block close.
        t_end = time.perf_counter() + timeout
        for th in self._abandoned:
            th.join(max(0.0, t_end - time.perf_counter()))
        # Final drain: anything still queued (worker dead/stuck, or a
        # submit that raced the worker's own drain) fails typed.
        self._drain_closed()

    def rebind(self, octree, timeout: Optional[float] = 60.0) -> None:
        """Swap the engine's bound scene(s) THROUGH the worker thread.

        Calling ``engine.rebind_octrees`` directly under a live batcher
        races the launch path: a rebind mid-launch swaps the device
        tables, scene signature and capacity memo out from under an
        in-flight traversal.  This routes the swap into the admission
        queue instead — FIFO with the requests around it, executed by
        the worker strictly BETWEEN launches — and blocks until applied.
        Requests submitted before the rebind run against the old scene,
        requests after it against the new one.
        """
        if self._closed:
            raise BatcherClosed("batcher is closed")
        r = _Rebind(octree, threading.Event())
        self._queue.put(r)
        if not r.event.wait(timeout):
            raise TimeoutError(f"scene rebind not applied after {timeout}s")
        if r.error is not None:
            raise r.error

    def _scene_nodes(self) -> int:
        """Per-query factor of the predicted-work estimate; 1 for duck-
        typed engines that don't expose a node count (work then reduces
        to the query count — the v1 behavior)."""
        return max(1, int(getattr(self.engine, "scene_nodes", 1)))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    def _drain_closed(self) -> None:
        """Fail every request still in the admission queue: the batcher is
        closing and they will never launch."""
        while True:
            try:
                p = self._queue.get_nowait()
            except queue.Empty:
                return
            if p is _STOP:
                continue
            if isinstance(p, _Rebind):
                p.error = BatcherClosed(
                    "batcher closed before this rebind applied")
                p.event.set()
                continue
            with self._lock:
                self._queued_work -= p.work
            if p.ticket._fail(BatcherClosed(
                    "batcher closed before this request launched")):
                with self._lock:
                    self.totals.rejected += 1

    def _watch(self) -> None:
        """Liveness watchdog: a worker that dies (an exception escaping
        the per-launch containment — a real bug, or an injected
        ``WorkerKill``) leaves its batch's tickets unresolved and every
        queued client stranded.  Detect it, fail the unresolved in-flight
        tickets with a diagnosable :class:`WorkerDied`, and restart the
        worker so queued and future requests keep being served."""
        while not self._closed_event.wait(0.05):
            if self._worker.is_alive():
                continue
            with self._lock:
                if self._closed:
                    return
                self.totals.worker_restarts += 1
                inflight, self._inflight = self._inflight, []
            for p in inflight:
                p.ticket._fail(WorkerDied(
                    "collision-batcher worker died mid-launch; the "
                    "watchdog restarted it — resubmit if the request "
                    "is still wanted"))
            self._worker = self._start_worker()

    # ------------------------------------------------------------------
    def _run(self):
        try:
            self._run_inner()
        except BaseException as e:                # noqa: BLE001
            if not getattr(e, "fatal", False):
                raise      # real bug: traceback + watchdog restart
            # Injected worker death (chaos): die quietly — the thread
            # ending WITHOUT resolving its tickets is the scenario, and
            # the watchdog is the handler; no traceback spam.

    def _do_rebind(self, r: _Rebind) -> None:
        """Apply a queued scene swap (worker thread, between launches).
        The measured exec estimates describe the OLD scene's traversal
        cost, so they reset with it."""
        try:
            self.engine.rebind_octrees(r.octree)
            with self._lock:
                self._exec_ewma.clear()
                self._work_rate = None
        except BaseException as e:                # noqa: BLE001
            r.error = e
        finally:
            r.event.set()

    def _run_inner(self):
        while True:
            first = self._queue.get()
            if first is _STOP:
                self._drain_closed()
                return
            if isinstance(first, _Rebind):
                self._do_rebind(first)
                continue
            with self._lock:
                self._queued_work -= first.work
            # Backlog behind this launch as it forms: coalescing drains
            # the queue, so the overload signal must be read BEFORE it
            # (a launch that absorbs the whole backlog is still a launch
            # that formed under pressure).
            self._pressure = self._queue.qsize()
            batch = [first]
            total = first.plan.num_queries
            deadline = time.perf_counter() + self.max_wait_s
            stop = False
            rebind = None
            while total < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stop = True
                    break
                if isinstance(nxt, _Rebind):
                    # Stop coalescing: requests queued BEFORE the rebind
                    # launch against the old scene first (FIFO), then the
                    # swap applies.
                    rebind = nxt
                    break
                with self._lock:
                    self._queued_work -= nxt.work
                batch.append(nxt)
                total += nxt.plan.num_queries
            self._admit(batch)
            if rebind is not None:
                self._do_rebind(rebind)
            if stop:
                self._drain_closed()
                return

    def _estimate_exec_s(self, num_queries: int) -> float:
        """Deadline-shedding estimate for a pool of this many live query
        slots: the pad bucket's own EWMA when measured, else the
        work-rate EWMA scaled to this bucket, else 0 (optimistic — never
        shed on no data)."""
        bucket = _pad_bucket(num_queries) if self.pad_pow2 else num_queries
        est = self._exec_ewma.get(bucket)
        if est is not None:
            return est
        if self._work_rate is not None:
            return self._work_rate * self._scene_nodes() * bucket
        return 0.0

    def _admit(self, batch: List[_Pending]) -> None:
        """Deadline shedding at launch time: a request whose budget is
        already spent — or will be by the end of an average engine call —
        is failed fast, never launched dead."""
        now = time.perf_counter()
        est = self._estimate_exec_s(sum(p.plan.num_queries for p in batch))
        live = []
        for p in batch:
            if p.t_deadline is not None and now + est > p.t_deadline:
                with self._lock:
                    self.totals.deadline_missed += 1
                p.ticket._fail(DeadlineExceeded(
                    f"deadline unmeetable: {1e3 * (now - p.t_submit):.1f}ms "
                    f"queued + ~{1e3 * est:.1f}ms estimated exec exceeds "
                    f"the {1e3 * (p.t_deadline - p.t_submit):.1f}ms budget"))
            else:
                live.append(p)
        if live:
            self._launch(live)

    def _pad_obbs(self, n: int) -> OBBs:
        """Degenerate pad queries: point-sized OBBs far outside the scene
        AABB, so the root-cell test fails and each pad retires at level 0
        with one node visit of work."""
        lo = np.asarray(self.engine.octree.scene_lo, np.float32)
        far = np.broadcast_to(lo - np.float32(1e6), (n, 3))
        return OBBs(center=np.ascontiguousarray(far),
                    half=np.full((n, 3), 1e-6, np.float32),
                    rot=np.broadcast_to(np.eye(3, dtype=np.float32),
                                        (n, 3, 3)))

    def _call_engine(self, plan: QueryPlan,
                     max_depth: Optional[int] = None):
        """One engine execute under the liveness bound: with
        ``launch_timeout_s`` set the call runs on a monitored thread, and
        on timeout the batch fails with :class:`LaunchStalled` while the
        abandoned call finishes (or hangs) on its daemon thread — its
        late result is discarded by first-wins ticket resolution."""
        # Only degraded launches pass max_depth, so duck-typed engines
        # with an execute(plan)-only signature keep working un-degraded.
        kw = {} if max_depth is None else {"max_depth": max_depth}
        if self.launch_timeout_s is None:
            return self.engine.execute(plan, **kw)
        box: dict = {}

        def target():
            try:
                box["out"] = self.engine.execute(plan, **kw)
            except BaseException as e:            # noqa: BLE001
                box["err"] = e

        th = threading.Thread(target=target, daemon=True,
                              name="collision-launch")
        th.start()
        th.join(self.launch_timeout_s)
        if th.is_alive():
            # Track the abandoned thread so close() can wait for it:
            # exiting the process while it is still inside an XLA
            # computation aborts interpreter teardown.
            self._abandoned.append(th)
            raise LaunchStalled(
                f"engine call exceeded launch_timeout_s="
                f"{self.launch_timeout_s}; failing the batch so no "
                f"client hangs on a wedged launch")
        if "err" in box:
            raise box["err"]
        return box["out"]

    def _should_degrade(self) -> bool:
        """Degrade rather than shed (DESIGN.md §7): under sustained
        overload (queue at ``degrade_queue``) or while device loss has
        the mesh below its configured width."""
        if self.degrade_queue is not None \
                and max(self._queue.qsize(),
                        self._pressure) >= self.degrade_queue:
            return True
        active = getattr(self.engine, "active_shards", None)
        configured = getattr(getattr(self.engine, "cfg", None),
                             "shards", None)
        return (active is not None and configured is not None
                and active < configured)

    def _degraded_depth(self) -> Optional[int]:
        """Traversal depth cap for degraded launches: the configured
        ``degraded_max_depth``, defaulting to one level above the leaves;
        None when the engine's mode has no cap (degradation is then the
        halved pad bucket alone)."""
        if not getattr(self.engine, "supports_depth_cap", False):
            return None
        if self.degraded_max_depth is not None:
            return self.degraded_max_depth
        return max(1, self.engine.octree.depth - 1)

    def _execute_with_retry(self, batch: List[_Pending],
                            degraded: bool = False):
        """Build the coalesced pool and execute it, retrying transient
        failures with exponential backoff.  An oversized pow2 pad bucket
        shrinks toward the exact pool width across retries (the
        RESOURCE_EXHAUSTED response: ask for less).  A degraded launch
        starts from a HALVED pad bucket and caps traversal depth.
        Returns (verdict, counters, live, pad, retries)."""
        c = [np.asarray(p.plan.obb_c) for p in batch]
        h = [np.asarray(p.plan.obb_h) for p in batch]
        r = [np.asarray(p.plan.obb_r) for p in batch]
        live = sum(a.shape[0] for a in c)
        bucket = _pad_bucket(live) if self.pad_pow2 else live
        max_depth = None
        if degraded:
            bucket = max(live, bucket >> 1)
            max_depth = self._degraded_depth()
        retries = 0
        while True:
            pad = bucket - live
            cc, hh, rr = list(c), list(h), list(r)
            if pad:
                po = self._pad_obbs(pad)
                cc.append(np.asarray(po.center))
                hh.append(np.asarray(po.half))
                rr.append(np.asarray(po.rot))
            pool = OBBs(center=np.concatenate(cc), half=np.concatenate(hh),
                        rot=np.concatenate(rr))
            try:
                verdict, counters = self._call_engine(plan_queries(pool),
                                                      max_depth)
                return verdict, counters, live, pad, retries
            except BaseException as e:            # noqa: BLE001
                if not _is_transient(e) or retries >= self.max_retries:
                    raise
                retries += 1
                with self._lock:
                    self.totals.retried += 1
                if bucket > live:                 # retry at half width
                    bucket = max(live, bucket >> 1)
                time.sleep(self.retry_backoff_s * (1 << (retries - 1)))

    def _launch(self, batch: List[_Pending], depth: int = 0):
        """Launch one coalesced batch; on failure, bisect-retry so only
        the poisoned request's ticket errors while innocent co-riders
        complete (fault isolation, DESIGN.md §7)."""
        t_launch = time.perf_counter()
        for p in batch:
            p.ticket._mark_launched()
        with self._lock:
            self._inflight = list(batch)
        degraded = self._should_degrade()
        try:
            verdict, counters, live, pad, retries = \
                self._execute_with_retry(batch, degraded)
            counters.pad_queries += pad
            if degraded:
                counters.degraded_launches += 1
            t_done = time.perf_counter()
            exec_s = t_done - t_launch
            width = live + pad
            with self._lock:
                self.totals.merge(counters)
                self.num_launches += 1
                prev = self._exec_ewma.get(width)
                self._exec_ewma[width] = (
                    exec_s if prev is None else 0.5 * prev + 0.5 * exec_s)
                rate = exec_s / max(self._scene_nodes() * width, 1)
                self._work_rate = (
                    rate if self._work_rate is None
                    else 0.5 * self._work_rate + 0.5 * rate)
            off = 0
            for p in batch:
                q = p.plan.num_queries
                stats = RequestStats(
                    wait_s=t_launch - p.t_submit,
                    exec_s=exec_s,
                    total_s=t_done - p.t_submit,
                    batch_requests=len(batch), batch_queries=live,
                    pad_queries=pad, retries=retries, splits=depth,
                    degraded=degraded)
                p.ticket._resolve(p.plan.unflatten(verdict[off:off + q]),
                                  stats)
                self._lat_window.append(stats.total_s)
                off += q
            if depth == 0:
                self._maybe_rescale()
        except BaseException as e:                    # noqa: BLE001
            if getattr(e, "fatal", False):
                # Simulated (or real) worker death: propagate WITHOUT
                # resolving tickets — the watchdog's job is to catch
                # exactly this and fail the in-flight tickets itself.
                raise
            if device_loss_count(e) is not None:
                # The executor already tried every surviving subset; a
                # loss surfacing here means the mesh has no devices left
                # to re-shard onto.  Not attributable to any request —
                # the whole batch fails typed, never bisected.
                err = DeviceLost(
                    f"collision mesh lost its devices with no survivors "
                    f"to re-shard onto: {e}")
                for p in batch:
                    p.ticket._fail(err)
                return
            if len(batch) == 1 or isinstance(e, LaunchStalled):
                # A singleton owns its failure; a stall is not
                # attributable to any one request, so the whole batch
                # fails typed rather than stalling again per half.
                for p in batch:
                    p.ticket._fail(e)
                return
            # Bisect-retry: the failure rode in with SOME request; split
            # the batch and relaunch each half so the poison isolates to
            # a singleton while everyone else completes.
            with self._lock:
                self.totals.launch_splits += 1
            mid = len(batch) // 2
            self._launch(batch[:mid], depth + 1)
            self._launch(batch[mid:], depth + 1)

    def _maybe_rescale(self) -> None:
        """Elastic width (DESIGN.md §6): between launches, resize the
        engine's collision mesh when the windowed p99 or the queue depth
        drifts past the SLO.  Doubling under pressure / halving when
        comfortably idle, cooled down so the window reflects each new
        width before the next decision.  A rescale re-probes the full
        device set, which is also how devices lost to a recovery rejoin.
        """
        if not self.autoscale_shards:
            return
        eng = self.engine
        cur = getattr(eng, "active_shards", None)
        if cur is None or not hasattr(eng, "set_shards"):
            return
        if self.num_launches - self._last_rescale_launch < _RESCALE_COOLDOWN:
            return
        n_dev = len(jax.devices())
        lat = sorted(self._lat_window)
        p99 = (lat[min(len(lat) - 1, int(0.99 * len(lat)))]
               if lat else None)
        target_s = (None if self.target_p99_ms is None
                    else self.target_p99_ms / 1e3)
        depth = self._queue.qsize()
        new = None
        if cur < n_dev and (
                (target_s is not None and p99 is not None and p99 > target_s)
                or depth >= max(1, self.max_queue // 2)):
            new = min(cur * 2, n_dev)
        elif cur > 1 and depth == 0 and target_s is not None \
                and p99 is not None and p99 < target_s / 4:
            new = max(1, cur // 2)
        if new is None or new == cur:
            return
        try:
            eng.set_shards(new)
        except Exception as e:                        # noqa: BLE001
            logger.warning("elastic rescale %d -> %d shards failed: %s",
                           cur, new, e)
            return
        logger.info(
            "elastic rescale: %d -> %d shards (p99 %.1fms vs target %s, "
            "queue depth %d)", cur, new,
            0.0 if p99 is None else 1e3 * p99, self.target_p99_ms, depth)
        with self._lock:
            self.totals.shard_rescales += 1
            self._last_rescale_launch = self.num_launches
        # Old-width latencies no longer describe the mesh being measured.
        self._lat_window.clear()
