"""Async request aggregator: continuous batching for the collision engine.

The serving problem (DESIGN.md §6): planner clients issue many SMALL query
sets — a dozen link OBBs per motion-plan step — while the engine's
throughput comes from LARGE flat pools that keep the persistent megakernel
saturated.  The :class:`RequestBatcher` bridges the two: client threads
``submit`` plans and block on a ticket; a single worker thread coalesces
whatever is queued into ONE flat pool, launches it as one engine execute,
and routes each slice of the verdict back through the submitting plan's
own un-flattening recipe.

Admission policy (the knobs in :data:`ADMISSION_KNOBS`, drift-guarded
against DESIGN.md §6):

* ``max_batch`` — launch as soon as the coalesced pool holds this many
  query slots (one oversized request still launches alone);
* ``max_wait_ms`` — never hold the FIRST queued request longer than this
  before launching, whatever the pool size.

The coalesced pool pads up to a power-of-two bucket (``pad_pow2``) with
degenerate OBBs far outside the scene — they fail the root test and die
at level 0 — so the engine's jit cache sees O(log max_batch) distinct
pool widths instead of one per arrival pattern.  The pad count is
reported in ``Counters.pad_queries``.

Per-request latency accounting (:class:`RequestStats`): ``wait_s`` is
admission (submit -> launch), ``exec_s`` the shared engine call,
``total_s`` their sum — the quantities the serve harness turns into
p50/p99 SLO rows.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core.counters import Counters
from repro.core.geometry import OBBs
from repro.engine.executor import CollisionEngine
from repro.engine.plan import QueryPlan, plan_queries

#: Admission-policy knobs of the batcher (drift-guarded against the
#: DESIGN.md §6 admission table).
ADMISSION_KNOBS = ("max_batch", "max_wait_ms")


@dataclasses.dataclass
class RequestStats:
    """Latency + batching accounting for one submitted request."""

    wait_s: float          # submit -> batch launch (admission queueing)
    exec_s: float          # the shared engine call the request rode in
    total_s: float         # wait_s + exec_s (client-observed latency)
    batch_requests: int    # requests coalesced into the launch
    batch_queries: int     # live query slots in the coalesced pool
    pad_queries: int       # dead pow2-bucket pad slots in the pool


class BatchTicket:
    """Handle returned by :meth:`RequestBatcher.submit`."""

    def __init__(self):
        self._event = threading.Event()
        self._value: Optional[np.ndarray] = None
        self._stats: Optional[RequestStats] = None
        self._error: Optional[BaseException] = None

    def result(self, timeout: Optional[float] = None
               ) -> Tuple[np.ndarray, RequestStats]:
        """Block until the batch the request rode in completes; returns
        (un-flattened verdicts, per-request stats)."""
        if not self._event.wait(timeout):
            raise TimeoutError("collision request still queued/in flight")
        if self._error is not None:
            raise self._error
        return self._value, self._stats

    def done(self) -> bool:
        return self._event.is_set()


@dataclasses.dataclass
class _Pending:
    plan: QueryPlan
    ticket: BatchTicket
    t_submit: float


_STOP = object()


def _pad_bucket(n: int, floor: int = 64) -> int:
    b = floor
    while b < n:
        b <<= 1
    return b


class RequestBatcher:
    """Coalesce concurrent small plans into single engine launches.

    ``engine`` is any :class:`repro.engine.executor.CollisionEngine`
    bound to ONE scene — including a sharded one (``cfg.shards``), which
    is how the service stacks continuous batching on top of the device
    mesh.  Accepts boolean single-scene plans of any workload kind; the
    verdicts come back through each plan's own ``unflatten`` recipe, so
    a trajectory client gets per-waypoint flags while an OBB-set client
    gets per-query booleans out of the same coalesced launch.
    """

    def __init__(self, engine: CollisionEngine, max_batch: int = 1024,
                 max_wait_ms: float = 2.0, pad_pow2: bool = True):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.pad_pow2 = pad_pow2
        #: Aggregate engine counters over every launch (includes pads).
        self.totals = Counters()
        self.num_launches = 0
        self._queue: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._closed = False
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="collision-batcher")
        self._worker.start()

    # ------------------------------------------------------------------
    def submit(self, plan_or_obbs) -> BatchTicket:
        """Enqueue one request; returns a ticket to block on.

        Takes a lowered boolean plan, or bare :class:`OBBs` as shorthand
        for ``plan_queries``.
        """
        plan = (plan_queries(plan_or_obbs)
                if isinstance(plan_or_obbs, OBBs) else plan_or_obbs)
        if plan.grouped:
            raise ValueError(
                "the batcher coalesces boolean plans; owner/payload "
                "verdict groups cannot share a pool with other requests")
        if plan.num_scenes != 1:
            raise ValueError(
                "the batcher serves single-scene plans against the "
                "engine's bound scene")
        if self._closed:
            raise RuntimeError("batcher is closed")
        pending = _Pending(plan, BatchTicket(), time.perf_counter())
        self._queue.put(pending)
        return pending.ticket

    def close(self, timeout: float = 30.0) -> None:
        """Drain queued requests, then stop the worker."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._queue.put(_STOP)
        self._worker.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    def _run(self):
        while True:
            first = self._queue.get()
            if first is _STOP:
                return
            batch = [first]
            total = first.plan.num_queries
            deadline = time.perf_counter() + self.max_wait_s
            stop = False
            while total < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stop = True
                    break
                batch.append(nxt)
                total += nxt.plan.num_queries
            self._launch(batch)
            if stop:
                return

    def _pad_obbs(self, n: int) -> OBBs:
        """Degenerate pad queries: point-sized OBBs far outside the scene
        AABB, so the root-cell test fails and each pad retires at level 0
        with one node visit of work."""
        lo = np.asarray(self.engine.octree.scene_lo, np.float32)
        far = np.broadcast_to(lo - np.float32(1e6), (n, 3))
        return OBBs(center=np.ascontiguousarray(far),
                    half=np.full((n, 3), 1e-6, np.float32),
                    rot=np.broadcast_to(np.eye(3, dtype=np.float32),
                                        (n, 3, 3)))

    def _launch(self, batch: List[_Pending]):
        t_launch = time.perf_counter()
        try:
            c = [np.asarray(p.plan.obb_c) for p in batch]
            h = [np.asarray(p.plan.obb_h) for p in batch]
            r = [np.asarray(p.plan.obb_r) for p in batch]
            live = sum(a.shape[0] for a in c)
            pad = (_pad_bucket(live) - live) if self.pad_pow2 else 0
            if pad:
                po = self._pad_obbs(pad)
                c.append(np.asarray(po.center))
                h.append(np.asarray(po.half))
                r.append(np.asarray(po.rot))
            pool = OBBs(center=np.concatenate(c), half=np.concatenate(h),
                        rot=np.concatenate(r))
            verdict, counters = self.engine.execute(plan_queries(pool))
            counters.pad_queries += pad
            t_done = time.perf_counter()
            with self._lock:
                self.totals.merge(counters)
                self.num_launches += 1
            off = 0
            for p in batch:
                q = p.plan.num_queries
                stats = RequestStats(
                    wait_s=t_launch - p.t_submit,
                    exec_s=t_done - t_launch,
                    total_s=t_done - p.t_submit,
                    batch_requests=len(batch), batch_queries=live,
                    pad_queries=pad)
                p.ticket._value = p.plan.unflatten(verdict[off:off + q])
                p.ticket._stats = stats
                p.ticket._error = None
                p.ticket._event.set()
                off += q
        except BaseException as e:                    # noqa: BLE001
            for p in batch:
                p.ticket._error = e
                p.ticket._event.set()
