"""Query planning: lower every front-end batch shape to one canonical pool.

Every collision workload the repo serves — a single OBB set, a (B, M)
trajectory batch, a ragged multi-scene batch, a waypoint trajectory, a
swept-edge (CCD) batch — used to reach the traversal through its own
hand-routed code path.  A :class:`QueryPlan` replaces those paths with one
lowered form:

* a **flat OBB pool** ``(Q, 3)/(Q, 3)/(Q, 3, 3)`` — one slot per query, no
  leading batch axes anywhere downstream;
* an optional **scene lane** ``scene_of_query`` (Q,) mapping each slot to
  its octree for multi-scene batches (``None`` = single scene);
* an optional **owner lane** ``owner_of_query`` (Q,) mapping slots to
  *verdict groups*: a terminal hit decides the whole group, and the group's
  remaining frontier pairs are compacted out exactly like a decided
  waypoint's (``None`` = every slot is its own group, the boolean case);
* an optional **payload lane** ``payload`` (Q,) int32: a group's verdict is
  the *minimum* payload that hit (``PAYLOAD_INF`` if none) instead of a
  boolean, which is what gives swept edges their first-colliding
  sub-interval — a waypoint is just the ``payload == 0`` special case;
* an **un-flattening recipe** (``out_shape`` + ``reduce_last``) that maps
  the flat group verdicts back to the front-end's native shape.

Plans are data, not behavior: :mod:`repro.engine.executor` owns mode
dispatch, the traversal cache, capacity escalation, and counter assembly
for every plan alike.  Lowering is pure reshaping/indexing — the property
tests assert the pool round-trips bit-exactly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import NUM_LINKS, OBBs, arm_link_obbs
from repro.core.sact import PAYLOAD_INF

#: Front-end workloads a plan can carry; DESIGN.md §2's workload table and
#: the README are drift-guarded against this tuple (tests/test_docs_modes).
WORKLOADS = ("queries", "batch", "scenes", "trajectory", "edges")


class PlanValidationError(ValueError):
    """A plan's OBB pool is malformed (shape/dtype/NaN/inf/degenerate).

    Raised by :func:`validate_plan` — the service's admission check
    (DESIGN.md §7): a malformed request is rejected at ``submit`` with a
    message naming the offending field, instead of poisoning a coalesced
    engine launch it would share with innocent co-batched requests.
    """


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """One lowered collision query batch (see module docstring)."""

    kind: str                 # workload tag, one of WORKLOADS
    obb_c: jax.Array          # (Q, 3) flat query OBB pool
    obb_h: jax.Array          # (Q, 3)
    obb_r: jax.Array          # (Q, 3, 3)
    out_shape: Tuple[int, ...]            # group verdicts reshape to this
    num_scenes: int = 1
    scene_of_query: Optional[jax.Array] = None   # (Q,) int32, None = scene 0
    owner_of_query: Optional[jax.Array] = None   # (Q,) int32, None = identity
    num_groups: Optional[int] = None             # verdict groups, None = Q
    payload: Optional[jax.Array] = None          # (Q,) int32, None = zeros
    reduce_last: bool = False  # any() over out_shape's last axis (trajectory)

    def __post_init__(self):
        if self.kind not in WORKLOADS:
            raise ValueError(
                f"unknown workload {self.kind!r}; allowed: "
                f"{', '.join(WORKLOADS)}")
        if math.prod(self.out_shape) != self.groups:
            raise ValueError(
                f"out_shape {self.out_shape} does not hold {self.groups} "
                f"verdict groups")

    @property
    def num_queries(self) -> int:
        return self.obb_c.shape[0]

    @property
    def groups(self) -> int:
        return self.num_groups if self.num_groups is not None \
            else self.num_queries

    @property
    def grouped(self) -> bool:
        """True when the plan carries owner or payload lanes: the traversal
        keeps an int32 ``best`` per group instead of a boolean per query."""
        return self.owner_of_query is not None or self.payload is not None

    @property
    def obbs(self) -> OBBs:
        return OBBs(center=self.obb_c, half=self.obb_h, rot=self.obb_r)

    @property
    def shape_tag(self) -> str:
        """One-line plan-shape descriptor for logs and fallback reports
        (``Counters.ref_arm_fallbacks``): names the workload and every
        lane that shapes arm routing, so a downgrade is never anonymous.
        """
        lanes = [l for l, v in (("scene", self.scene_of_query),
                                ("owner", self.owner_of_query),
                                ("payload", self.payload))
                 if v is not None]
        return (f"{self.kind}[Q={self.num_queries} S={self.num_scenes} "
                f"G={self.groups} lanes={'+'.join(lanes) or 'none'}]")

    def work_units(self, scene_nodes: int) -> int:
        """Predicted traversal work for admission control (DESIGN.md §6):
        scene node count x query count — the worst-case (query, node)
        pair universe traversal cost actually scales with, unlike the raw
        request count the v1 admission queue bounded.  The batcher
        calibrates it against the measured exec-EWMA to turn units into
        seconds."""
        return int(scene_nodes) * self.num_queries

    def unflatten(self, flat) -> np.ndarray:
        """Map flat group verdicts back to the front-end's native shape.

        ``flat`` is (G,) — bool for boolean plans, int32 ``best`` payloads
        for grouped plans (``PAYLOAD_INF`` = group never hit).
        """
        out = np.asarray(flat).reshape(self.out_shape)
        if self.reduce_last:
            out = out.any(axis=-1)
        return out


def validate_plan(plan: QueryPlan) -> QueryPlan:
    """Fault-isolation gate: reject malformed OBB pools before they launch.

    Checks every condition under which a plan would corrupt (or crash) a
    coalesced engine launch — wrong field shapes, non-float32 dtypes,
    NaN/inf coordinates, non-positive half extents, and lane arrays that
    do not match the pool — and raises :class:`PlanValidationError` naming
    the first offending field.  Pure host-side numpy over the (small)
    request pool; returns the plan unchanged when clean so call sites can
    chain ``submit(validate_plan(plan))``-style.
    """
    q = plan.num_queries
    fields = (("obb_c", plan.obb_c, (q, 3)), ("obb_h", plan.obb_h, (q, 3)),
              ("obb_r", plan.obb_r, (q, 3, 3)))
    for name, arr, want in fields:
        a = np.asarray(arr)
        if a.shape != want:
            raise PlanValidationError(
                f"plan.{name} has shape {a.shape}, want {want}")
        if a.dtype != np.float32:
            raise PlanValidationError(
                f"plan.{name} has dtype {a.dtype}, want float32 (the "
                f"engine's pool dtype; cast before submitting)")
        if not np.isfinite(a).all():
            bad = int(np.flatnonzero(
                ~np.isfinite(a).reshape(q, -1).all(1))[0])
            raise PlanValidationError(
                f"plan.{name} contains NaN/inf (first bad query slot "
                f"{bad}); non-finite OBBs poison every SACT test in the "
                f"coalesced pool")
    h = np.asarray(plan.obb_h)
    if not (h > 0).all():
        bad = int(np.flatnonzero(~(h > 0).all(axis=1))[0])
        raise PlanValidationError(
            f"plan.obb_h must be strictly positive (first degenerate "
            f"query slot {bad}); zero/negative half extents make the "
            f"separating-axis margins meaningless")
    for name, lane in (("scene_of_query", plan.scene_of_query),
                       ("owner_of_query", plan.owner_of_query),
                       ("payload", plan.payload)):
        if lane is None:
            continue
        a = np.asarray(lane)
        if a.shape != (q,) or a.dtype != np.int32:
            raise PlanValidationError(
                f"plan.{name} must be ({q},) int32, got {a.shape} "
                f"{a.dtype}")
    return plan


def _flat_obbs(obbs: OBBs) -> Tuple[jax.Array, jax.Array, jax.Array]:
    return (jnp.reshape(obbs.center, (-1, 3)),
            jnp.reshape(obbs.half, (-1, 3)),
            jnp.reshape(obbs.rot, (-1, 3, 3)))


def plan_queries(obbs: OBBs) -> QueryPlan:
    """Single flat query set: (M,) OBBs against one scene."""
    assert obbs.center.ndim == 2, "plan_queries wants flat (M, 3) fields"
    return QueryPlan(kind="queries", obb_c=obbs.center, obb_h=obbs.half,
                     obb_r=obbs.rot, out_shape=(obbs.n,))


def plan_batch(obbs: OBBs) -> QueryPlan:
    """(B, M) query sets against one scene, lowered to one flat pool.

    Every query keeps its own verdict slot and early exit; the batch
    structure survives only in the un-flattening recipe, so the executor
    runs one traversal over B * M slots instead of vmapping B loops.
    """
    assert obbs.center.ndim == 3, "plan_batch wants (B, M, 3) fields"
    B, M = obbs.center.shape[:2]
    c, h, r = _flat_obbs(obbs)
    return QueryPlan(kind="batch", obb_c=c, obb_h=h, obb_r=r,
                     out_shape=(B, M))


def plan_scenes(obbs: OBBs) -> QueryPlan:
    """S scenes x (M,) queries each: flat pool plus the scene lane."""
    assert obbs.center.ndim == 3, "plan_scenes wants (S, M, 3) fields"
    S, M = obbs.center.shape[:2]
    c, h, r = _flat_obbs(obbs)
    soq = jnp.repeat(jnp.arange(S, dtype=jnp.int32), M)
    return QueryPlan(kind="scenes", obb_c=c, obb_h=h, obb_r=r,
                     out_shape=(S, M), num_scenes=S, scene_of_query=soq)


def plan_trajectory(waypoints: jax.Array, base_pos=None) -> QueryPlan:
    """Joint-space waypoints (..., 7) -> link-OBB pool with an any-link
    reduction: FK emits ``NUM_LINKS`` query slots per waypoint, and the
    un-flattening recipe ORs them back into per-waypoint flags.  Host and
    device engines consume this same plan — the lowering IS the front-end.
    """
    waypoints = jnp.asarray(waypoints, jnp.float32)
    batch_shape = waypoints.shape[:-1]
    obbs = arm_link_obbs(waypoints, base_pos=base_pos)   # flat (prod*L,)
    return QueryPlan(kind="trajectory", obb_c=obbs.center, obb_h=obbs.half,
                     obb_r=obbs.rot,
                     out_shape=tuple(batch_shape) + (NUM_LINKS,),
                     reduce_last=True)


def plan_edges(obbs: OBBs, owner: np.ndarray, num_groups: int,
               payload: Optional[np.ndarray] = None) -> QueryPlan:
    """Swept-edge pool: flat swept OBBs with owner (+ optional payload) lanes.

    ``owner`` groups the slots that decide together (a segment's links, or
    every surviving segment of one edge); ``payload`` carries each slot's
    sub-interval rank for first-hit queries.  Owner ids must be compact —
    every value in ``[0, num_groups)`` with ``num_groups <= len(owner)`` —
    so the executor can compute grouped verdicts in a pool-sized buffer
    without making the group count a compile-time constant.  Built by
    :func:`repro.core.sweep.sweep_edges`.
    """
    assert obbs.center.ndim == 2, "plan_edges wants a flat pool"
    own_np = np.asarray(owner)
    if num_groups > obbs.n or (own_np.size and (
            int(own_np.min()) < 0 or int(own_np.max()) >= num_groups)):
        # Non-compact ids would scatter hits into the sliced-off tail of
        # the executor's Q-sized verdict buffer — a silently lost verdict.
        raise ValueError(
            f"owner ids must be compact in [0, {num_groups}) with "
            f"num_groups <= {obbs.n} query slots")
    own = jnp.asarray(owner, jnp.int32)
    pay = None if payload is None else jnp.asarray(payload, jnp.int32)
    return QueryPlan(kind="edges", obb_c=obbs.center, obb_h=obbs.half,
                     obb_r=obbs.rot, out_shape=(num_groups,),
                     owner_of_query=own, num_groups=num_groups, payload=pay)


__all__ = ["PAYLOAD_INF", "PlanValidationError", "QueryPlan", "WORKLOADS",
           "plan_batch", "plan_edges", "plan_queries", "plan_scenes",
           "plan_trajectory", "validate_plan"]
