"""Plan executor: one engine consuming :class:`repro.engine.plan.QueryPlan`.

DESIGN — plan/execute split
===========================
The front-end shapes the repo serves (single query set, (B, M) batch,
ragged multi-scene, trajectory, swept edge) all lower to one canonical
flat pool — see :mod:`repro.engine.plan`.  This module owns everything
downstream of the lowering, for every plan alike:

  * **mode dispatch** — the paper's Fig. 11 arms (``EngineConfig.mode``,
    DESIGN.md §2): host-loop ablations, the device-resident wavefront
    ``lax.while_loop``, the fused per-level traversal step
    (:mod:`repro.kernels.traverse`), and the persistent whole-traversal
    megakernel (:mod:`repro.kernels.persist`);
  * **the traversal cache** — one jit-compiled traversal per (mode, batch
    kind, capacity, statics), LRU-keyed so repeated engines and
    escalation replays never retrace (:func:`traversal_cache_info`);
  * **capacity escalation** — the frontier runs in a fixed-capacity
    buffer; overflow is counted on device and the query replays at 4x
    capacity until clean (see the README's capacity policy);
  * **counter assembly** — device-side stats become
    :class:`repro.core.counters.Counters`, including the §4 bytes model.

Verdict state generalizes from a boolean per query to an int32 ``best``
per *verdict group* (``PAYLOAD_INF`` = undecided): a terminal hit folds
the pair's payload lane in with a min, and a pair expands only while its
payload could still beat its group's best — which is exactly the boolean
early exit when every slot owns itself and every payload is zero, and
per-edge first-hit with in-traversal early exit for swept-edge plans.
Boolean plans keep the original boolean code path, so verdicts and work
counters of all pre-existing modes are bitwise-identical to the
pre-split engine (CI-enforced).

``core/wavefront.py`` remains as a compatibility shim re-exporting this
module's public names.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
import weakref
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sact as sact_mod
from repro.core.counters import (BYTES_FUSED_STEP, BYTES_FUSED_TEST,
                                 BYTES_META_STREAM, BYTES_META_STREAM_BF16,
                                 BYTES_META_STREAM_U8, BYTES_PAYLOAD_LANE,
                                 BYTES_PERSIST_QUERY, BYTES_PERSIST_SPILL,
                                 BYTES_SHADER_HANDOFF, BYTES_UNFUSED_TEST,
                                 NUM_EXIT_CODES, Counters)
from repro.core.geometry import OBBs
from repro.core.octree import (MAX_DEPTH, DeviceOctree, Octree,
                               concat_device_octrees, device_octree,
                               lookup_children, node_centers_from_codes,
                               stack_device_octrees)
from repro.core.quantize import META_FORMATS
from repro.core.sact import (NUM_AXES, PAYLOAD_INF, SactResult,
                             payload_min_update)
from repro.engine.plan import QueryPlan, plan_batch, plan_queries, plan_scenes
from repro.kernels.compact.ops import compact_pairs
from repro.kernels.persist.ops import (DEFAULT_VMEM_BUDGET, build_tile_map,
                                       choose_meta_layout,
                                       persist_kernel_unsupported,
                                       traverse_whole)
from repro.kernels.traverse.ops import traverse_step

logger = logging.getLogger(__name__)

MODES = ("naive", "rta_like", "staged_noexit", "predicated", "wavefront_host",
         "wavefront", "wavefront_fused", "wavefront_persistent")
#: Modes whose traversal runs fully on-device inside one compiled call.
DEVICE_MODES = ("wavefront", "wavefront_fused", "wavefront_persistent")
#: CSR-frontier modes: multi-scene batches run on the ragged flat frontier.
CSR_MODES = ("wavefront_fused", "wavefront_persistent")
#: Modes whose traversal accepts a static ``max_depth`` cap — the coarser
#: half of the declared degraded mode (DESIGN.md §7).  The per-level arms
#: treat every cap-level node as terminal, so capped verdicts are a
#: conservative superset of full-depth ones.  The persistent megakernel's
#: in-kernel level schedule has no cap; degraded persistent launches
#: shrink the pad bucket only.
DEPTH_CAP_MODES = ("wavefront_host", "wavefront", "wavefront_fused")


def device_loss_count(e: BaseException) -> Optional[int]:
    """Classify an exception as device/mesh loss (DESIGN.md §7): the
    number of shard devices lost, or None if this is not a device-loss
    failure.  Injected :class:`repro.engine.faults.SimulatedDeviceLoss`
    carries a ``device_loss`` attribute and a ``lost`` count; a real
    runtime failure surfaces as an error whose message carries XLA's
    DEVICE_LOST token (count unknown — assume one and let the relaunch
    probe the rest)."""
    if getattr(e, "device_loss", False):
        return max(1, int(getattr(e, "lost", 1)))
    if "DEVICE_LOST" in str(e):
        return 1
    return None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    mode: str = "wavefront"
    use_spheres: bool = False      # MPAccel bounding/inscribing sphere pre-tests
    max_frontier: int = 1 << 20    # hard cap on live pairs per level
    min_bucket: int = 1024         # smallest frontier allocation
    query_block: int = 128         # naive-mode OBB block size
    frontier_capacity: Optional[int] = None  # device engine: static capacity
    use_pallas_compact: Optional[bool] = None  # None = auto (TPU only)
    use_pallas_traverse: Optional[bool] = None  # fused step / persistent
    #                                            megakernel; None = auto
    # Persistent-megakernel metadata residency (DESIGN.md §3): budget for
    # the resident node_meta table, and an explicit layout override
    # (None = residency estimator, True = force streamed windows,
    # False = force the resident block).
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    stream_meta: Optional[bool] = None
    # Node-metadata row format for the CSR modes (DESIGN.md §3): None =
    # the layout/format chooser (fp32 when resident fits, else the
    # narrowest eligible compressed format when streaming); "fp32" /
    # "bf16" / "u8" pin it.  Verdicts and work counters are bitwise
    # format-independent; only bytes streamed and VMEM footprint move.
    meta_format: Optional[str] = None
    # Sharded execution (DESIGN.md §6): split the flat pair pool over a
    # 1-D device mesh of this many devices via shard_map.  None =
    # single-device; any int (including 1) routes through the sharded
    # path, whose verdicts and counters are bitwise-identical to
    # single-device (CI-enforced on 8 virtual CPU devices).
    shards: Optional[int] = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown engine mode {self.mode!r}; allowed modes: "
                f"{', '.join(MODES)}")
        if self.shards is not None:
            if self.mode not in DEVICE_MODES:
                raise ValueError(
                    f"shards={self.shards} needs a device-resident mode "
                    f"({', '.join(DEVICE_MODES)}), not {self.mode!r}")
            if self.shards < 1:
                raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.meta_format is not None:
            if self.meta_format not in META_FORMATS:
                raise ValueError(
                    f"unknown meta_format {self.meta_format!r}; allowed: "
                    f"{', '.join(META_FORMATS)}")
            if self.mode not in CSR_MODES:
                raise ValueError(
                    f"meta_format={self.meta_format!r} needs a CSR mode "
                    f"({', '.join(CSR_MODES)}), not {self.mode!r}: only the "
                    "CSR frontiers decode packed metadata rows")

    @property
    def early_exit(self) -> bool:
        return self.mode in ("predicated", "wavefront_host") + DEVICE_MODES

    @property
    def stage_split(self) -> bool:
        return self.mode in ("wavefront_host",) + DEVICE_MODES

    @property
    def fused(self) -> bool:
        return self.mode == "wavefront_fused"

    @property
    def persistent(self) -> bool:
        return self.mode == "wavefront_persistent"

    @property
    def device_resident(self) -> bool:
        return self.mode in DEVICE_MODES


def _bucket(n: int, cfg: EngineConfig) -> int:
    b = cfg.min_bucket
    while b < n:
        b <<= 1
    return min(b, cfg.max_frontier)


def frontier_capacity_bound(level_counts: Sequence[int], num_queries: int,
                            cfg: EngineConfig) -> int:
    """Static worst-case frontier size for a query set against one tree.

    Level l+1 can hold at most 8x the level-l frontier, and never more than
    every query paired with every occupied node of that level.
    """
    if cfg.frontier_capacity is not None:
        return max(cfg.frontier_capacity, num_queries)
    bound = cap = num_queries                # level 0: one root cell
    for n_l in level_counts[1:]:
        bound = min(bound * 8, num_queries * n_l)
        cap = max(cap, bound)
    cap = min(cap, cfg.max_frontier)
    return max(_bucket(cap, cfg), num_queries)


def _initial_capacity(num_queries: int, cfg: EngineConfig) -> int:
    """First-attempt frontier bucket for the escalate-on-overflow policy.

    The level-0 frontier is exactly one pair per query, and with early exit
    most scenes never outgrow that by much — so guess the bucket that holds
    M and let overflow replays buy more only when traversal proves it needs
    it.  Over-guessing costs every level of every query; under-guessing
    costs one replay."""
    if cfg.frontier_capacity is not None:
        return max(cfg.frontier_capacity, num_queries)
    guess = min(max(num_queries, cfg.min_bucket), cfg.max_frontier)
    return max(_bucket(guess, cfg), num_queries)


def _escalate(run, num_queries: int, worst: int, cfg: EngineConfig,
              start: Optional[int] = None):
    """Run ``run(capacity)`` -> (verdict, stats), replaying at 4x capacity
    while the completed call reports frontier overflow.  A pinned
    ``frontier_capacity`` disables escalation (deterministic latency).

    ``start`` seeds the first attempt (the engine remembers the last clean
    capacity per query shape, so repeat queries skip the replay ladder).
    Returns (verdict, stats, clean_capacity, num_replays).
    """
    cap = _initial_capacity(num_queries, cfg)
    if start is not None and cfg.frontier_capacity is None:
        cap = min(max(start, cap), max(worst, num_queries))
    replays = 0
    while True:
        verdict, st = run(cap)
        if cfg.frontier_capacity is not None or cap >= worst:
            return verdict, st, cap, replays
        if int(jax.device_get(jnp.sum(st["overflow"]))) == 0:
            return verdict, st, cap, replays
        cap = min(max(cap * 4, cfg.min_bucket), worst)
        replays += 1


# ---------------------------------------------------------------------------
# Device-resident traversal (one jit-compiled while_loop, no host syncs)
# ---------------------------------------------------------------------------

def _empty_stats():
    return dict(
        nodes=jnp.int32(0), leaf=jnp.int32(0), axis_exec=jnp.int32(0),
        axis_dec=jnp.int32(0), sphere=jnp.int32(0), overflow=jnp.int32(0),
        per_level=jnp.zeros((MAX_DEPTH + 1,), jnp.int32),
        exit_hist=jnp.zeros((NUM_EXIT_CODES,), jnp.int32))


def _verdict_init(num_queries: int, grouped: bool):
    """Boolean verdicts (one per query) or payload-lane int32 ``best`` cells.

    Grouped verdicts are allocated one cell per query slot regardless of the
    plan's group count (owner ids are compact, ``G <= Q``; the executor
    slices the first G cells after the call) so the group count never
    becomes a compile-time constant — refinement rounds with shifting group
    counts reuse the same traced traversal.
    """
    if not grouped:
        return jnp.zeros((num_queries,), bool)
    return jnp.full((num_queries,), PAYLOAD_INF, jnp.int32)


def _lane_payload(payload, q_idx):
    return (jnp.zeros(q_idx.shape, jnp.int32) if payload is None
            else payload[q_idx])


def _lane_owner(owner, q_idx):
    return q_idx if owner is None else owner[q_idx]


def _traverse(obb_c, obb_h, obb_r, dev: DeviceOctree, capacity: int,
              use_spheres: bool, use_pallas: bool, owner=None, payload=None,
              num_valid=None, max_depth: Optional[int] = None):
    """Full multi-level wavefront traversal for one query set / one scene.

    Pure function of device arrays; composes under jit and vmap.  Returns
    (verdict, stats dict) — (M,) bool collide flags, or with owner /
    payload lanes the (M,) int32 payload-lane ``best`` array (cells past
    the plan's group count unused).

    ``num_valid`` (traced int32, default all M) marks the pool's live
    prefix: slots past it never seed the frontier and add zero work to
    every counter, so a padded pool traverses bitwise like its unpadded
    prefix (the sharded executor's per-shard padding relies on this).

    ``max_depth`` (static) caps traversal at that level: every node of
    the cap level is treated terminal, so an overlap there counts as a
    hit.  Capped verdicts are a conservative SUPERSET of the full-depth
    ones (possible false positives at cap-cell granularity, never a
    missed collision) — the declared degraded mode of DESIGN.md §7.
    """
    M = obb_c.shape[0]
    grouped = owner is not None or payload is not None
    depth = dev.depth if max_depth is None else min(dev.depth, max_depth)
    lane = jnp.arange(capacity, dtype=jnp.int32)
    eight = jnp.arange(8, dtype=jnp.uint32)

    def level_row(arr, level):
        return jax.lax.dynamic_index_in_dim(arr, level, keepdims=False)

    def body(carry):
        level, n_live, q_idx, codes, verdict, st = carry
        valid = lane < n_live
        cell = level_row(dev.cell_sizes, level)
        node_c, node_h = node_centers_from_codes(codes, dev.scene_lo, cell)
        res = sact_mod.sact_frontier(
            obb_c[q_idx], obb_h[q_idx], obb_r[q_idx], node_c, node_h, valid,
            use_spheres=use_spheres)

        # Terminal nodes: leaves, or internal nodes with a full subtree.
        codes_l = level_row(dev.codes, level)
        pos = jnp.clip(jnp.searchsorted(codes_l, codes), 0,
                       codes_l.shape[0] - 1)
        is_term = jnp.where(level == depth, True, level_row(dev.full, level)[pos])
        overlap = res.collide & valid
        term_hit = overlap & is_term
        if grouped:
            pay = _lane_payload(payload, q_idx)
            own = _lane_owner(owner, q_idx)
            verdict = payload_min_update(verdict, own, pay, term_hit)
            undecided = pay < verdict[own]
        else:
            verdict = verdict.at[q_idx].max(term_hit)
            undecided = ~verdict[q_idx]

        # ---- work accounting (device-side; fetched once post-call) -------
        n_valid = jnp.sum(valid.astype(jnp.int32))
        term_valid = (valid & is_term).astype(jnp.int32)
        st = dict(
            nodes=st["nodes"] + n_valid,
            leaf=st["leaf"] + jnp.sum(term_valid),
            axis_exec=st["axis_exec"] + jnp.sum(res.axis_tests),
            axis_dec=st["axis_dec"] + n_valid * NUM_AXES,
            sphere=st["sphere"] + jnp.sum(res.sphere_tests),
            overflow=st["overflow"],
            per_level=st["per_level"].at[level].set(n_valid),
            exit_hist=st["exit_hist"].at[res.exit_code].add(term_valid))

        # ---- expansion + on-device stream compaction ---------------------
        child_codes_l = level_row(dev.codes, jnp.minimum(level + 1, depth))
        cand = (codes[:, None] << jnp.uint32(3)) | eight[None, :]   # (cap, 8)
        cpos = jnp.clip(
            jnp.searchsorted(child_codes_l, cand.reshape(-1)), 0,
            child_codes_l.shape[0] - 1).reshape(cand.shape)
        found = child_codes_l[cpos] == cand
        # Early exit: decided queries retire their whole wavefront share.
        expand = overlap & ~is_term & undecided
        child_mask = (expand[:, None] & found).reshape(-1)          # (cap*8,)
        n_new = jnp.sum(child_mask.astype(jnp.int32))
        cnt, q_next, codes_next = compact_pairs(
            child_mask, jnp.repeat(q_idx, 8), cand.reshape(-1), capacity,
            use_pallas=use_pallas)
        st["overflow"] = st["overflow"] + jnp.maximum(n_new - capacity, 0)
        return level + 1, cnt, q_next, codes_next, verdict, st

    def cond(carry):
        level, n_live = carry[0], carry[1]
        return (level <= depth) & (n_live > 0)

    q0 = jnp.where(lane < M, lane, 0)
    nv = jnp.asarray(M if num_valid is None else num_valid, jnp.int32)
    carry0 = (jnp.int32(0), jnp.minimum(nv, jnp.int32(capacity)),
              q0, jnp.zeros((capacity,), jnp.uint32),
              _verdict_init(M, grouped), _empty_stats())
    _, _, _, _, verdict, st = jax.lax.while_loop(cond, body, carry0)
    return verdict, st


def _traverse_fused(obb_c, obb_h, obb_r, dev: DeviceOctree, capacity: int,
                    use_spheres: bool, use_pallas: bool,
                    use_pallas_traverse: Optional[bool], owner=None,
                    payload=None, num_valid=None,
                    max_depth: Optional[int] = None):
    """Fused multi-level wavefront traversal (``mode="wavefront_fused"``).

    Same while_loop skeleton and work accounting as :func:`_traverse`, but
    each level is one :func:`repro.kernels.traverse.ops.traverse_step`: the
    frontier carries (query, CSR node index) pairs — codes, terminality and
    child occupancy are O(1) CSR gathers instead of searchsorted probes —
    the staged SACT culls in two phases, and the per-level HBM-resident
    intermediates reduce to frontier-in / frontier-out.  Verdicts and work
    counters are bitwise-identical to :func:`_traverse`.

    ``max_depth`` (static) stops traversal at that level; the step kernel
    only treats TRUE leaves/full subtrees as terminal, so the cap level's
    still-internal overlaps are folded into the verdict here — every
    overlap at the cap counts as a hit, keeping capped verdicts the same
    conservative superset :func:`_traverse` produces (boolean plans only;
    the executor never routes grouped plans through a depth cap).
    """
    M = obb_c.shape[0]
    depth = dev.depth if max_depth is None else min(dev.depth, max_depth)
    capped = depth < dev.depth
    assert not (capped and (owner is not None or payload is not None)), \
        "depth-capped traversal serves boolean plans only"
    lane = jnp.arange(capacity, dtype=jnp.int32)

    def body(carry):
        level, n_live, q_idx, node_idx, verdict, st = carry
        n_next, q_next, idx_next, verdict, info = traverse_step(
            obb_c, obb_h, obb_r, dev, level, n_live, q_idx, node_idx,
            verdict, use_spheres=use_spheres,
            use_pallas=use_pallas_traverse, use_pallas_compact=use_pallas,
            owner=owner, payload=payload)
        res, valid, is_term = info["res"], info["valid"], info["is_term"]
        if capped:
            cap_hit = (res.collide & valid & ~is_term
                       & (level == jnp.int32(depth)))
            verdict = verdict.at[q_idx].max(cap_hit)

        # ---- work accounting (identical formulas to the unfused arm) -----
        n_valid = jnp.sum(valid.astype(jnp.int32))
        term_valid = (valid & is_term).astype(jnp.int32)
        st = dict(
            nodes=st["nodes"] + n_valid,
            leaf=st["leaf"] + jnp.sum(term_valid),
            axis_exec=st["axis_exec"] + jnp.sum(res.axis_tests),
            axis_dec=st["axis_dec"] + n_valid * NUM_AXES,
            sphere=st["sphere"] + jnp.sum(res.sphere_tests),
            overflow=st["overflow"] + jnp.maximum(info["n_new"] - capacity,
                                                  0),
            per_level=st["per_level"].at[level].set(n_valid),
            exit_hist=st["exit_hist"].at[res.exit_code].add(term_valid))
        return level + 1, n_next, q_next, idx_next, verdict, st

    def cond(carry):
        level, n_live = carry[0], carry[1]
        return (level <= depth) & (n_live > 0)

    q0 = jnp.where(lane < M, lane, 0)
    nv = jnp.asarray(M if num_valid is None else num_valid, jnp.int32)
    carry0 = (jnp.int32(0), jnp.minimum(nv, jnp.int32(capacity)),
              q0, jnp.zeros((capacity,), jnp.int32),
              _verdict_init(M, owner is not None or payload is not None),
              _empty_stats())
    out = jax.lax.while_loop(cond, body, carry0)
    return out[4], out[5]


#: Trace counts per cached-traversal key; Python side effects run only at
#: trace time, so a key whose count stays 1 proved its cache hits.
_TRACE_COUNTS: dict = {}

#: Sentinel for "use the config's value" in per-call overrides.
_UNSET = object()


@functools.lru_cache(maxsize=None)
def _traversal_fn(mode: str, batch: str, capacity: int, use_spheres: bool,
                  use_pallas, use_pallas_traverse, streamed: bool = False,
                  meta_format: str = "fp32",
                  max_depth: Optional[int] = None):
    """One jit-compiled traversal per (mode, batch kind, capacity, statics).

    The LRU gives every (mode, capacity, ...) configuration a *stable
    callable identity*, so jax.jit's shape-keyed cache persists across
    overflow-escalation replays and across repeated ``CollisionEngine``
    constructions on same-shaped scenes — neither retraces.  See
    :func:`traversal_cache_info` for the observability hook tests use.

    ``streamed`` / ``meta_format`` are the persistent megakernel's
    metadata-residency layout and packed row format (the executor's
    chooser picks them per engine, so the choice is part of this cache
    key like every other static — ``meta_format`` also rides the device
    tree's pytree aux, which is what actually drives the traced decode;
    keying it here keeps the cache observability honest when the same
    engine shape flips format).
    """
    key = (mode, batch, capacity, use_spheres, use_pallas,
           use_pallas_traverse, streamed, meta_format, max_depth)

    def base(c, h, r, d, soq=None, owner=None, payload=None, tiles=None):
        _TRACE_COUNTS[key] = _TRACE_COUNTS.get(key, 0) + 1
        if mode == "wavefront_persistent" or soq is not None or \
                tiles is not None:
            assert max_depth is None, \
                "the persistent/ragged arms have no depth cap (DESIGN.md §7)"
            # Whole-traversal megakernel / live-prefix ref; the ragged
            # multi-scene flat frontier (soq or a pre-built tile map)
            # also lands here for every CSR mode.  Only the persistent
            # mode may take the megakernel arm — the fused mode's ragged
            # pool is ref-served so its counters stay the per-level
            # arm's (its own Pallas kernel is the per-level step).
            return traverse_whole(c, h, r, d, capacity,
                                  use_spheres=use_spheres,
                                  use_pallas=(use_pallas_traverse
                                              if mode == "wavefront_persistent"
                                              else False),
                                  scene_of_query=soq, owner_of_query=owner,
                                  payload=payload, streamed=streamed,
                                  tiles=tiles)
        if mode == "wavefront_fused":
            return _traverse_fused(c, h, r, d, capacity, use_spheres,
                                   use_pallas, use_pallas_traverse,
                                   owner=owner, payload=payload,
                                   max_depth=max_depth)
        return _traverse(c, h, r, d, capacity, use_spheres, use_pallas,
                         owner=owner, payload=payload, max_depth=max_depth)

    if batch == "single":
        fn = base
    elif batch == "scenes":      # padded stacked scenes (legacy vmap path)
        def fn(c, h, r, d, soq=None, owner=None, payload=None, tiles=None):
            assert soq is None and owner is None and payload is None \
                and tiles is None, \
                "the padded-scenes vmap path has no scene/owner/payload lanes"
            return jax.vmap(lambda cc, hh, rr, dd: base(cc, hh, rr, dd))(
                c, h, r, d)
    else:
        raise ValueError(f"unknown batch kind {batch!r}; the plan/executor "
                         f"split serves 'single' (flat pool) and 'scenes'")
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _sharded_traversal_fn(mode: str, capacity: int, use_spheres: bool,
                          use_pallas, use_pallas_traverse, streamed: bool,
                          shards: int, max_depth: Optional[int] = None):
    """Sharded sibling of :func:`_traversal_fn` (DESIGN.md §6).

    One shard_map-wrapped jit-compiled traversal per (mode, capacity,
    statics, shard count): the flat pool — padded by the executor so the
    shard count divides it — splits into equal contiguous blocks over the
    collision mesh, the scene tables replicate, and each device traverses
    its block with the SAME per-shard frontier capacity a single-device
    run would use, masking its pad slots via the live-prefix ``num_valid``
    lane.  Work counters psum to the single-device values; ``overflow``
    is a global max so the host escalation loop replays all shards in
    lockstep (see :func:`repro.parallel.sharding.shard_collision_traversal`).
    """
    from repro.parallel.sharding import (make_collision_mesh,
                                         shard_collision_traversal)
    key = (mode, "sharded", capacity, use_spheres, use_pallas,
           use_pallas_traverse, streamed, shards, max_depth)

    def local(nv, c, h, r, d):
        _TRACE_COUNTS[key] = _TRACE_COUNTS.get(key, 0) + 1
        if mode == "wavefront_persistent":
            assert max_depth is None, \
                "the persistent arm has no depth cap (DESIGN.md §7)"
            return traverse_whole(c, h, r, d, capacity,
                                  use_spheres=use_spheres,
                                  use_pallas=use_pallas_traverse,
                                  streamed=streamed, num_valid=nv)
        if mode == "wavefront_fused":
            return _traverse_fused(c, h, r, d, capacity, use_spheres,
                                   use_pallas, use_pallas_traverse,
                                   num_valid=nv, max_depth=max_depth)
        return _traverse(c, h, r, d, capacity, use_spheres, use_pallas,
                         num_valid=nv, max_depth=max_depth)

    mesh = make_collision_mesh(shards)
    sm = jax.jit(shard_collision_traversal(local, mesh))

    def call(counts, c, h, r, d):
        # The wrapper's stats come back with a leading shard axis of
        # identical (already psum/pmax-reduced) rows; read row 0 so the
        # escalation loop and counter assembly see single-device shapes.
        verdict, st = sm(counts, c, h, r, d)
        return verdict, {k: v[0] for k, v in st.items()}

    return call


def traversal_cache_info() -> dict:
    """Cache observability: lru entries + per-key trace counts."""
    info = _traversal_fn.cache_info()
    sharded = _sharded_traversal_fn.cache_info()
    return dict(hits=info.hits, misses=info.misses,
                entries=info.currsize, sharded_entries=sharded.currsize,
                traces=dict(_TRACE_COUNTS))


def _stats_to_counters(st, mode: str, replays: int = 0,
                       extra_lanes: int = 0,
                       meta_format: str = "fp32") -> Counters:
    st = jax.device_get(st)
    c = Counters()

    def tot(x):
        return int(np.sum(np.asarray(st[x], np.int64)))

    c.nodes_traversed = tot("nodes")
    c.leaf_tests = tot("leaf")
    c.axis_tests_executed = tot("axis_exec")
    c.axis_tests_decoded = tot("axis_dec")
    c.sphere_tests = tot("sphere")
    c.frontier_overflow = tot("overflow")
    c.escalations = replays
    per = np.asarray(st["per_level"], np.int64)
    if per.ndim > 1:                       # batched: sum lanes per level
        per = per.reshape(-1, per.shape[-1]).sum(axis=0)
    c.nodes_per_level = [int(n) for n in per if n > 0]
    hist = np.asarray(st["exit_hist"], np.int64)
    c.exit_histogram += hist.reshape(-1, hist.shape[-1]).sum(axis=0)
    if "meta_rows" in st:
        c.meta_rows_streamed = tot("meta_rows")
    # Streamed rows are priced at the packed row format's width (the row
    # COUNT is format-independent — see counters.py).
    row_bytes = {"fp32": BYTES_META_STREAM, "bf16": BYTES_META_STREAM_BF16,
                 "u8": BYTES_META_STREAM_U8}[meta_format]
    c.meta_bytes_streamed = c.meta_rows_streamed * row_bytes
    # Bytes models (see counters.py): per-level arms move the frontier
    # through HBM every level; the persistent megakernel only moves each
    # query's seed in / verdict out, plus the streamed layout's metadata
    # window rows.  Grouped plans pay one extra int32 lane per frontier
    # pair (per seed, for the persistent arm) for each lane they carry —
    # owner and/or payload.
    extra = BYTES_PAYLOAD_LANE * extra_lanes
    if mode == "wavefront_persistent":
        seeds = int(per[0]) if per.size else 0
        c.bytes_moved = (seeds * (BYTES_PERSIST_QUERY + extra)
                         + c.frontier_overflow * BYTES_PERSIST_SPILL
                         + c.meta_bytes_streamed)
    elif mode == "wavefront_fused":
        c.bytes_moved = c.nodes_traversed * (BYTES_FUSED_STEP + extra)
    else:
        c.bytes_moved = c.nodes_traversed * (BYTES_UNFUSED_TEST + extra)
    return c


@functools.partial(jax.jit, static_argnames=("use_spheres", "stage_split"))
def _test_pairs(obb_c, obb_h, obb_r, node_c, node_h, valid,
                use_spheres: bool, stage_split: bool) -> SactResult:
    """Staged SACT on a host-managed frontier of pairs.

    With ``stage_split`` the edge axes are evaluated behind a
    ``lax.select``-style mask (their cost is counted separately by the work
    model); the wall-clock stage split happens at the frontier level via
    bucket resizing, which is where static-shape hardware can actually save.
    """
    del stage_split
    return sact_mod.sact_frontier(obb_c, obb_h, obb_r, node_c, node_h, valid,
                                  use_spheres=use_spheres)


@functools.partial(jax.jit, static_argnames=("n_out",))
def _compact(mask: jax.Array, n_out: int, *arrays):
    """Pack entries where mask is True to the front of fresh (n_out,) arrays."""
    idx = jnp.nonzero(mask, size=n_out, fill_value=mask.shape[0])[0]
    in_range = idx < mask.shape[0]
    idx_c = jnp.minimum(idx, mask.shape[0] - 1)
    out = tuple(jnp.where(in_range.reshape((-1,) + (1,) * (a.ndim - 1)),
                          a[idx_c], 0) for a in arrays)
    return (in_range,) + out


#: Device scene-table memo for repeat multi-scene batches: building the
#: concatenated/stacked level tables is a host-side numpy pass over every
#: level of every scene plus a device transfer — far more than a warm
#: traversal costs.  Keyed by the octree objects' identities; weakrefs
#: guard against id reuse after GC (a dead ref can never alias a live key).
_TABLE_CACHE: dict = {}
_TABLE_CACHE_MAX = 8


def _scene_tables(octrees: List[Octree], padded: bool, fmt: str = "fp32"):
    key = (padded, fmt, tuple(id(t) for t in octrees))
    hit = _TABLE_CACHE.get(key)
    if hit is not None:
        refs, tables = hit
        if all(r() is t for r, t in zip(refs, octrees)):
            return tables
    tables = (stack_device_octrees(octrees) if padded
              else concat_device_octrees(octrees, meta_format=fmt))
    while len(_TABLE_CACHE) >= _TABLE_CACHE_MAX:
        _TABLE_CACHE.pop(next(iter(_TABLE_CACHE)))
    _TABLE_CACHE[key] = ([weakref.ref(t) for t in octrees], tables)
    return tables


class CollisionEngine:
    """Octree collision queries for fixed scene(s), in a selectable mode.

    The engine is the executor of :class:`repro.engine.plan.QueryPlan`:
    ``execute`` serves any lowered plan, and ``query`` /
    ``query_batched`` are thin front-ends that build the obvious plan.
    Construct with one :class:`Octree` for single-scene service or a list
    for multi-scene plans (``plan_scenes``).
    """

    def __init__(self, octree: Union[Octree, List[Octree]],
                 config: EngineConfig = EngineConfig()):
        self.cfg = config
        # Last clean frontier capacity per (query shape, scene signature):
        # repeat queries start there instead of re-climbing the escalation
        # ladder.  The scene node counts are part of every key so a
        # rebind to a grown scene can never reuse a stale clean capacity
        # (which could skip the ladder and silently overflow-spill).
        self._cap_memo: dict = {}
        # Device-loss seam (DESIGN.md §7): an optional callable invoked
        # with the shard count at the top of every sharded launch attempt
        # — the chaos harness installs one that raises SimulatedDeviceLoss
        # so the re-shard/relaunch recovery below it is exercised, not
        # just the batcher's typed-error translation.
        self.device_fault_injector = None
        # Surviving shard count after device loss (None = all of
        # cfg.shards healthy).  Sticky across calls — lost devices do not
        # come back on their own; ``set_shards`` re-probes the full set.
        self._healthy_shards: Optional[int] = None
        self.rebind_octrees(octree)

    def rebind_octrees(self, octree: Union[Octree, List[Octree]]) -> None:
        """(Re)bind the engine to new scene(s), keeping config and caches.

        Growing a scene between calls is a supported pattern (e.g. a
        mapping robot accreting points): derived device state is rebuilt
        lazily, and the clean-capacity memo — which survives the rebind —
        is keyed on the scenes' node counts, so queries against the grown
        scene re-enter the escalation ladder instead of inheriting the old
        scene's (possibly too small, silently spilling) clean capacity.
        """
        self.octrees = (list(octree) if isinstance(octree, (list, tuple))
                        else [octree])
        self.octree = self.octrees[0]
        self._scene_lo = jnp.asarray(self.octree.scene_lo)
        self._level_codes = [jnp.asarray(l.codes) for l in self.octree.levels]
        self._level_full = [jnp.asarray(l.full) for l in self.octree.levels]
        self._dev: dict = {}               # packed device tables by format
        # The layout/format choice depends on the bound scene's size
        # class, so a rebind must re-run the chooser: a scene grown past
        # a residency or format-eligibility boundary would otherwise keep
        # a stale (layout, format) decision — and with it a stale cache
        # key — from the smaller scene.
        self._meta_choice = None
        # Per-scene total node counts: the memo-key scene signature.
        self._scene_sig = tuple(
            sum(len(l.codes) for l in t.levels) for t in self.octrees)
        # Every memo key ends with the scene signature; entries for
        # superseded scenes can never be read again, so drop them — a
        # long accreting-scene loop keeps the memo bounded by the query
        # shapes of the CURRENT scene.
        self._cap_memo = {k: v for k, v in self._cap_memo.items()
                          if k[-1] == self._scene_sig}

    # ------------------------------------------------------------------
    # Elastic sharding surface (DESIGN.md §6/§7): the batcher reads
    # active_shards / scene_nodes and rescales via set_shards.
    # ------------------------------------------------------------------
    @property
    def scene_nodes(self) -> int:
        """Total node count of the bound scene(s) — the per-query factor
        of the service's predicted-work admission estimate."""
        return sum(self._scene_sig)

    @property
    def active_shards(self) -> Optional[int]:
        """Shards the next sharded launch will use: ``cfg.shards`` minus
        devices lost to (possibly injected) device-loss recoveries; None
        for an unsharded engine."""
        if self.cfg.shards is None:
            return None
        return (self._healthy_shards if self._healthy_shards is not None
                else self.cfg.shards)

    @property
    def supports_depth_cap(self) -> bool:
        """Whether ``execute(plan, max_depth=...)`` can cap this engine's
        traversal depth (the coarser half of the degraded mode)."""
        return self.cfg.mode in DEPTH_CAP_MODES

    def set_shards(self, shards: int) -> None:
        """Elastic width: rebind the engine to an ``shards``-device
        collision mesh (the batcher's autoscaler calls this between
        launches).  Resets the device-loss bookkeeping — a rescale
        re-probes the full device set, which is how a recovered device
        rejoins the mesh."""
        if self.cfg.shards is None:
            raise ValueError(
                "set_shards needs an engine constructed with cfg.shards; "
                "unsharded engines have no collision mesh to resize")
        n_dev = len(jax.devices())
        if not 1 <= shards <= n_dev:
            raise ValueError(
                f"shards must be in [1, {n_dev}] (visible devices), "
                f"got {shards}")
        self.cfg = dataclasses.replace(self.cfg, shards=shards)
        self._healthy_shards = None

    def _device_tree(self, fmt: str) -> DeviceOctree:
        """Padded level arrays packed in ``fmt``, cached per format."""
        if fmt not in self._dev:
            self._dev[fmt] = device_octree(self.octree, meta_format=fmt)
        return self._dev[fmt]

    @property
    def device_tree(self) -> DeviceOctree:
        """Packed level arrays for the device-resident engine (lazy); the
        CSR modes get this engine's chosen row format, the Morton-code
        frontier (``mode="wavefront"``) always fp32 (it never reads the
        packed rows, but shares the table builder)."""
        fmt = self.meta_format if self.cfg.mode in CSR_MODES else "fp32"
        return self._device_tree(fmt)

    def _choose_meta(self):
        """Run (and memoize) the layout x format chooser for this engine's
        scene(s).  Multi-scene engines size the CONCATENATED flat table
        (per-level totals across scenes) — the table the CSR modes
        actually hold — so ragged batches stream and compress on the same
        budget rules as single scenes."""
        if self._meta_choice is None:
            n_levels = max(len(t.levels) for t in self.octrees)
            n_max = max(
                sum(len(t.levels[l].codes) if l < len(t.levels) else 0
                    for t in self.octrees)
                for l in range(n_levels))
            layout = (None if self.cfg.stream_meta is None else
                      ("streamed" if self.cfg.stream_meta else "resident"))
            self._meta_choice = choose_meta_layout(
                self.octree.depth, n_max, self.cfg.vmem_budget,
                fmt=self.cfg.meta_format, layout=layout)
        return self._meta_choice

    @property
    def meta_layout(self) -> str:
        """Persistent-megakernel metadata residency for this engine's
        scene: ``"resident"`` or ``"streamed"`` (DESIGN.md §3).  Driven by
        the layout/format chooser against ``cfg.vmem_budget`` unless
        ``cfg.stream_meta`` pins it; feeds the traversal cache key."""
        return self._choose_meta().layout

    @property
    def meta_format(self) -> str:
        """Packed node-metadata row format for this engine's scene
        ("fp32" | "bf16" | "u8", DESIGN.md §3).  ``cfg.meta_format`` pins
        it; otherwise the chooser's pick for the persistent megakernel,
        and fp32 for every other mode (the fused arm decodes any format
        but only compresses when asked — its table is never the VMEM
        bound)."""
        if self.cfg.meta_format is not None:
            return self.cfg.meta_format
        if self.cfg.persistent:
            return self._choose_meta().fmt
        return "fp32"

    def _capacity(self, num_queries: int) -> int:
        counts = [len(l.codes) for l in self.octree.levels]
        return frontier_capacity_bound(counts, num_queries, self.cfg)

    # ------------------------------------------------------------------
    # Front-ends: build the obvious plan, execute it.
    # ------------------------------------------------------------------
    def query(self, obbs: OBBs) -> Tuple[np.ndarray, Counters]:
        return self.execute(plan_queries(obbs))

    def query_batched(self, obbs: OBBs) -> Tuple[np.ndarray, Counters]:
        """Batched front-end: OBB fields carry a leading batch axis.

        ``obbs.center`` is (B, M, 3) (likewise half/rot); the batch lowers
        to ONE flat pool of B * M query slots traversed in a single
        compiled call — for host modes too, which is what lets benchmarks
        report the device speedup on identical work.  Returns ((B, M)
        verdicts, aggregate counters).
        """
        return self.execute(plan_batch(obbs))

    # ------------------------------------------------------------------
    # The executor.
    # ------------------------------------------------------------------
    def execute(self, plan: QueryPlan,
                max_depth: Optional[int] = None
                ) -> Tuple[np.ndarray, Counters]:
        """Run one lowered plan; returns (un-flattened verdicts, counters).

        Boolean plans yield bool verdicts in the plan's native shape;
        payload-lane plans yield the int32 per-group ``best`` payloads
        (``PAYLOAD_INF`` = group never hit).

        ``max_depth`` caps traversal depth for degraded-mode service
        (``DEPTH_CAP_MODES`` only; single-scene boolean plans): the cap
        level is treated terminal, so verdicts are a conservative
        superset of the full-depth run — coarser, never missing a
        collision.
        """
        t0 = time.perf_counter()
        if plan.num_scenes != len(self.octrees):
            raise ValueError(
                f"plan carries {plan.num_scenes} scene(s) but the engine "
                f"holds {len(self.octrees)}")
        assert plan.num_scenes == 1 or self.cfg.device_resident, \
            "multi-scene batching needs a device mode"
        if plan.grouped and not self.cfg.device_resident:
            raise ValueError(
                "owner/payload plans need a device-resident mode; lower to "
                "a boolean plan and reduce on the host instead")
        if max_depth is not None:
            if not self.supports_depth_cap:
                raise ValueError(
                    f"max_depth needs a depth-cappable mode "
                    f"({', '.join(DEPTH_CAP_MODES)}), not "
                    f"{self.cfg.mode!r}")
            if plan.grouped or plan.num_scenes > 1:
                raise ValueError(
                    "max_depth serves single-scene boolean plans (the "
                    "degraded service path); grouped/multi-scene plans "
                    "run at full depth")
            if max_depth < 1:
                raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        if self.cfg.shards is not None:
            value, counters = self._exec_sharded(plan, max_depth)
        elif self.cfg.mode == "naive":
            value, counters = self._exec_naive(plan)
        elif self.cfg.device_resident:
            value, counters = self._exec_device(plan, max_depth)
        else:
            value, counters = self._exec_host(plan, max_depth)
        counters.wall_time_s = time.perf_counter() - t0
        counters.num_queries = plan.num_queries
        return plan.unflatten(value), counters

    # ------------------------------------------------------------------
    def _run(self, capacity: int, batch: str = "single",
             streamed: bool = False, meta_format: str = "fp32",
             use_pallas_traverse=_UNSET, max_depth: Optional[int] = None):
        """Cached jit-compiled traversal for this engine's config.

        ``use_pallas_traverse`` overrides the config's setting (the
        persistent executor resolves arm routing per plan — capability
        fallbacks pin the ref arm for that plan only)."""
        upt = (self.cfg.use_pallas_traverse
               if use_pallas_traverse is _UNSET else use_pallas_traverse)
        return _traversal_fn(self.cfg.mode, batch, capacity,
                             self.cfg.use_spheres,
                             self.cfg.use_pallas_compact,
                             upt, streamed, meta_format, max_depth)

    def _exec_device(self, plan: QueryPlan,
                     max_depth: Optional[int] = None):
        cfg = self.cfg
        Q = plan.num_queries
        owner, payload = plan.owner_of_query, plan.payload
        fmt = self.meta_format if cfg.mode in CSR_MODES else "fp32"
        # Metadata residency is picked here, per (mode, statics) cache
        # key, so paper-scale scenes run the persistent megakernel with
        # streamed windows instead of needing a different mode — for
        # EVERY plan shape: ragged multi-scene batches and cross-slot
        # owner (swept-edge) plans are owner-group tiled onto the same
        # kernel (per-scene sub-level windows key each tile's schedule
        # to its own scene), so they stream and compress like single
        # scenes.
        streamed = cfg.persistent and self.meta_layout == "streamed"
        # Kernel-arm routing (persistent mode): the only ref-arm routes
        # left are named capability gaps — counted in
        # ``Counters.ref_arm_fallbacks`` and logged with the plan shape,
        # never silent.
        kernel_arm = (cfg.use_pallas_traverse
                      if cfg.use_pallas_traverse is not None
                      else jax.default_backend() == "tpu")
        fallback_reason = None
        if cfg.persistent:
            fallback_reason = persist_kernel_unsupported(
                owner, plan.scene_of_query)
            if fallback_reason is not None:
                if kernel_arm:
                    logger.debug(
                        "persistent plan %s routed to the ref arm: %s",
                        plan.shape_tag, fallback_reason)
                kernel_arm = False
        upt = kernel_arm if cfg.persistent else cfg.use_pallas_traverse
        # Plans whose verdict groups or scenes cross query-tile
        # boundaries run as an owner-group tiled pool (pre-built here,
        # eagerly — the tile map needs concrete ids — and passed through
        # jit as arrays); capability fallbacks keep the untiled legacy
        # ref routing.
        tiled = (cfg.persistent and fallback_reason is None
                 and (plan.num_scenes > 1 or owner is not None))
        tiles = None
        if tiled:
            tm = build_tile_map(
                Q, 128,
                None if plan.scene_of_query is None
                else np.asarray(plan.scene_of_query),
                None if owner is None else np.asarray(owner))
            perm = np.maximum(tm.perm, 0)
            run_args = (jnp.asarray(plan.obb_c)[perm],
                        jnp.asarray(plan.obb_h)[perm],
                        jnp.asarray(plan.obb_r)[perm])
            owner_t = None if owner is None else jnp.asarray(owner)[perm]
            payload_t = (None if payload is None
                         else jnp.asarray(payload)[perm])
            tiles = jax.tree.map(jnp.asarray, tm.tiles)
        if plan.num_scenes > 1 and cfg.mode in CSR_MODES:
            # Ragged flat frontier: one pool of (scene, query, CSR node)
            # triples over the concatenated multi-scene table.
            multi = _scene_tables(self.octrees, padded=False, fmt=fmt)
            per_scene = Q // plan.num_scenes
            worst = min(
                sum(frontier_capacity_bound([len(l.codes) for l in t.levels],
                                            per_scene, cfg)
                    for t in self.octrees),
                max(cfg.max_frontier, Q))
            memo_key = ("csr_scenes", Q, plan.grouped, self._scene_sig)
            if tiled:
                run = lambda cap: self._run(
                    cap, streamed=streamed, meta_format=fmt,
                    use_pallas_traverse=upt)(
                        *run_args, multi, None, owner_t, payload_t, tiles)
            else:
                run = lambda cap: self._run(
                    cap, streamed=streamed, meta_format=fmt,
                    use_pallas_traverse=upt)(
                        plan.obb_c, plan.obb_h, plan.obb_r, multi,
                        plan.scene_of_query, owner, payload)
            verdict, st, cap, replays = _escalate(
                run, Q, worst, cfg, start=self._cap_memo.get(memo_key))
        elif plan.num_scenes > 1:
            # mode="wavefront" keeps the legacy padded-vmap path (its
            # frontier carries Morton codes, not CSR indices) for A/B.
            assert not plan.grouped, \
                "owner/payload plans need a CSR mode for multi-scene batches"
            dev = _scene_tables(self.octrees, padded=True)
            S, M = plan.out_shape
            worst = max(frontier_capacity_bound(
                [len(l.codes) for l in t.levels], M, cfg)
                for t in self.octrees)
            memo_key = ("pad_scenes", S, M, self._scene_sig)
            verdict, st, cap, replays = _escalate(
                lambda cap: self._run(cap, "scenes")(
                    plan.obb_c.reshape(S, M, 3), plan.obb_h.reshape(S, M, 3),
                    plan.obb_r.reshape(S, M, 3, 3), dev),
                M, worst, cfg, start=self._cap_memo.get(memo_key))
        else:
            memo_key = ("single", Q, plan.grouped, max_depth,
                        self._scene_sig)
            if tiled:
                run = lambda cap: self._run(
                    cap, streamed=streamed, meta_format=fmt,
                    use_pallas_traverse=upt)(
                        *run_args, self.device_tree, None, owner_t,
                        payload_t, tiles)
            else:
                run = lambda cap: self._run(
                    cap, streamed=streamed, meta_format=fmt,
                    use_pallas_traverse=upt, max_depth=max_depth)(
                        plan.obb_c, plan.obb_h, plan.obb_r,
                        self.device_tree, None, owner, payload)
            verdict, st, cap, replays = _escalate(
                run, Q, self._capacity(Q), cfg,
                start=self._cap_memo.get(memo_key))
        self._cap_memo[memo_key] = cap
        lanes = ((plan.owner_of_query is not None)
                 + (plan.payload is not None))
        counters = _stats_to_counters(st, cfg.mode, replays,
                                      extra_lanes=lanes, meta_format=fmt)
        if cfg.persistent and fallback_reason is not None:
            counters.ref_arm_fallbacks = 1
        verdict = np.asarray(jax.device_get(verdict))
        if plan.grouped:
            # Grouped verdicts are computed in a Q-sized buffer (owner ids
            # are compact); only the first G cells are meaningful.
            verdict = verdict[:plan.groups]
        return verdict, counters

    # ------------------------------------------------------------------
    def _exec_sharded(self, plan: QueryPlan,
                      max_depth: Optional[int] = None):
        """Sharded execute path (``cfg.shards``, DESIGN.md §6).

        The flat pool pads up to a multiple of the shard count (pad slots
        ride in the LAST shard's tail), splits into equal contiguous
        blocks over the collision mesh, and every device traverses its
        block at the same frontier capacity the single-device run would
        use — its true live count travels as a per-shard ``num_valid``
        lane, so pads add zero work.  Verdicts and counters come back
        bitwise-identical to single-device; escalation replays are
        coordinated by the global max over per-shard overflow flags.

        **Device-loss recovery (DESIGN.md §7):** a launch attempt that
        fails with a device-loss-classified error (see
        :func:`device_loss_count`) does not fail the plan — the pool
        re-pads and re-shards over the surviving device set and the
        launch replays there.  Because verdicts and counters are
        bitwise-identical across ANY shard count (the invariant above,
        CI-enforced), the recovered run answers exactly like the healthy
        mesh; only ``Counters.reshards`` / ``shards_lost`` (and the pad
        count) betray that anything happened.  The reduced width is
        sticky on the engine (``active_shards``) until ``set_shards``
        re-probes the full device set; a loss with no survivors
        propagates, which the batcher translates into the typed
        ``DeviceLost`` service error.

        v1 serves single-scene boolean plans; ragged multi-scene pools
        and owner/payload lanes stay single-device (their frontiers are
        not partitioned by query slot).  The streamed metadata layout is
        per-device-tile, so sharded runs pin the resident fp32 layout to
        keep ``meta_rows`` partition-invariant.
        """
        cfg = self.cfg
        Q = plan.num_queries
        if plan.num_scenes != 1:
            raise ValueError(
                "sharded execution serves single-scene plans; multi-scene "
                "pools are single-device for now (DESIGN.md §6)")
        if plan.grouped:
            raise ValueError(
                "sharded execution serves boolean plans; owner/payload "
                "verdict groups span shards and stay single-device")
        shards = self.active_shards
        reshards = 0
        lost_total = 0
        while True:
            try:
                if self.device_fault_injector is not None:
                    self.device_fault_injector(shards)
                q_shard = -(-Q // shards)
                pad = q_shard * shards - Q
                obb_c = jnp.pad(jnp.asarray(plan.obb_c), ((0, pad), (0, 0)))
                obb_h = jnp.pad(jnp.asarray(plan.obb_h), ((0, pad), (0, 0)))
                obb_r = jnp.pad(jnp.asarray(plan.obb_r),
                                ((0, pad), (0, 0), (0, 0)))
                counts = jnp.clip(
                    Q - jnp.arange(shards, dtype=jnp.int32) * q_shard,
                    0, q_shard)
                memo_key = ("sharded", shards, Q, max_depth,
                            self._scene_sig)
                verdict, st, cap, replays = _escalate(
                    lambda cap: _sharded_traversal_fn(
                        cfg.mode, cap, cfg.use_spheres,
                        cfg.use_pallas_compact, cfg.use_pallas_traverse,
                        False, shards, max_depth)(
                            # Sharded runs pin the resident fp32 table
                            # (see the docstring): per-device window
                            # traffic would break the partition-
                            # invariance of ``meta_rows``.
                            counts, obb_c, obb_h, obb_r,
                            self._device_tree("fp32")),
                    Q, self._capacity(Q), cfg,
                    start=self._cap_memo.get(memo_key))
                break
            except Exception as e:
                lost = device_loss_count(e)
                if lost is None:
                    raise
                lost = min(lost, shards)
                surviving = shards - lost
                lost_total += lost
                self._healthy_shards = max(surviving, 1)
                if surviving < 1:
                    logger.error(
                        "collision mesh lost its last %d device(s); "
                        "no survivors to re-shard onto: %s", lost, e)
                    raise
                reshards += 1
                logger.warning(
                    "device loss mid-launch (%d of %d shard devices); "
                    "re-sharding the %d-query pool over the %d survivors",
                    lost, shards, Q, surviving)
                shards = surviving
        self._cap_memo[memo_key] = cap
        counters = _stats_to_counters(st, cfg.mode, replays)
        counters.pad_queries = pad
        counters.reshards = reshards
        counters.shards_lost = lost_total
        verdict = np.asarray(jax.device_get(verdict))[:Q]
        return verdict, counters

    # ------------------------------------------------------------------
    def _exec_naive(self, plan: QueryPlan):
        """CUDA-baseline arm: dense all-pairs vs all leaf AABBs, all axes."""
        obbs = plan.obbs
        leaves = self.octree.leaf_aabbs()
        c = Counters()
        M = obbs.n
        res = sact_mod.sact_pairwise_blocked(
            obbs, leaves, block=self.cfg.query_block, use_spheres=False)
        collide = np.asarray(jax.device_get(jnp.any(res.collide, axis=-1)))
        n_tests = M * leaves.n
        c.nodes_traversed = n_tests
        c.leaf_tests = n_tests
        c.axis_tests_executed = n_tests * NUM_AXES
        c.axis_tests_decoded = n_tests * NUM_AXES
        c.bytes_moved = n_tests * BYTES_UNFUSED_TEST
        codes = np.asarray(jax.device_get(res.exit_code)).reshape(-1)
        c.merge_exit_codes(codes, np.ones_like(codes, bool))
        return collide, c

    # ------------------------------------------------------------------
    def _exec_host(self, plan: QueryPlan, max_depth: Optional[int] = None):
        """Legacy host-in-the-loop traversal (``wavefront_host`` and the
        predication/no-exit ablation arms): the frontier is re-bucketed on
        the host between levels, which blocks jit across levels.

        ``max_depth`` caps the level loop, treating the cap level as
        terminal — same conservative-superset contract as the device
        arms."""
        obbs = plan.obbs
        cfg = self.cfg
        oct_ = self.octree
        depth_eff = (oct_.depth if max_depth is None
                     else min(oct_.depth, max_depth))
        M = obbs.n
        c = Counters()
        decided = np.zeros(M, bool)           # queries confirmed colliding
        collide = np.zeros(M, bool)

        if len(oct_.levels[0].codes) == 0:
            return collide, c

        # Frontier at level 0: every query x the root cell.
        q_idx = jnp.arange(M, dtype=jnp.int32)
        codes = jnp.zeros((M,), jnp.uint32)
        n_live = M
        bucket = _bucket(M, cfg)
        q_idx = jnp.pad(q_idx, (0, bucket - M))
        codes = jnp.pad(codes, (0, bucket - M))
        valid = jnp.arange(bucket) < n_live

        for level in range(0, depth_eff + 1):
            if n_live == 0:
                break
            cell = oct_.cell_size(level)
            node_c, node_h = node_centers_from_codes(codes, self._scene_lo,
                                                     cell)
            res = _test_pairs(obbs.center[q_idx], obbs.half[q_idx],
                              obbs.rot[q_idx], node_c, node_h, valid,
                              use_spheres=cfg.use_spheres,
                              stage_split=cfg.stage_split)
            # Terminal nodes: leaves, full internal subtrees, or (when a
            # degraded max_depth caps the loop) everything at the cap.
            if level == depth_eff:
                is_term = jnp.ones_like(valid)
            else:
                pos = jnp.searchsorted(self._level_codes[level], codes)
                pos = jnp.clip(pos, 0, self._level_codes[level].shape[0] - 1)
                is_term = self._level_full[level][pos]
            overlap = res.collide & valid
            term_hit = overlap & is_term

            # ---- work accounting -------------------------------------
            valid_np = np.asarray(jax.device_get(valid))
            n_valid = int(valid_np.sum())
            c.nodes_traversed += n_valid
            c.nodes_per_level.append(n_valid)
            n_term = int(jax.device_get(jnp.sum(valid & is_term)))
            c.leaf_tests += n_term
            exec_tests = int(jax.device_get(
                jnp.sum(jnp.where(valid, res.axis_tests, 0))))
            c.axis_tests_executed += exec_tests
            c.axis_tests_decoded += n_valid * NUM_AXES
            c.sphere_tests += int(jax.device_get(
                jnp.sum(jnp.where(valid, res.sphere_tests, 0))))
            per_test_bytes = (BYTES_FUSED_TEST if cfg.fused
                              else BYTES_UNFUSED_TEST)
            c.bytes_moved += n_valid * per_test_bytes
            if cfg.mode == "rta_like":
                n_hits = int(jax.device_get(jnp.sum(overlap)))
                c.shader_invocations += n_hits
                c.bytes_moved += n_hits * BYTES_SHADER_HANDOFF
            codes_np = np.asarray(jax.device_get(res.exit_code))
            c.merge_exit_codes(codes_np, np.asarray(jax.device_get(
                valid & is_term)))

            # ---- collision confirmation ------------------------------
            hit_q = np.asarray(jax.device_get(
                jnp.zeros(M, bool).at[q_idx].max(term_hit)))
            collide |= hit_q
            if cfg.early_exit:
                decided |= hit_q

            if level == depth_eff:
                break

            # ---- expansion -------------------------------------------
            expand = overlap & ~is_term
            if cfg.early_exit:
                expand = expand & ~jnp.asarray(decided)[q_idx]
            child_codes, child_idx = lookup_children(
                self._level_codes[level + 1], codes)
            child_mask = expand[:, None] & (child_idx >= 0)         # (K, 8)
            flat_mask = child_mask.reshape(-1)
            flat_codes = child_codes.reshape(-1)
            flat_q = jnp.repeat(q_idx, 8)
            n_live = int(jax.device_get(jnp.sum(flat_mask)))
            if n_live == 0:
                break
            if n_live > cfg.max_frontier:
                c.frontier_overflow += n_live - cfg.max_frontier
                n_live = cfg.max_frontier
            bucket = _bucket(n_live, cfg)
            valid, q_idx, codes = _compact(flat_mask, bucket, flat_q,
                                           flat_codes)
        return collide, c


def query_batched_scenes(octrees: List[Octree], obbs: OBBs,
                         config: EngineConfig = EngineConfig()
                         ) -> Tuple[np.ndarray, Counters]:
    """Traverse S scenes, each with its own (M,) OBB set, in ONE compiled call.

    ``obbs`` fields carry a leading scene axis: center (S, M, 3).  All trees
    must share a depth; node counts may differ arbitrarily.

    CSR modes (``wavefront_fused`` / ``wavefront_persistent``) run the
    **ragged flat frontier**: one pool of (scene, query, CSR node) triples
    over the :func:`repro.core.octree.concat_device_octrees` flat table —
    mixed-size scenes share the compiled call and the compaction pool, and
    no work scales with the largest scene's padding.  ``mode="wavefront"``
    (whose frontier carries Morton codes, not CSR indices) keeps the legacy
    padded-vmap path over :func:`stack_device_octrees` for A/B benchmarks.
    Returns ((S, M) verdicts, aggregate counters).

    Compatibility front-end over ``CollisionEngine(octrees).execute``; the
    device scene tables are memoized module-wide, so repeat calls on the
    same octree list skip the table build.
    """
    assert config.device_resident, "multi-scene batching needs a device mode"
    assert obbs.center.ndim == 3 and obbs.center.shape[0] == len(octrees)
    return CollisionEngine(list(octrees), config).execute(plan_scenes(obbs))
