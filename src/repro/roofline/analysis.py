"""Roofline terms from a compiled dry-run artifact.

  compute    = HLO_FLOPs   / (chips * 197e12)
  memory     = HLO_bytes   / (chips * 819e9)
  collective = Σ collective operand bytes / (chips * 50e9)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and sum operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops (dtype width x element count of each shape).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_\[\]{}, ]+?)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{$")
_CALL_REF_RE = re.compile(
    r"(to_apply|calls|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """HLO module text -> {computation name: [instruction lines]}."""
    comps: Dict[str, List[str]] = {}
    cur = None
    entry_alias = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _COMP_HEAD_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if raw.startswith("ENTRY"):
                entry_alias = cur
            continue
        if line == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry_alias is not None:
        comps["__entry__"] = comps[entry_alias]
    return comps


def _line_collective_bytes(line: str) -> Tuple[Optional[str], int]:
    m = _COLL_RE.search(line)
    if not m or "=" not in line:
        return None, 0
    if "-done(" in line:
        return None, 0
    rhs = line.split("=", 1)[1]
    op_idx = rhs.find(m.group(1))
    prefix = rhs[:op_idx] if op_idx > 0 else rhs
    nbytes = _shape_bytes(prefix)
    if nbytes == 0:
        sm = _SHAPE_RE.search(rhs)
        nbytes = _shape_bytes(sm.group(0)) if sm else 0
    return m.group(1).lower(), nbytes


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-shard collective bytes from optimized HLO, loop-aware.

    Sums the result-shape bytes of every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute instruction; a
    collective inside a `while` body is multiplied by the loop trip count
    (largest integer constant in the loop condition — scan-lowered loops
    compare an induction variable against the length).  -start/-done async
    pairs count once.
    """
    comps = _split_computations(hlo_text)

    def trip_count(cond_name: str, seen=None) -> int:
        """Largest integer constant reachable from the loop condition."""
        seen = seen or set()
        if cond_name in seen:
            return 1
        seen.add(cond_name)
        best = 1
        for line in comps.get(cond_name, []):
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
            for _, ref in _CALL_REF_RE.findall(line):
                best = max(best, trip_count(ref, seen))
        return best

    memo: Dict[str, Dict[str, float]] = {}

    def walk(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = {}
        out: Dict[str, float] = {}
        for line in comps.get(name, []):
            kind, nbytes = _line_collective_bytes(line)
            if kind:
                out[kind] = out.get(kind, 0) + nbytes
            refs = dict()
            for key, ref in _CALL_REF_RE.findall(line):
                refs[key] = ref
            if "body" in refs:                      # while loop
                k = trip_count(refs.get("condition", ""))
                for kk, vv in walk(refs["body"]).items():
                    out[kk] = out.get(kk, 0) + vv * k
            else:
                for key, ref in refs.items():
                    if key in ("to_apply", "calls"):
                        for kk, vv in walk(ref).items():
                            out[kk] = out.get(kk, 0) + vv
            bm = _BRANCH_RE.search(line)
            if bm:
                branch_costs = [walk(b.strip().lstrip("%"))
                                for b in bm.group(1).split(",")]
                if branch_costs:
                    biggest = max(branch_costs,
                                  key=lambda d: sum(d.values()))
                    for kk, vv in biggest.items():
                        out[kk] = out.get(kk, 0) + vv
        memo[name] = out
        return out

    return {k: int(v) for k, v in walk("__entry__").items()}


@dataclasses.dataclass
class RooflineTerms:
    """All quantities are PER-CHIP: XLA's cost_analysis on an SPMD module
    reports the per-device program (verified against a hand-counted local
    dot), and the collective parser sums per-shard operand bytes.  The
    assignment's `HLO_FLOPs / (chips * peak)` with global HLO_FLOPs is the
    same number: global = per_chip * chips."""

    flops: float                  # per-chip HLO FLOPs
    hbm_bytes: float              # per-chip HBM bytes (fusion-aware model)
    collective_bytes: float       # per-chip collective bytes moved
    chips: int
    peak_mem_per_chip: float = 0.0
    hbm_bytes_unfused: float = 0.0  # per-chip unfused upper bound

    @property
    def compute_s(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / hw.ICI_BW

    @property
    def total_flops(self) -> float:
        return self.flops * self.chips

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops, "hbm_bytes_per_chip": self.hbm_bytes,
            "hbm_bytes_unfused_per_chip": self.hbm_bytes_unfused,
            "collective_bytes_per_chip": self.collective_bytes,
            "chips": self.chips, "total_flops": self.total_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "peak_mem_per_chip": self.peak_mem_per_chip,
        }


def analyze_compiled(compiled, chips: int,
                     jaxpr_cost=None) -> RooflineTerms:
    """Extract roofline terms from a jax compiled artifact.

    ``jaxpr_cost``: optional roofline.jaxpr_cost.Cost with loop-aware global
    FLOPs/bytes (XLA's cost_analysis counts while bodies once; see
    jaxpr_cost.py).  When provided, per-chip = cost / chips; otherwise fall
    back to cost_analysis (valid for loop-free programs).
    """
    unfused = 0.0
    if jaxpr_cost is not None:
        flops = jaxpr_cost.flops / chips
        hbm = jaxpr_cost.bytes_major / chips
        unfused = jaxpr_cost.bytes / chips
    else:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        flops = float(ca.get("flops", 0.0))
        hbm = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = parse_collective_bytes(text)
    mem = compiled.memory_analysis()
    peak = 0.0
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes"):
        peak += float(getattr(mem, attr, 0.0) or 0.0)
    return RooflineTerms(flops=flops, hbm_bytes=hbm,
                         collective_bytes=float(sum(coll.values())),
                         chips=chips, peak_mem_per_chip=peak,
                         hbm_bytes_unfused=unfused)


def model_flops(cfg, shape, backward: bool) -> float:
    """6·N_active·D (train) or 2·N_active·D (inference) headline FLOPs."""
    n = cfg.num_active_params
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if backward else 2.0
    return mult * n * tokens
