"""Loop-aware analytical cost model from the jaxpr.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
in-repo: a 10-iteration scan of a 4.2 MFLOP matmul reports 4.2 MFLOPs), so
for scan-over-layers models it undercounts by ~num_layers.  This walker
traverses the closed jaxpr instead, multiplying through ``scan`` trip
counts and recursing into pjit / remat / custom-vjp calls.

FLOPs: dot_general = 2·batch·M·N·K; conv ≈ 2·out·kernel; elementwise ops
1 FLOP/output element (exp/log/tanh etc. weighted higher is noise at model
scale).  Bytes: Σ (operand + output) bytes per equation — an *unfused*
upper bound on HBM traffic; true fused traffic is lower.  Both totals are
whole-computation; divide by chip count for per-chip roofline terms
(assumes even SPMD split; padding waste from non-divisible dims is noted
per-arch in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

_ELEMENTWISE_FLOPS = {
    "add": 1, "sub": 1, "mul": 1, "div": 1, "max": 1, "min": 1, "exp": 4,
    "log": 4, "tanh": 6, "logistic": 6, "rsqrt": 2, "sqrt": 2, "erf": 6,
    "neg": 1, "abs": 1, "floor": 1, "sign": 1, "cos": 4, "sin": 4,
    "integer_pow": 2, "pow": 6, "select_n": 1, "clamp": 2,
}


@dataclasses.dataclass
class Cost:
    """flops: loop-aware FLOPs.  bytes: unfused upper bound (every equation's
    operands+outputs).  bytes_major: fusion-aware estimate — only ops that
    must materialize HBM traffic on TPU are counted (matmul operand/output
    streaming, gathers/scatters, sorts, and loop-carried state); elementwise
    chains are assumed fused into their producers."""

    flops: float = 0.0
    bytes: float = 0.0
    bytes_major: float = 0.0

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        self.bytes_major += other.bytes_major
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.bytes_major * k)


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64)
                     * np.dtype(aval.dtype).itemsize)
    except Exception:
        return 0.0


def _nelems(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64))
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = np.prod([lhs.shape[i] for i in lb], dtype=np.float64) if lb \
        else 1.0
    contract = np.prod([lhs.shape[i] for i in lc], dtype=np.float64) if lc \
        else 1.0
    m = np.prod([lhs.shape[i] for i in range(len(lhs.shape))
                 if i not in lc and i not in lb], dtype=np.float64)
    n = np.prod([rhs.shape[i] for i in range(len(rhs.shape))
                 if i not in rc and i not in rb], dtype=np.float64)
    return float(2.0 * batch * m * n * contract)


def _eqn_bytes(eqn) -> float:
    b = sum(_nbytes(v.aval) for v in eqn.invars
            if hasattr(v, "aval"))
    b += sum(_nbytes(v.aval) for v in eqn.outvars)
    return b


def jaxpr_cost(jaxpr) -> Cost:
    """Total cost of a (Closed)Jaxpr, loops multiplied through."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    total = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += Cost(_dot_flops(eqn), _eqn_bytes(eqn), _eqn_bytes(eqn))
        elif prim == "scan":
            body = eqn.params["jaxpr"]
            length = eqn.params["length"]
            total += jaxpr_cost(body).scaled(length)
            # loop-carried state is re-materialized each iteration
            n_carry = eqn.params.get("num_carry", 0)
            carry_bytes = sum(_nbytes(v.aval)
                              for v in eqn.outvars[:n_carry])
            total += Cost(0.0, 0.0, 2.0 * carry_bytes * length)
        elif prim == "while":
            body = eqn.params["body_jaxpr"]
            # trip count unknown statically here; most of our whiles come
            # from scan (handled above).  Count once + flag via bytes.
            total += jaxpr_cost(body)
        elif prim in ("pjit", "closed_call", "core_call", "custom_jvp_call",
                      "custom_vjp_call", "custom_vjp_call_jaxpr",
                      "checkpoint", "remat2", "remat", "custom_lin"):
            inner = (eqn.params.get("jaxpr")
                     or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                total += jaxpr_cost(inner)
        elif prim == "cond":
            branches = eqn.params.get("branches", ())
            if branches:
                costs = [jaxpr_cost(b) for b in branches]
                total += max(costs, key=lambda c: c.flops)
        elif prim in _ELEMENTWISE_FLOPS:
            out_e = sum(_nelems(v.aval) for v in eqn.outvars)
            total += Cost(_ELEMENTWISE_FLOPS[prim] * out_e,
                          _eqn_bytes(eqn))
        elif prim in ("reduce_sum", "reduce_max", "reduce_min",
                      "reduce_prod", "argmax", "argmin", "cumsum",
                      "cumlogsumexp", "logsumexp"):
            in_e = sum(_nelems(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
            total += Cost(in_e, _eqn_bytes(eqn))
        elif prim in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice", "take",
                      "sort", "top_k", "argsort"):
            # data-movement ops: HBM traffic even when "fused"
            total += Cost(0.0, _eqn_bytes(eqn), _eqn_bytes(eqn))
        elif prim in ("concatenate", "transpose", "reshape", "rev",
                      "broadcast_in_dim", "convert_element_type", "slice",
                      "pad", "iota"):
            total += Cost(0.0, _eqn_bytes(eqn))
        else:
            # default: count bytes in the unfused bound only
            total += Cost(0.0, _eqn_bytes(eqn))
    return total


def trace_cost(fn, *abstract_args, **kw) -> Cost:
    """Cost of fn applied to ShapeDtypeStructs."""
    jaxpr = jax.make_jaxpr(fn, **kw)(*abstract_args)
    return jaxpr_cost(jaxpr)
