"""TPU v5e hardware constants used by the roofline model."""

PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # B/s per chip
ICI_BW = 50e9                     # B/s per link (~per-chip injection)
HBM_BYTES = 16 * (1 << 30)        # 16 GiB per chip
