"""Shared model building blocks: norms, init, RoPE, dtype policy.

Parameters are plain nested dicts of jax arrays (pytrees) so the sharding
engine (parallel/sharding.py) can attach PartitionSpecs by path pattern.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    std = (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), -1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def init_norm(key, cfg: ModelConfig, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def apply_norm(params, x, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"])


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """Rotary embedding; x (..., S, hd), positions (..., S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    y = jnp.stack([y1, y2], -1).reshape(x.shape)
    return y.astype(x.dtype)


def activation(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                 # nemotron squared-ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          z_loss: float = 0.0) -> jax.Array:
    """Stable token-mean xent; logits (..., V) f32-upcast, labels (...).

    The label pick is an iota-compare masked reduction rather than
    take_along_axis: a gather over the vocab dim would make GSPMD
    all-gather the (B, S, V) logits when V is model-sharded; the masked
    reduce partitions cleanly (partial sum + all-reduce).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
              == labels[..., None])
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)


def shard_hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op without a mesh.

    ``spec`` entries are axis names / tuples / None; axes absent from the
    ambient mesh are dropped so the same model code runs in single-device
    tests and under the production meshes.
    """
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is not None:
        mesh = get_mesh()
    else:  # jax < 0.5: ambient mesh of the `with Mesh(...)` context
        try:
            from jax._src import mesh as _mesh_lib
            mesh = _mesh_lib.thread_resources.env.physical_mesh
        except Exception:
            mesh = None
    names = set(getattr(mesh, "axis_names", ()) or ())
    if not names:
        return x
    sizes = dict(getattr(mesh, "shape", {}))

    def keep(a, dim):
        if a is None:
            return None
        axes = (a,) if isinstance(a, str) else tuple(a)
        kept = tuple(x_ for x_ in axes if x_ in names)
        if not kept:
            return None
        total = 1
        for x_ in kept:
            total *= sizes.get(x_, 1)
        if dim % total != 0:
            return None
        return kept if len(kept) > 1 else kept[0]

    from jax.sharding import PartitionSpec as P
    clean = [keep(a, x.shape[i]) for i, a in enumerate(spec)]
    return jax.lax.with_sharding_constraint(x, P(*clean))


BATCH_AXES = ("pod", "data")


def shard_hint_spec(x: jax.Array, spec) -> jax.Array:
    """with_sharding_constraint from an explicit PartitionSpec (degrades to a
    no-op without an ambient mesh; drops axes that don't divide; the string
    "skip" sentinel means no hint at all)."""
    if spec is None or (isinstance(spec, str) and spec == "skip"):
        return x
    return shard_hint(x, *tuple(spec) + (None,) * (x.ndim - len(spec)))
