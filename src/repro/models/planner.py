"""MpiNet-lite: neural motion planner = PointNet++ encoder + MLP policy.

Predicts the next joint-space delta given (point-cloud feature, current
config, goal config); rolled out autoregressively and *always* validated by
the explicit collision gate (core/pipeline.py) — the paper's safety argument
(§II-B): neural planners must be paired with explicit collision detection.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.geometry import NUM_LINKS
from repro.models.common import dense_init
from repro.models.pointnet import init_pointnet, pointnet_encode


def init_planner(key, feat_dim: int = 256, hidden: int = 512,
                 widen: int = 1, dtype=jnp.float32) -> Dict:
    """widen > 1 scales the MLP for the ~100M-param driver run."""
    ks = jax.random.split(key, 5)
    h = hidden * widen
    d_in = feat_dim + 2 * NUM_LINKS
    return {
        "pointnet": init_pointnet(ks[0], feat_dim, dtype),
        "fc1": {"w": dense_init(ks[1], (d_in, h), 0, dtype),
                "b": jnp.zeros((h,), dtype)},
        "fc2": {"w": dense_init(ks[2], (h, h), 0, dtype),
                "b": jnp.zeros((h,), dtype)},
        "fc3": {"w": dense_init(ks[3], (h, h), 0, dtype),
                "b": jnp.zeros((h,), dtype)},
        "out": {"w": dense_init(ks[4], (h, NUM_LINKS), 0, dtype) * 0.1,
                "b": jnp.zeros((NUM_LINKS,), dtype)},
    }


def planner_apply(params: Dict, cloud_feat: jax.Array, q: jax.Array,
                  goal: jax.Array) -> jax.Array:
    """(B,F), (B,7), (B,7) -> predicted delta-q (B,7)."""
    x = jnp.concatenate([cloud_feat, q, goal], -1)
    for name in ("fc1", "fc2", "fc3"):
        x = jax.nn.relu(jnp.einsum("bi,io->bo", x, params[name]["w"])
                        + params[name]["b"])
    return jnp.tanh(jnp.einsum("bi,io->bo", x, params["out"]["w"])
                    + params["out"]["b"]) * 0.4


def encode_cloud(params: Dict, cloud: jax.Array, sampling: str = "fps",
                 key: Optional[jax.Array] = None) -> jax.Array:
    return pointnet_encode(params["pointnet"], cloud, sampling, key)


def planner_loss(params: Dict, batch: Dict, sampling: str = "fps",
                 key: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    """Behaviour cloning: match expert delta on (cloud, q, goal) tuples."""
    feat = encode_cloud(params, batch["cloud"], sampling, key)
    pred = planner_apply(params, feat, batch["q"], batch["goal"])
    mse = jnp.mean(jnp.square(pred - batch["expert_delta"]))
    return mse, {"mse": mse}


def rollout(params: Dict, cloud: jax.Array, q0: jax.Array, goal: jax.Array,
            num_steps: int, sampling: str = "fps",
            key: Optional[jax.Array] = None) -> jax.Array:
    """Autoregressive plan: returns waypoints (B, num_steps+1, 7).

    The cloud feature is encoded once per plan (static scene assumption,
    same as MpiNet).
    """
    feat = encode_cloud(params, cloud, sampling, key)

    def step(q, _):
        dq = planner_apply(params, feat, q, goal)
        # snap toward goal when close (MpiNet-style termination smoothing)
        dist = jnp.linalg.norm(goal - q, axis=-1, keepdims=True)
        dq = jnp.where(dist < 0.4, goal - q, dq)
        return q + dq, q + dq

    _, traj = jax.lax.scan(step, q0, None, length=num_steps)
    traj = jnp.moveaxis(traj, 0, 1)                    # (B, T, 7)
    return jnp.concatenate([q0[:, None], traj], 1)
