"""Selective state-space (Mamba-style) branch for the hymba hybrid layers.

Minimal selective SSM: per-channel input-dependent dt/B/C, diagonal A.
  h_t = exp(dt_t * A) ⊙ h_{t-1} + dt_t * (x_t ⊗ B_t)
  y_t = h_t · C_t + D ⊙ x_t
Sequence form uses lax.scan (what the dry-run lowers); ``ssm_step`` is the
O(1)-state decode form used by long_500k serving.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


def init_ssm(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    n = cfg.ssm_state
    di = cfg.ssm_expand * d // 2          # inner width (keep params modest)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, di), 0, dtype),
        "w_z": dense_init(ks[1], (d, di), 0, dtype),
        "w_bc": dense_init(ks[2], (di, 2 * n), 0, dtype),
        "w_dt": dense_init(ks[3], (di, 1), 0, dtype),
        "a_log": jnp.log(jnp.linspace(1.0, float(n), n)[None, :]
                         * jnp.ones((di, 1))).astype(jnp.float32),
        "d_skip": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[5], (di, d), 0, dtype) / (2 * cfg.num_layers) ** 0.5,
    }


def _gates(params, x):
    xi = jnp.einsum("...d,de->...e", x, params["w_in"])        # (..., di)
    z = jax.nn.silu(jnp.einsum("...d,de->...e", x, params["w_z"]))
    bc = jnp.einsum("...e,en->...n", xi, params["w_bc"])
    n = bc.shape[-1] // 2
    B, C = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(jnp.einsum("...e,eo->...o", xi, params["w_dt"]))
    return xi, z, B, C, dt


def ssm_scan(params: Dict, x: jax.Array, cfg: ModelConfig,
             state: jax.Array | None = None
             ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), final state (B,di,n))."""
    Bb, S, d = x.shape
    xi, z, Bm, Cm, dt = _gates(params, x)
    di = xi.shape[-1]
    n = Bm.shape[-1]
    A = -jnp.exp(params["a_log"])                              # (di, n)
    h0 = (jnp.zeros((Bb, di, n), jnp.float32) if state is None
          else state.astype(jnp.float32))

    def step(h, ins):
        xt, Bt, Ct, dtt = ins                                  # (Bb, ·)
        decay = jnp.exp(dtt[:, None, None] * A[None])          # (Bb, di, n)
        h = decay * h + (dtt[:, None] * xt)[..., None] * Bt[:, None, :]
        y = jnp.einsum("ben,bn->be", h, Ct)
        return h, y

    ins = tuple(jnp.moveaxis(a, 1, 0) for a in
                (xi.astype(jnp.float32), Bm.astype(jnp.float32),
                 Cm.astype(jnp.float32), dt[..., 0].astype(jnp.float32)))
    h, ys = jax.lax.scan(step, h0, ins)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                 # (Bb,S,di)
    y = (y + params["d_skip"] * xi) * z
    return jnp.einsum("...e,ed->...d", y, params["w_out"]), h


def ssm_step(params: Dict, x: jax.Array, state: jax.Array,
             cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """One decode step: x (B,1,d), state (B,di,n) -> (y (B,1,d), state)."""
    xi, z, Bm, Cm, dt = _gates(params, x[:, 0])
    A = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt[..., None].astype(jnp.float32) * A[None])
    h = decay * state + (dt * xi).astype(jnp.float32)[..., None] \
        * Bm.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("ben,bn->be", h, Cm.astype(jnp.float32)).astype(x.dtype)
    y = (y + params["d_skip"] * xi) * z
    return jnp.einsum("be,ed->bd", y, params["w_out"])[:, None], h
