"""Memory-optimal training attention: chunked online-softmax with a
custom VJP (FlashAttention recomputation), in pure jnp.

Residuals are only (q, k, v, o, lse): O(B·S·H·hd).  The backward pass
recomputes P = exp(S - lse) blockwise, so neither forward nor backward ever
materializes an (S, S) score tensor in HBM — this is what makes the 32k
train/prefill cells fit 16 GiB/chip (see EXPERIMENTS.md §Perf for the
before/after).  GQA layout: q (B,K,g,S,hd), k/v (B,K,S,hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _blk_mask(qi, ki, q_chunk, k_chunk, causal, window):
    qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
    kpos = ki * k_chunk + jnp.arange(k_chunk)[None, :]
    m = jnp.ones((q_chunk, k_chunk), bool)
    if causal:
        m &= qpos >= kpos
    if window:
        m &= (qpos - kpos) < window
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_mha(q, k, v, causal: bool, window: int, q_chunk: int,
              k_chunk: int):
    o, _ = _flash_fwd_impl(q, k, v, causal, window, q_chunk, k_chunk)
    return o


def _flash_fwd_impl(q, k, v, causal, window, q_chunk, k_chunk):
    B, K, g, S, hd = q.shape
    T = k.shape[2]
    nq, nk = S // q_chunk, T // k_chunk
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, K, g, nq, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    kr = k.reshape(B, K, nk, k_chunk, hd).transpose(2, 0, 1, 3, 4)
    vr = v.reshape(B, K, nk, k_chunk, hd).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_qc):
        qi, qc = qi_qc
        m0 = jnp.full((B, K, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, g, q_chunk, hd), jnp.float32)

        def k_step(carry, ki_kc):
            m, l, acc = carry
            ki, kc, vc = ki_kc
            s = jnp.einsum("bkgqh,bkth->bkgqt", qc, kc
                           ).astype(jnp.float32) * scale
            s = jnp.where(_blk_mask(qi, ki, q_chunk, k_chunk, causal,
                                    window)[None, None, None], s, NEG_INF)
            mn = jnp.maximum(m, jnp.max(s, -1))
            p = jnp.exp(s - mn[..., None])
            alpha = jnp.exp(m - mn)
            l = l * alpha + jnp.sum(p, -1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bkth->bkgqh", p.astype(vc.dtype), vc)
            return (mn, l, acc), None

        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                      (jnp.arange(nk), kr, vr))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return None, (o, lse)

    _, (o, lse) = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    o = o.transpose(1, 2, 3, 0, 4, 5).reshape(B, K, g, S, hd)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, K, g, S)
    return o, lse


def _flash_fwd(q, k, v, causal, window, q_chunk, k_chunk):
    o, lse = _flash_fwd_impl(q, k, v, causal, window, q_chunk, k_chunk)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, q_chunk, k_chunk, res, do):
    q, k, v, o, lse = res
    B, K, g, S, hd = q.shape
    T = k.shape[2]
    nq, nk = S // q_chunk, T // k_chunk
    scale = 1.0 / (hd ** 0.5)
    # delta = rowsum(do * o)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)

    qr = q.reshape(B, K, g, nq, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    dor = do.reshape(B, K, g, nq, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    lr = lse.reshape(B, K, g, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    dr = delta.reshape(B, K, g, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    kr = k.reshape(B, K, nk, k_chunk, hd).transpose(2, 0, 1, 3, 4)
    vr = v.reshape(B, K, nk, k_chunk, hd).transpose(2, 0, 1, 3, 4)

    def q_step(carry, xs):
        dk_acc, dv_acc = carry                        # (nk,B,K,ck,hd) f32
        qi, qc, doc, lc, dc = xs

        def k_step(dq_acc, ki_kc):
            ki, kc, vc, dk_a, dv_a = ki_kc
            s = jnp.einsum("bkgqh,bkth->bkgqt", qc, kc
                           ).astype(jnp.float32) * scale
            msk = _blk_mask(qi, ki, q_chunk, k_chunk, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lc[..., None])            # (B,K,g,qc,kc)
            dp = jnp.einsum("bkgqh,bkth->bkgqt", doc.astype(jnp.float32),
                            vc.astype(jnp.float32))
            ds = p * (dp - dc[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bkgqt,bkth->bkgqh", ds,
                                         kc.astype(jnp.float32))
            dk_a = dk_a + jnp.einsum("bkgqt,bkgqh->bkth", ds,
                                     qc.astype(jnp.float32))
            dv_a = dv_a + jnp.einsum(
                "bkgqt,bkgqh->bkth", p,
                doc.astype(jnp.float32))
            return dq_acc, (dk_a, dv_a)

        dq0 = jnp.zeros((B, K, g, q_chunk, hd), jnp.float32)
        dq, (dk_new, dv_new) = jax.lax.scan(
            k_step, dq0, (jnp.arange(nk), kr, vr, dk_acc, dv_acc))
        return (dk_new, dv_new), dq

    dk0 = jnp.zeros((nk, B, K, k_chunk, hd), jnp.float32)
    dv0 = jnp.zeros((nk, B, K, k_chunk, hd), jnp.float32)
    (dk, dv), dq = jax.lax.scan(q_step, (dk0, dv0),
                                (jnp.arange(nq), qr, dor, lr, dr))
    dq = dq.transpose(1, 2, 3, 0, 4, 5).reshape(B, K, g, S, hd
                                                ).astype(q.dtype)
    dk = dk.transpose(1, 2, 0, 3, 4).reshape(B, K, T, hd).astype(k.dtype)
    dv = dv.transpose(1, 2, 0, 3, 4).reshape(B, K, T, hd).astype(v.dtype)
    return dq, dk, dv


flash_mha.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_train(q, k, v, causal: bool = True, window: int = 0,
                          q_chunk: int = 512, k_chunk: int = 1024
                          ) -> jax.Array:
    """(B,S,H,hd) x (B,T,K,hd) GQA API matching attention.dense_attention."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, T)
    assert S % q_chunk == 0 and T % k_chunk == 0
    qr = q.reshape(B, S, K, g, hd).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)
    vr = v.transpose(0, 2, 1, 3)
    o = flash_mha(qr, kr, vr, causal, window, q_chunk, k_chunk)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)
