"""Feed-forward layers: dense MLP variants + capacity-factor MoE.

MoE dispatch is the standard scatter-to-buffers formulation: tokens route to
their top-k experts, each expert owns a (capacity, d) buffer, overflow drops
(capacity factor configurable).  Under the production mesh the expert axis is
sharded over `model` (EP) so the dispatch reshard lowers to an all-to-all —
see parallel/sharding.py.  arctic-480b adds a parallel dense residual branch.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / (2 * cfg.num_layers) ** 0.5
    if cfg.mlp_act == "swiglu":
        return {"w_gate": dense_init(ks[0], (d, f), 0, dtype),
                "w_up": dense_init(ks[1], (d, f), 0, dtype),
                "w_down": dense_init(ks[2], (f, d), 0, dtype) * out_scale}
    return {"w_in": dense_init(ks[0], (d, f), 0, dtype),
            "w_out": dense_init(ks[1], (f, d), 0, dtype) * out_scale}


def apply_mlp(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(jnp.einsum("...d,df->...f", x, params["w_gate"]))
        h = h * jnp.einsum("...d,df->...f", x, params["w_up"])
        return jnp.einsum("...f,fd->...d", h, params["w_down"])
    act = activation(cfg.mlp_act)
    h = act(jnp.einsum("...d,df->...f", x, params["w_in"]))
    return jnp.einsum("...f,fd->...d", h, params["w_out"])


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    out_scale = 1.0 / (2 * cfg.num_layers) ** 0.5
    p = {"router": dense_init(ks[0], (d, E), 0, jnp.float32)}
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = dense_init(ks[1], (E, d, f), 1, dtype)
        p["w_up"] = dense_init(ks[2], (E, d, f), 1, dtype)
        p["w_down"] = dense_init(ks[3], (E, f, d), 1, dtype) * out_scale
    else:
        p["w_in"] = dense_init(ks[1], (E, d, f), 1, dtype)
        p["w_out"] = dense_init(ks[2], (E, f, d), 1, dtype) * out_scale
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[4], cfg, dtype)
    return p


def moe_capacity(cfg: ModelConfig, num_tokens: int) -> int:
    cap = int(math.ceil(cfg.moe_capacity_factor * num_tokens
                        * cfg.experts_per_token / cfg.num_experts))
    return max(8, min(cap, num_tokens))


def apply_moe(params: Dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), aux_loss scalar).

    Per-group dispatch (group = batch element, Switch-Transformer style):
    the expert-position cumsum runs *within* each group so it never crosses
    data shards; buffers are (B, E, C, d) with B over `data` and E over
    `model`, and the token->buffer reshard lowers to an all-to-all.

    Decode (S == 1): per-element groups waste E·C buffer rows per token
    (useful-FLOPs ratio ~0 for arctic top-2/128).  With
    cfg.moe_batch_group_decode the whole batch becomes ONE group so the
    capacity is shared across tokens — the (T, E) cumsum at decode scale is
    trivial.
    """
    if x.shape[1] == 1 and x.shape[0] > 1 and cfg.moe_batch_group_decode:
        B = x.shape[0]
        y, aux = apply_moe(params, x.reshape(1, B, -1),
                           cfg.replace(moe_batch_group_decode=False))
        return y.reshape(B, 1, -1), aux
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = moe_capacity(cfg, S)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])                     # (B, S, E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style).
    me = jnp.mean(probs, (0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E), (0, 1))
    aux = E * jnp.sum(me * ce)

    # Buffer position of each (token, slot): one-hot cumsum per group.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)     # (B, S, k, E)
    flatoh = onehot.reshape(B, S * k, E)
    pos_in_e = jnp.cumsum(flatoh, 1) - flatoh                 # (B, S*k, E)
    pos = jnp.sum(pos_in_e * flatoh, -1).reshape(B, S, k)
    keep = pos < C
    dest = jnp.where(keep, gate_idx * C + pos, E * C)         # (B, S, k)

    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    src = jnp.broadcast_to(x[:, :, None, :], (B, S, k, d)
                           ).reshape(B, S * k, d)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * k))
    buf = buf.at[bidx, dest.reshape(B, S * k)].set(src, mode="drop")
    buf = buf[:, :E * C].reshape(B, E, C, d)

    # Expert computation (E sharded over `model` under the mesh).
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
        h = h * jnp.einsum("becd,edf->becf", buf, params["w_up"])
        out = jnp.einsum("becf,efd->becd", h, params["w_down"])
    else:
        act = activation(cfg.mlp_act)
        h = act(jnp.einsum("becd,edf->becf", buf, params["w_in"]))
        out = jnp.einsum("becf,efd->becd", h, params["w_out"])

    flat_out = jnp.concatenate(
        [out.reshape(B, E * C, d), jnp.zeros((B, 1, d), out.dtype)], 1)
    gathered = jnp.take_along_axis(
        flat_out, dest.reshape(B, S * k)[..., None], axis=1
    ).reshape(B, S, k, d)
    y = jnp.sum(gathered * gate_vals[..., None].astype(x.dtype), 2)
    if cfg.dense_residual:
        y = y + apply_mlp(params["dense"], x, cfg)
    return y, aux


def init_ffn(key, cfg: ModelConfig, dtype) -> Dict:
    if cfg.num_experts:
        return init_moe(key, cfg, dtype)
    return init_mlp(key, cfg, dtype)


def apply_ffn(params: Dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    if cfg.num_experts:
        return apply_moe(params, x, cfg)
    return apply_mlp(params, x, cfg), jnp.zeros((), jnp.float32)
