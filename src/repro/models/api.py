"""Family-dispatching model API used by the trainer, server and dry-run.

Everything is functional: ``init_params`` builds the pytree, ``make_*_fn``
return pure functions suitable for jit/pjit.  ``abstract_params`` /
``abstract_caches`` use jax.eval_shape so the dry-run never allocates.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec as encdec_mod
from repro.models import transformer as tfm
from repro.models.common import dtype_of, softmax_cross_entropy

AUX_LOSS_WEIGHT = 0.01


def init_params(cfg: ModelConfig, key) -> Dict:
    if cfg.family == "encdec":
        return encdec_mod.init_encdec(key, cfg)
    return tfm.init_lm(key, cfg)


def abstract_params(cfg: ModelConfig) -> Dict:
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# Training / prefill batches
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStructs for one global batch of this (arch, shape)."""
    B, S = shape.global_batch, shape.seq_len
    cdt = dtype_of(cfg.compute_dtype)
    spec = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "train":
        spec["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "vlm":
        spec["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), cdt)
    if cfg.family == "encdec":
        spec["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cdt)
    return spec


def make_loss_fn(cfg: ModelConfig, use_specs: Optional[Dict] = None
                 ) -> Callable:
    def loss_fn(params: Dict, batch: Dict):
        if cfg.family == "encdec":
            logits, _ = encdec_mod.encdec_forward(
                params, batch["frames"], batch["tokens"], cfg,
                use_specs=use_specs)
            loss = softmax_cross_entropy(logits, batch["labels"])
            return loss, {"xent": loss}
        prefix = batch.get("patch_embeds")
        logits, aux, _ = tfm.lm_forward(params, batch["tokens"], cfg,
                                        prefix_embeds=prefix,
                                        use_specs=use_specs)
        if prefix is not None:
            logits = logits[:, prefix.shape[1]:]
        loss = softmax_cross_entropy(logits, batch["labels"])
        total = loss + AUX_LOSS_WEIGHT * aux
        return total, {"xent": loss, "moe_aux": aux}
    return loss_fn


def make_prefill_fn(cfg: ModelConfig, max_len: Optional[int] = None,
                    use_specs: Optional[Dict] = None) -> Callable:
    """``max_len``: KV-cache capacity to reserve for subsequent decode steps
    (defaults to prompt length + 128)."""
    def prefill_fn(params: Dict, batch: Dict):
        if cfg.family == "encdec":
            logits, caches = encdec_mod.encdec_forward(
                params, batch["frames"], batch["tokens"], cfg,
                collect_cache=True, use_specs=use_specs)
            return logits[:, -1], _pad_caches(caches, cfg, max_len)
        prefix = batch.get("patch_embeds")
        logits, _, caches = tfm.lm_forward(params, batch["tokens"], cfg,
                                           prefix_embeds=prefix,
                                           collect_cache=True,
                                           use_specs=use_specs)
        return logits[:, -1], _pad_caches(caches, cfg, max_len)
    return prefill_fn


def _pad_caches(caches, cfg: ModelConfig, max_len: Optional[int]):
    """Grow self-attention KV rings so decode appends have room.

    Prefill emits capacity-S caches; decode writes slot ``pos % capacity``
    (windowed) or ``pos`` (global), so global caches must be end-padded to
    the serving horizon.
    """
    if cfg.block_type == "rwkv":
        return caches

    def grow(kv):
        S = kv["k"].shape[2]               # (L, B, S, K, hd)
        # Windowed caches must be exactly window-sized (ring slot = p % w).
        target = (cfg.sliding_window if cfg.sliding_window
                  else (max_len or (S + 128)))
        pad = max(0, target - S)
        def padder(a):
            return jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": padder(kv["k"]), "v": padder(kv["v"])}

    out = dict(caches)
    out["kv"] = grow(caches["kv"])
    return out


def make_decode_fn(cfg: ModelConfig, use_specs: Optional[Dict] = None
                   ) -> Callable:
    def decode_fn(params: Dict, token: jax.Array, pos: jax.Array, caches):
        if cfg.family == "encdec":
            return encdec_mod.encdec_decode_step(params, token, pos, caches,
                                                 cfg, use_specs=use_specs)
        return tfm.lm_decode_step(params, token, pos, caches, cfg,
                                  use_specs=use_specs)
    return decode_fn


def abstract_caches(cfg: ModelConfig, shape: ShapeSpec):
    """Decode-cache ShapeDtypeStructs for an (arch, decode-shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return jax.eval_shape(functools.partial(
            encdec_mod.init_encdec_caches, cfg, B, S, S))
    return jax.eval_shape(functools.partial(
        tfm.init_decode_caches, cfg, B, S))


def decode_input_spec(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    B = shape.global_batch
    return {"token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
