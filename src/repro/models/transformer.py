"""Decoder-only LM assembly: scan-over-layers, remat, train/prefill/decode.

One homogeneous block stack per architecture; the block body dispatches on
``cfg.block_type``:
  attn    — pre-norm GQA attention + FFN (dense or MoE)
  hybrid  — hymba: parallel attention + SSM branches, mean-fused
  rwkv    — RWKV-6 time mix + channel mix (attention-free)

Layer parameters are stacked (leading L axis) and applied with ``lax.scan``
so the lowered HLO stays O(1) in depth — essential for compiling 96-layer
models for 512 devices on this container, and the right structure on real
TPUs too.  ``cfg.remat`` wraps the scan body in jax.checkpoint.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (BATCH_AXES, apply_norm, dtype_of,
                                 embed_init, init_norm, shard_hint,
                                 shard_hint_spec)


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> Dict:
    if cfg.block_type == "rwkv":
        return rwkv_mod.init_rwkv_block(key, cfg, dtype)
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_norm(ks[0], cfg, dtype),
        "attn": attn.init_attention(ks[1], cfg, dtype),
        "ln2": init_norm(ks[2], cfg, dtype),
        "ffn": ffn_mod.init_ffn(ks[3], cfg, dtype),
    }
    if cfg.block_type == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(jax.random.fold_in(key, 7), cfg, dtype)
    return p


def init_lm(key, cfg: ModelConfig) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    params = {
        "embed": embed_init(ks[1], (cfg.vocab_size, cfg.d_model), dtype),
        "blocks": blocks,
        "ln_f": init_norm(ks[2], cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[3], (cfg.d_model, cfg.vocab_size),
                                       dtype)
    return params


# ---------------------------------------------------------------------------
# Block apply (sequence form: train & prefill)
# ---------------------------------------------------------------------------

def block_seq(p: Dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
              collect_cache: bool, rwkv_kernel: bool = False):
    """One block over a full sequence.  Returns (x, aux, cache_or_None).

    Megatron-style sequence parallelism (cfg.seq_parallel): the residual
    stream and norms live sequence-sharded over `model`; explicit
    gather/scatter hints bracket the attention/FFN regions so the sharding
    never propagates into the flash scans (letting GSPMD derive it there
    multiplied collective traffic ~15x — EXPERIMENTS §Perf P1 v3).
    """
    sp = cfg.seq_parallel

    def to_full(t):      # all-gather the sequence dim for attention/FFN
        return shard_hint(t, BATCH_AXES, None, None) if sp else t

    def to_sp(t):        # reduce-scatter branch output back to SP layout
        return shard_hint(t, BATCH_AXES, "model", None) if sp else t

    if cfg.block_type == "rwkv":
        x, state = rwkv_mod.rwkv_block(p, to_full(x), cfg, None, rwkv_kernel)
        return x, jnp.zeros((), jnp.float32), (state if collect_cache
                                               else None)
    h = to_full(apply_norm(p["ln1"], x, cfg))
    q, k, v = attn.compute_qkv(p["attn"], h, cfg, positions)
    ctx = attn.attention_ctx(q, k, v, cfg, causal=True)
    branch = attn.project_out(p["attn"], ctx)
    cache = None
    if cfg.block_type == "hybrid":
        ssm_out, ssm_state = ssm_mod.ssm_scan(p["ssm"], h, cfg)
        branch = 0.5 * (branch + ssm_out)
        if collect_cache:
            cache = {"kv": _cache_from_prefill(k, v, cfg),
                     "ssm": ssm_state}
    elif collect_cache:
        cache = {"kv": _cache_from_prefill(k, v, cfg)}
    x = x + to_sp(branch)
    h2 = to_full(apply_norm(p["ln2"], x, cfg))
    y, aux = ffn_mod.apply_ffn(p["ffn"], h2, cfg)
    return x + to_sp(y), aux, cache


def _cache_from_prefill(k: jax.Array, v: jax.Array, cfg: ModelConfig) -> Dict:
    """(B,S,K,hd) prefill keys/values -> decode cache layout.

    Sliding-window caches are rolled so that absolute position p sits at ring
    slot p % window, matching cache_update's slot rule for later steps.
    """
    S = k.shape[1]
    w = cfg.sliding_window
    if w and S > w:
        k = jnp.roll(k[:, -w:], S % w, axis=1)
        v = jnp.roll(v[:, -w:], S % w, axis=1)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Block apply (single-step decode)
# ---------------------------------------------------------------------------

def block_decode(p: Dict, x: jax.Array, cfg: ModelConfig, pos: jax.Array,
                 cache: Dict):
    """One block, one token. x (B,1,d). Returns (x, new_cache)."""
    if cfg.block_type == "rwkv":
        x, state = rwkv_mod.rwkv_block(p, x, cfg, cache)
        return x, state
    h = apply_norm(p["ln1"], x, cfg)
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = attn.compute_qkv(p["attn"], h, cfg, positions)
    kv = attn.cache_update(cache["kv"], k, v, pos, cfg)
    ctx = attn.decode_attention(q, kv, pos, cfg)
    branch = attn.project_out(p["attn"], ctx)
    new_cache = {"kv": kv}
    if cfg.block_type == "hybrid":
        ssm_out, ssm_state = ssm_mod.ssm_step(p["ssm"], h, cache["ssm"], cfg)
        branch = 0.5 * (branch + ssm_out)
        new_cache["ssm"] = ssm_state
    x = x + branch
    h2 = apply_norm(p["ln2"], x, cfg)
    y, _ = ffn_mod.apply_ffn(p["ffn"], h2, cfg)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg, prefix_embeds, use_specs=None):
    emb = params["embed"]
    if use_specs is not None:
        emb = shard_hint_spec(emb, use_specs["embed"])
    x = emb[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], 1)
    return x.astype(dtype_of(cfg.compute_dtype))


def _unembed(params, x, cfg, use_specs=None):
    if cfg.tie_embeddings:
        head = params["embed"].T
    else:
        head = params["lm_head"]
        if use_specs is not None:
            head = shard_hint_spec(head, use_specs["lm_head"])
    return jnp.einsum("bsd,dv->bsv", x, head)


def lm_forward(params: Dict, tokens: jax.Array, cfg: ModelConfig,
               prefix_embeds: Optional[jax.Array] = None,
               collect_cache: bool = False, rwkv_kernel: bool = False,
               use_specs: Optional[Dict] = None):
    """Full-sequence forward.  Returns (logits, aux, caches|None).

    ``use_specs``: optional pytree of use-site PartitionSpecs
    (parallel/sharding.use_pspecs) — ZeRO-3 weight-gather hints applied
    per layer inside the scan.
    """
    x = _embed(params, tokens, cfg, prefix_embeds, use_specs)
    S = x.shape[1]
    positions = jnp.arange(S)
    sp = "model" if cfg.seq_parallel else None
    x = shard_hint(x, BATCH_AXES, sp, None)

    def body(carry, layer_params):
        h, aux = carry
        # Pin the scan-carry sharding: without the hint GSPMD can lose the
        # batch sharding across the loop-state tuple and replicate the
        # whole layer subgraph (observed: 45 GB/chip of B-replicated
        # buffers on glm4 train_4k).
        h = shard_hint(h, BATCH_AXES, sp, None)
        if use_specs is not None:
            layer_params = jax.tree.map(shard_hint_spec, layer_params,
                                        use_specs["blocks"],
                                        is_leaf=lambda t: t is None)
        h, a, cache = block_seq(layer_params, h, cfg, positions,
                                collect_cache, rwkv_kernel)
        h = shard_hint(h, BATCH_AXES, sp, None)
        return (h, aux + a), cache

    body_fn = body
    if cfg.remat:
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["blocks"])
    x = apply_norm(params["ln_f"], x, cfg)
    logits = _unembed(params, x, cfg, use_specs)
    logits = shard_hint(logits, BATCH_AXES, None, "model")
    return logits, aux, caches


def lm_decode_step(params: Dict, token: jax.Array, pos: jax.Array,
                   caches, cfg: ModelConfig,
                   use_specs: Optional[Dict] = None):
    """token (B,) int32, pos scalar int32 -> (logits (B,V), new caches)."""
    x = _embed(params, token[:, None], cfg, None, use_specs)

    def body(h, layer):
        layer_params, layer_cache = layer
        if use_specs is not None:
            layer_params = jax.tree.map(shard_hint_spec, layer_params,
                                        use_specs["blocks"],
                                        is_leaf=lambda t: t is None)
        h, new_cache = block_decode(layer_params, h, cfg, pos, layer_cache)
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    x = apply_norm(params["ln_f"], x, cfg)
    logits = _unembed(params, x, cfg, use_specs)
    return logits[:, 0], new_caches


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked (L-leading) decode caches for lax.scan consumption."""
    dtype = dtype_of(cfg.compute_dtype)
    L = cfg.num_layers

    def one():
        if cfg.block_type == "rwkv":
            return rwkv_mod.init_rwkv_state(cfg, batch, dtype)
        c = {"kv": attn.init_cache(cfg, batch, max_len, dtype)}
        if cfg.block_type == "hybrid":
            di = cfg.ssm_expand * cfg.d_model // 2
            c["ssm"] = jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)
        return c

    return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), one())
