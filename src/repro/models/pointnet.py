"""PointNet++ set-abstraction backbone (MpiNet's point-cloud encoder).

Sampling uses FPS or random selection (the paper's Fig. 9 tradeoff) and
grouping uses ball query — the two kernels RoboGPU accelerates (§IV).  The
implementations are the differentiable jnp paths; the octree/kernel variants
in core/ and kernels/ are drop-in for serving.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ballquery import ball_query_ref
from repro.core.fps import farthest_point_sampling, random_sampling
from repro.models.common import dense_init


def init_sa_layer(key, c_in: int, c_out: int, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    h = c_out
    return {
        "w1": dense_init(ks[0], (c_in + 3, h), 0, dtype),
        "b1": jnp.zeros((h,), dtype),
        "w2": dense_init(ks[1], (h, c_out), 0, dtype),
        "b2": jnp.zeros((c_out,), dtype),
    }


def set_abstraction(params: Dict, xyz: jax.Array, feats: Optional[jax.Array],
                    n_centers: int, radius: float, k: int,
                    sampling: str = "fps",
                    key: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """(B,N,3), (B,N,C)|None -> (centers (B,M,3), feats (B,M,C'))."""
    B, N, _ = xyz.shape

    def sample_one(pts, k_):
        if sampling == "fps":
            return farthest_point_sampling(pts, n_centers)
        return random_sampling(k_, N, n_centers)

    keys = (jax.random.split(key, B) if key is not None
            else jnp.zeros((B, 2), jnp.uint32))
    cidx = jax.vmap(sample_one)(xyz, keys)                    # (B, M)
    centers = jnp.take_along_axis(xyz, cidx[..., None], 1)    # (B, M, 3)

    def group_one(pts, ctr):
        idx, cnt = ball_query_ref(pts, ctr, radius, k)        # (M,k),(M,)
        safe = jnp.maximum(idx, 0)
        valid = idx >= 0
        return safe, valid

    nidx, nvalid = jax.vmap(group_one)(xyz, centers)          # (B,M,k)
    ngb_xyz = jax.vmap(lambda p, i: p[i])(xyz, nidx)          # (B,M,k,3)
    rel = ngb_xyz - centers[:, :, None, :]
    if feats is not None:
        ngb_f = jax.vmap(lambda f, i: f[i])(feats, nidx)      # (B,M,k,C)
        g = jnp.concatenate([rel, ngb_f], -1)
    else:
        g = rel
    h = jax.nn.relu(jnp.einsum("bmkc,ch->bmkh", g, params["w1"])
                    + params["b1"])
    h = jax.nn.relu(jnp.einsum("bmkh,ho->bmko", h, params["w2"])
                    + params["b2"])
    h = jnp.where(nvalid[..., None], h, -jnp.inf)
    pooled = jnp.max(h, axis=2)
    pooled = jnp.where(jnp.isfinite(pooled), pooled, 0.0)     # empty balls
    return centers, pooled


def init_pointnet(key, c_out: int = 256, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "sa1": init_sa_layer(ks[0], 0, 64, dtype),
        "sa2": init_sa_layer(ks[1], 64, 128, dtype),
        "sa3": init_sa_layer(ks[2], 128, c_out, dtype),
    }


def pointnet_encode(params: Dict, xyz: jax.Array, sampling: str = "fps",
                    key: Optional[jax.Array] = None,
                    n1: int = 256, n2: int = 64, n3: int = 16,
                    r1: float = 0.1, r2: float = 0.25, r3: float = 0.6
                    ) -> jax.Array:
    """(B, N, 3) point cloud -> (B, C) global feature."""
    ks = jax.random.split(key, 3) if key is not None else [None] * 3
    c1, f1 = set_abstraction(params["sa1"], xyz, None, n1, r1, 16,
                             sampling, ks[0])
    c2, f2 = set_abstraction(params["sa2"], c1, f1, n2, r2, 16,
                             sampling, ks[1])
    c3, f3 = set_abstraction(params["sa3"], c2, f2, n3, r3, 8,
                             sampling, ks[2])
    return jnp.max(f3, axis=1)
