"""RWKV-6 (Finch) blocks: data-dependent-decay time mix + channel mix.

Time-mix uses the WKV6 recurrence (kernels/wkv6 chunked Pallas kernel or the
jnp scan reference — selectable); decode carries O(1) state per layer:
(wkv state (B,H,D,D), token-shift state (B,d) x2).  The decay is
data-dependent: logw_t = -exp(w0 + x_t W_d), per channel, matching Finch's
"data-dependent decay" headline feature (low-rank refinements dropped for
clarity; documented deviation).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rmsnorm


def init_rwkv_block(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    H = cfg.num_heads if cfg.num_heads > 0 else d // 64
    D = d // H
    f = cfg.d_ff
    ks = jax.random.split(key, 10)
    out_scale = 1.0 / (2 * cfg.num_layers) ** 0.5
    return {
        "tm_norm": {"scale": jnp.ones((d,), dtype)},
        "mix_r": jnp.full((d,), 0.5, dtype),
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], (d, d), 0, dtype),
        "wk": dense_init(ks[1], (d, d), 0, dtype),
        "wv": dense_init(ks[2], (d, d), 0, dtype),
        "wd": dense_init(ks[3], (d, d), 0, dtype) * 0.1,
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "u": dense_init(ks[4], (H, D), 0, jnp.float32),
        "wo": dense_init(ks[5], (d, d), 0, dtype) * out_scale,
        "ln_x": {"scale": jnp.ones((d,), dtype)},
        "cm_norm": {"scale": jnp.ones((d,), dtype)},
        "cmix_k": jnp.full((d,), 0.5, dtype),
        "ck": dense_init(ks[6], (d, f), 0, dtype),
        "cv": dense_init(ks[7], (f, d), 0, dtype) * out_scale,
        "cr": dense_init(ks[8], (d, d), 0, dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Shifted sequence: y_t = x_{t-1}; first step uses `prev` (or zeros)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None] if prev.ndim == 2 else prev
    return jnp.concatenate([prev, x[:, :-1]], 1)


def _time_mix_inputs(params, x, shifted, cfg):
    d = x.shape[-1]
    H = cfg.num_heads if cfg.num_heads > 0 else d // 64
    D = d // H
    def mix(m):
        return x * params[m] + shifted * (1.0 - params[m])
    r = jnp.einsum("bsd,de->bse", mix("mix_r"), params["wr"])
    k = jnp.einsum("bsd,de->bse", mix("mix_k"), params["wk"])
    v = jnp.einsum("bsd,de->bse", mix("mix_v"), params["wv"])
    logw = -jnp.exp(params["w0"]
                    + jnp.einsum("bsd,de->bse", mix("mix_w"),
                                 params["wd"]).astype(jnp.float32))
    B, S = x.shape[:2]
    def shp(a):
        return a.reshape(B, S, H, D).transpose(0, 2, 1, 3)
    return shp(r), shp(k), shp(v), shp(logw), H, D


def rwkv_time_mix(params: Dict, x: jax.Array, cfg: ModelConfig,
                  state: Dict | None = None, use_kernel: bool = False
                  ) -> Tuple[jax.Array, Dict]:
    """Sequence form. x (B,S,d) -> (y (B,S,d), state for decode handoff)."""
    from repro.kernels.wkv6.ref import wkv6_ref
    B, S, d = x.shape
    xn = rmsnorm(x, params["tm_norm"]["scale"])
    prev = None if state is None else state["tm_shift"]
    shifted = _token_shift(xn, prev)
    r, k, v, logw, H, D = _time_mix_inputs(params, xn, shifted, cfg)
    def fold(a):
        return a.reshape(B * H, S, D)
    u = params["u"]                                        # (H, D)
    uexp = jnp.repeat(u[None], B, 0).reshape(B * H, D)
    s0 = None if state is None else state["wkv"].reshape(B * H, D, D)
    if use_kernel:
        from repro.kernels.wkv6.ops import wkv6_heads
        o, s = wkv6_heads(r.reshape(B, H, S, D), k.reshape(B, H, S, D),
                          v.reshape(B, H, S, D), logw.reshape(B, H, S, D),
                          u)
        o = o.reshape(B * H, S, D)
        s = s.reshape(B * H, D, D)
    else:
        o, s = wkv6_ref(fold(r), fold(k), fold(v), fold(logw), uexp, s0)
    y = o.reshape(B, H, S, D).transpose(0, 2, 1, 3).reshape(B, S, d)
    y = rmsnorm(y, params["ln_x"]["scale"])
    y = jnp.einsum("bsd,de->bse", y, params["wo"])
    new_state = {"wkv": s.reshape(B, H, D, D), "tm_shift": xn[:, -1]}
    return y, new_state


def rwkv_channel_mix(params: Dict, x: jax.Array, cfg: ModelConfig,
                     state: Dict | None = None
                     ) -> Tuple[jax.Array, jax.Array]:
    xn = rmsnorm(x, params["cm_norm"]["scale"])
    prev = None if state is None else state["cm_shift"]
    shifted = _token_shift(xn, prev)
    mixed = xn * params["cmix_k"] + shifted * (1.0 - params["cmix_k"])
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", mixed,
                                           params["ck"])))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", mixed, params["cr"]))
    return rr * jnp.einsum("bsf,fd->bsd", kk, params["cv"]), xn[:, -1]


def rwkv_block(params: Dict, x: jax.Array, cfg: ModelConfig,
               state: Dict | None = None, use_kernel: bool = False
               ) -> Tuple[jax.Array, Dict]:
    tm, tm_state = rwkv_time_mix(params, x, cfg, state, use_kernel)
    x = x + tm
    cm, cm_shift = rwkv_channel_mix(params, x, cfg, state)
    x = x + cm
    new_state = dict(tm_state, cm_shift=cm_shift)
    return x, new_state


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d = cfg.d_model
    H = cfg.num_heads if cfg.num_heads > 0 else d // 64
    D = d // H
    return {"wkv": jnp.zeros((batch, H, D, D), jnp.float32),
            "tm_shift": jnp.zeros((batch, d), dtype),
            "cm_shift": jnp.zeros((batch, d), dtype)}
