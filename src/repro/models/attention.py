"""GQA attention: train/prefill (chunked online-softmax) + cached decode.

Three implementations share the same math:
  * dense      — materializes (S, S) scores; smoke-test scale only.
  * chunked    — two-level lax.scan flash equivalent in pure jnp; this is
                 what the dry-run lowers (bounded VMEM/HBM working set at
                 32k+ sequence lengths).
  * pallas     — repro.kernels.flash_attention (forward-only; serving).
``attention_decode_partial`` exposes the (numerator, denom, max) triple used
by the seq-sharded KV decode path (parallel/decode_attention.py) to merge
partial softmaxes across the `model` mesh axis.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype) -> Dict:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), 0, dtype),
        "wk": dense_init(ks[1], (d, K, hd), 0, dtype),
        "wv": dense_init(ks[2], (d, K, hd), 0, dtype),
        "wo": dense_init(ks[3], (H, hd, d), 0, dtype) / (2 * cfg.num_layers) ** 0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((K, hd), dtype)
        p["bv"] = jnp.zeros((K, hd), dtype)
    return p


def compute_qkv(params: Dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """x (B,S,d) -> q (B,S,H,hd), k,v (B,S,K,hd), RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.use_rope:
        # rope over seq axis: move head axis first
        q = apply_rope(q.swapaxes(1, 2), positions, cfg.rope_theta
                       ).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions, cfg.rope_theta
                       ).swapaxes(1, 2)
    return q, k, v


def project_out(params: Dict, ctx: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"])


def _mask(qpos, kpos, causal: bool, window: int):
    m = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), bool)
    if causal:
        m &= qpos >= kpos
    if window:
        m &= (qpos - kpos) < window
    return m


def dense_attention(q, k, v, cfg: ModelConfig, causal: bool = True,
                    q_offset: int = 0) -> jax.Array:
    """(B,S,H,hd) x (B,T,K,hd) -> (B,S,H,hd).  Small-S path."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    qr = q.reshape(B, S, K, g, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qr, k) / (hd ** 0.5)
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    msk = _mask(qpos, kpos, causal, cfg.sliding_window)
    s = jnp.where(msk[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return ctx.reshape(B, S, H, hd)


def chunked_attention(q, k, v, cfg: ModelConfig, causal: bool = True,
                      q_chunk: int = 512, k_chunk: int = 1024) -> jax.Array:
    """Flash-style two-level scan; never materializes (S,T) scores."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, T)
    assert S % q_chunk == 0 and T % k_chunk == 0, (S, q_chunk, T, k_chunk)
    nq, nk = S // q_chunk, T // k_chunk
    qr = q.reshape(B, nq, q_chunk, K, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(B, nk, k_chunk, K, hd).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, k_chunk, K, hd).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / (hd ** 0.5)

    def q_step(_, qi_qc):
        qi, qc = qi_qc                       # qc (B,K,g,q_chunk,hd)
        m0 = jnp.full((B, K, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, g, q_chunk), jnp.float32)
        acc0 = jnp.zeros((B, K, g, q_chunk, hd), jnp.float32)

        def k_step(carry, ki_kc):
            m, l, acc = carry
            ki, kc, vc = ki_kc
            s = jnp.einsum("bkgqh,bkth->bkgqt", qc, kc).astype(jnp.float32)
            s = s * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = ki * k_chunk + jnp.arange(k_chunk)[None, :]
            msk = _mask(qpos, kpos, causal, cfg.sliding_window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, -1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, -1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bkth->bkgqh", p.astype(qc.dtype), vc)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, acc0),
            (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # out (nq, B, K, g, q_chunk, hd) -> (B, S, H, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, hd)
    return out


def _pick_chunk(n: int, target: int, floor: int = 64) -> int:
    """Largest power-of-two divisor of n that is <= target (>= floor)."""
    c = target
    while c >= floor:
        if n % c == 0:
            return c
        c //= 2
    return 0


def attention_ctx(q, k, v, cfg: ModelConfig, causal: bool = True
                  ) -> jax.Array:
    """Implementation dispatch.

    flash (custom-VJP, O(S) residuals) whenever chunk sizes divide the
    sequence — the production path for train_4k/prefill_32k; dense for
    smoke-test shapes; chunked (no custom VJP) as the inference fallback.
    """
    S, T = q.shape[1], k.shape[1]
    qc, kc = _pick_chunk(S, 512), _pick_chunk(T, 1024)
    if cfg.attn_impl != "dense" and S * T > 1 << 22 and qc and kc:
        from repro.models.flash_jnp import flash_attention_train
        return flash_attention_train(q, k, v, causal=causal,
                                     window=cfg.sliding_window,
                                     q_chunk=qc, k_chunk=kc)
    if S * T <= 1 << 22:
        return dense_attention(q, k, v, cfg, causal)
    return chunked_attention(q, k, v, cfg, causal,
                             q_chunk=qc or 512, k_chunk=kc or 1024)


# ---------------------------------------------------------------------------
# Decode with KV cache.
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype
               ) -> Dict:
    """Per-layer KV cache; ring buffer when cfg.sliding_window > 0."""
    L = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    K, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, L, K, hd), dtype),
        "v": jnp.zeros((batch, L, K, hd), dtype),
    }


def cache_update(cache: Dict, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, cfg: ModelConfig) -> Dict:
    """Insert one step (B,1,K,hd) at absolute position pos (RoPE already
    applied at absolute positions, so ring order does not matter)."""
    L = cache["k"].shape[1]
    slot = pos % L if cfg.sliding_window else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                     (0, slot, 0, 0))
    return {"k": k, "v": v}


def decode_partial(q, kc, vc, valid) -> Tuple[jax.Array, jax.Array,
                                              jax.Array]:
    """Partial attention for one decode step over a cache shard.

    q (B,1,H,hd), kc/vc (B,L,K,hd), valid (B,L) bool.
    Returns (acc (B,H,hd) f32, denom (B,H) f32, m (B,H) f32) — mergeable
    across shards by LSE combination.
    """
    B, _, H, hd = q.shape
    K = kc.shape[2]
    g = H // K
    qr = q.reshape(B, K, g, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qr, kc).astype(jnp.float32)
    s = s / (hd ** 0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, -1)
    p = jnp.exp(s - m[..., None])
    denom = jnp.sum(p, -1)
    acc = jnp.einsum("bkgt,btkh->bkgh", p.astype(vc.dtype), vc
                     ).astype(jnp.float32)
    return (acc.reshape(B, H, hd), denom.reshape(B, H), m.reshape(B, H))


def decode_attention(q, cache: Dict, pos: jax.Array, cfg: ModelConfig
                     ) -> jax.Array:
    """Unsharded single-step decode attention: (B,1,H,hd)."""
    L = cache["k"].shape[1]
    idx = jnp.arange(L)
    if cfg.sliding_window:
        n_valid = jnp.minimum(pos + 1, L)
        valid = idx[None, :] < n_valid
    else:
        valid = idx[None, :] <= pos
    acc, denom, _ = decode_partial(q, cache["k"], cache["v"], valid)
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    return out[:, None].astype(q.dtype)
