"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: callers provide
precomputed frame embeddings (B, S_enc, d_model).  Encoder = bidirectional
attention blocks; decoder = causal self-attention + cross-attention + MLP.
Decode caches: per-layer self-KV ring + cross-KV computed once at prefill.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models.common import (BATCH_AXES, apply_norm, dtype_of,
                                 embed_init, init_norm, shard_hint,
                                 shard_hint_spec)


def _use(layer_params, use_specs, key):
    if use_specs is None:
        return layer_params
    return jax.tree.map(shard_hint_spec, layer_params, use_specs[key])


def init_enc_block(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "ln1": init_norm(ks[0], cfg, dtype),
        "attn": attn.init_attention(ks[1], cfg, dtype),
        "ln2": init_norm(ks[2], cfg, dtype),
        "ffn": ffn_mod.init_mlp(ks[3], cfg, dtype),
    }


def init_dec_block(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    return {
        "ln1": init_norm(ks[0], cfg, dtype),
        "self_attn": attn.init_attention(ks[1], cfg, dtype),
        "ln_x": init_norm(ks[2], cfg, dtype),
        "cross_attn": attn.init_attention(ks[3], cfg, dtype),
        "ln2": init_norm(ks[4], cfg, dtype),
        "ffn": ffn_mod.init_mlp(ks[5], cfg, dtype),
    }


def init_encdec(key, cfg: ModelConfig) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": embed_init(ks[2], (cfg.vocab_size, cfg.d_model), dtype),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg, dtype)
                               )(enc_keys),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg, dtype)
                               )(dec_keys),
        "ln_enc": init_norm(ks[3], cfg, dtype),
        "ln_f": init_norm(ks[3], cfg, dtype),
        "lm_head": embed_init(ks[4], (cfg.d_model, cfg.vocab_size), dtype),
    }


def encode(params: Dict, frames: jax.Array, cfg: ModelConfig,
           use_specs: Dict | None = None) -> jax.Array:
    """Stub-frontend encoder: frames (B, S_enc, d) -> states (B, S_enc, d)."""
    x = frames.astype(dtype_of(cfg.compute_dtype))
    S = x.shape[1]
    positions = jnp.arange(S)
    x = shard_hint(x, BATCH_AXES, None, None)

    def body(h, p):
        h = shard_hint(h, BATCH_AXES, None, None)   # pin loop-state sharding
        p = _use(p, use_specs, "enc_blocks")
        hn = apply_norm(p["ln1"], h, cfg)
        q, k, v = attn.compute_qkv(p["attn"], hn, cfg, positions)
        h = h + attn.project_out(p["attn"],
                                 attn.attention_ctx(q, k, v, cfg,
                                                    causal=False))
        hn = apply_norm(p["ln2"], h, cfg)
        return h + ffn_mod.apply_mlp(p["ffn"], hn, cfg), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return apply_norm(params["ln_enc"], x, cfg)


def _dec_block_seq(p, h, enc, cfg, positions, enc_positions, collect):
    h = shard_hint(h, BATCH_AXES, None, None)       # pin loop-state sharding
    hn = apply_norm(p["ln1"], h, cfg)
    q, k, v = attn.compute_qkv(p["self_attn"], hn, cfg, positions)
    h = h + attn.project_out(p["self_attn"],
                             attn.attention_ctx(q, k, v, cfg, causal=True))
    hn = apply_norm(p["ln_x"], h, cfg)
    qx, _, _ = attn.compute_qkv(p["cross_attn"], hn, cfg, positions)
    _, kx, vx = attn.compute_qkv(p["cross_attn"], enc, cfg, enc_positions)
    h = h + attn.project_out(p["cross_attn"],
                             attn.attention_ctx(qx, kx, vx, cfg,
                                                causal=False))
    hn = apply_norm(p["ln2"], h, cfg)
    h = h + ffn_mod.apply_mlp(p["ffn"], hn, cfg)
    cache = None
    if collect:
        cache = {"kv": {"k": k, "v": v}, "xk": kx, "xv": vx}
    return h, cache


def encdec_forward(params: Dict, frames: jax.Array, tokens: jax.Array,
                   cfg: ModelConfig, collect_cache: bool = False,
                   use_specs: Dict | None = None):
    """Full teacher-forced forward: returns (logits, caches|None)."""
    enc = encode(params, frames, cfg, use_specs)
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    S = x.shape[1]
    positions = jnp.arange(S)
    enc_positions = jnp.arange(enc.shape[1])

    def body(h, p):
        p = _use(p, use_specs, "dec_blocks")
        h, cache = _dec_block_seq(p, h, enc, cfg, positions, enc_positions,
                                  collect_cache)
        return h, cache

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(body_fn, x, params["dec_blocks"])
    x = apply_norm(params["ln_f"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = shard_hint(logits, BATCH_AXES, None, "model")
    return logits, caches


def encdec_decode_step(params: Dict, token: jax.Array, pos: jax.Array,
                       caches: Dict, cfg: ModelConfig,
                       use_specs: Dict | None = None):
    """One decoder token with self-KV ring + fixed cross-KV caches."""
    x = params["embed"][token[:, None]].astype(dtype_of(cfg.compute_dtype))

    def body(h, layer):
        p, cache = layer
        p = _use(p, use_specs, "dec_blocks")
        hn = apply_norm(p["ln1"], h, cfg)
        positions = pos[None]
        q, k, v = attn.compute_qkv(p["self_attn"], hn, cfg, positions)
        kv = attn.cache_update(cache["kv"], k, v, pos, cfg)
        h = h + attn.project_out(p["self_attn"],
                                 attn.decode_attention(q, kv, pos, cfg))
        hn = apply_norm(p["ln_x"], h, cfg)
        qx, _, _ = attn.compute_qkv(p["cross_attn"], hn, cfg, positions)
        Lx = cache["xk"].shape[1]
        valid = jnp.ones((h.shape[0], Lx), bool)
        acc, den, _ = attn.decode_partial(qx, cache["xk"], cache["xv"],
                                          valid)
        ctx = (acc / jnp.maximum(den, 1e-30)[..., None])[:, None]
        h = h + attn.project_out(p["cross_attn"], ctx.astype(h.dtype))
        hn = apply_norm(p["ln2"], h, cfg)
        h = h + ffn_mod.apply_mlp(p["ffn"], hn, cfg)
        return h, dict(cache, kv=kv)

    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches))
    x = apply_norm(params["ln_f"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits[:, 0], new_caches


def init_encdec_caches(cfg: ModelConfig, batch: int, max_len: int,
                       enc_len: int):
    dtype = dtype_of(cfg.compute_dtype)
    L = cfg.num_layers
    K, hd = cfg.num_kv_heads, cfg.hd
    one = {
        "kv": attn.init_cache(cfg, batch, max_len, dtype),
        "xk": jnp.zeros((batch, enc_len, K, hd), dtype),
        "xv": jnp.zeros((batch, enc_len, K, hd), dtype),
    }
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), one)
