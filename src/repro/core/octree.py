"""Linear Morton octree over point clouds (TPU-friendly: arrays, no pointers).

The paper stores the environment in an octree whose nodes hold occupancy and
"only further subdivide when partially occupied" (§II-B).  We reproduce that
with a *linear* octree: for every level ``l`` we keep a sorted array of the
Morton codes of occupied nodes plus a ``full`` flag (all descendants occupied
=> terminal solid box).  Child lookup is a binary search — no stacks, no
pointers, so the traversal in :mod:`repro.engine.executor` is pure array
code.  The engine's scene tables (padded :func:`stack_device_octrees` and
ragged :func:`concat_device_octrees`) both build from these levels.

Build runs once per scene on the host (numpy); traversal consumes the arrays
as jax constants.

For the device-resident wavefront engine the ragged per-level Python lists
are additionally materialized as *padded* rectangular device arrays
(:class:`DeviceOctree`): one ``(depth+1, n_max)`` code matrix (tail-padded
with ``PAD_CODE = 0xFFFFFFFF``, which sorts above every valid 30-bit Morton
code, so ``searchsorted`` stays correct on the padded rows), a matching
``full`` matrix (padded ``False``), per-level occupancy counts, the
per-level cell sizes, and a CSR child-pointer table (per-node first-child
offset + 8-bit child-occupancy mask) that turns child lookup into an O(1)
gather for the fused traversal step.  This is what lets a single
``jax.lax.while_loop`` index levels with a traced loop counter instead of
Python-level unrolling.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import AABBs
from repro.core.quantize import (GRID_BITS, META_FORMATS, pack_geom_bf16,
                                 pack_topo_bf16, pack_topo_u8)

MAX_DEPTH = 10  # 30 bits of Morton code
# The bf16 geometry word packs cell coordinates on the 2**GRID_BITS leaf
# grid; that is exact precisely because no tree is deeper than the grid.
assert GRID_BITS == MAX_DEPTH, "packed-geometry grid must match MAX_DEPTH"
PAD_CODE = np.uint32(0xFFFFFFFF)  # > any 30-bit Morton code; keeps rows sorted
#: Row-alignment quantum of the level-major device tables.  Every padded
#: level row (``DeviceOctree`` / ``MultiSceneOctree``) is a whole number of
#: these rows, so the persistent megakernel's HBM->VMEM metadata windows
#: (kernels/persist) can stream a level as back-to-back fixed-size DMA
#: chunks without ever slicing past the table edge.  Occupied nodes sit at
#: the FRONT of their level row (level-major layout), so a level's window
#: is one contiguous gather of ``ceil(counts[l] / META_ROW_ALIGN)`` chunks.
META_ROW_ALIGN = 128


def align_rows(n: int) -> int:
    """Round a level width up to the :data:`META_ROW_ALIGN` row quantum."""
    return max(((int(n) + META_ROW_ALIGN - 1) // META_ROW_ALIGN)
               * META_ROW_ALIGN, META_ROW_ALIGN)


def _part1by2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) & 0x3FF
    x = (x | (x << 16)) & np.uint32(0x030000FF)
    x = (x | (x << 8)) & np.uint32(0x0300F00F)
    x = (x | (x << 4)) & np.uint32(0x030C30C3)
    x = (x | (x << 2)) & np.uint32(0x09249249)
    return x


def morton_encode(ix: np.ndarray, iy: np.ndarray, iz: np.ndarray) -> np.ndarray:
    return (_part1by2(ix) | (_part1by2(iy) << 1) | (_part1by2(iz) << 2)
            ).astype(np.uint32)


def _compact1by2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) & np.uint32(0x09249249)
    x = (x | (x >> 2)) & np.uint32(0x030C30C3)
    x = (x | (x >> 4)) & np.uint32(0x0300F00F)
    x = (x | (x >> 8)) & np.uint32(0x030000FF)
    x = (x | (x >> 16)) & np.uint32(0x000003FF)
    return x


def morton_decode(code: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    return (_compact1by2(code), _compact1by2(code >> 1), _compact1by2(code >> 2))


# jnp versions (used inside jitted traversal for node AABB reconstruction).

def _jnp_compact1by2(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32) & jnp.uint32(0x09249249)
    x = (x | (x >> 2)) & jnp.uint32(0x030C30C3)
    x = (x | (x >> 4)) & jnp.uint32(0x0300F00F)
    x = (x | (x >> 8)) & jnp.uint32(0x030000FF)
    x = (x | (x >> 16)) & jnp.uint32(0x000003FF)
    return x


def jnp_morton_decode(code: jax.Array) -> jax.Array:
    """(...,) uint32 codes -> (..., 3) int32 cell coords."""
    return jnp.stack([
        _jnp_compact1by2(code), _jnp_compact1by2(code >> 1),
        _jnp_compact1by2(code >> 2)], axis=-1).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class OctreeLevel:
    codes: np.ndarray      # (n_l,) uint32, sorted occupied node codes
    full: np.ndarray       # (n_l,) bool, all descendants occupied
    # CSR child pointers into the next level's sorted code array.  Children
    # of node i occupy the contiguous index range
    # [child_start[i], child_start[i] + popcount(child_mask[i])); bit j of
    # child_mask is set iff octant j is occupied.  Zeros at the leaf level.
    child_start: np.ndarray  # (n_l,) int32 first-child offset in level l+1
    child_mask: np.ndarray   # (n_l,) uint8 8-bit child-occupancy bitmask


@dataclasses.dataclass(frozen=True)
class Octree:
    """Linear octree over a cubic scene volume."""

    scene_lo: np.ndarray         # (3,)
    scene_size: float            # cube edge length
    depth: int                   # leaf level
    levels: List[OctreeLevel]    # levels[0] = root level (1 cell), … [depth]
    # Point storage (for ball query): points sorted by leaf Morton code.
    points_sorted: np.ndarray    # (P, 3)
    point_index: np.ndarray      # (P,) int32 original index of points_sorted[i]
    leaf_point_start: np.ndarray  # (n_leaf,) int32 range start into points_sorted
    leaf_point_count: np.ndarray  # (n_leaf,) int32

    @property
    def num_leaves(self) -> int:
        return len(self.levels[self.depth].codes)

    def cell_size(self, level: int) -> float:
        return self.scene_size / (1 << level)

    def node_aabbs(self, level: int) -> AABBs:
        """Materialize all occupied nodes of a level as AABBs."""
        codes = self.levels[level].codes
        xyz = np.stack(morton_decode(codes), -1).astype(np.float32)
        cs = self.cell_size(level)
        center = self.scene_lo[None, :] + (xyz + 0.5) * cs
        half = np.full_like(center, cs / 2.0)
        return AABBs(center=jnp.asarray(center), half=jnp.asarray(half))

    def leaf_aabbs(self) -> AABBs:
        return self.node_aabbs(self.depth)


def _pack_node_meta(codes: np.ndarray, full: np.ndarray,
                    child_start: np.ndarray, child_mask: np.ndarray,
                    meta_format: str) -> np.ndarray:
    """Pack per-level channel matrices into the gather-optimized row table.

    Inputs are the padded ``(L, n_max)`` channel matrices (``codes``
    uint32 with :data:`PAD_CODE` tails); output is the ``(L, n_max,
    words)`` int32 ``node_meta`` table for ``meta_format`` (see
    :mod:`repro.core.quantize` for the row encodings).  Pad rows pack to
    zero words in the compressed formats — they are only ever gathered
    by invalid (masked) lanes, and PAD_CODE's coordinates would overflow
    the 10-bit geometry fields.
    """
    if meta_format not in META_FORMATS:
        raise ValueError(f"unknown meta_format {meta_format!r}; "
                         f"allowed: {', '.join(META_FORMATS)}")
    if meta_format == "fp32":
        return np.stack([codes.view(np.int32), full.astype(np.int32),
                         child_start, child_mask], axis=-1)
    pad = codes == PAD_CODE
    full_p = np.where(pad, False, full)
    start_p = np.where(pad, 0, child_start)
    mask_p = np.where(pad, 0, child_mask)
    if meta_format == "u8":
        octant = (codes & np.uint32(7)).astype(np.int32)
        w = pack_topo_u8(full_p, np.where(pad, 0, octant), start_p, mask_p)
        return w[..., None]
    w0 = pack_topo_bf16(full_p, start_p, mask_p)
    w1 = np.zeros_like(w0)
    for level in range(codes.shape[0]):
        xyz = np.stack(morton_decode(codes[level]), axis=-1)
        w1[level] = np.where(pad[level], 0,
                             pack_geom_bf16(np.where(pad[level, :, None], 0,
                                                     xyz), level))
    return np.stack([w0, w1], axis=-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DeviceOctree:
    """Padded, device-resident view of the octree levels.

    All rows are tail-padded to the widest level (rounded up to the
    :data:`META_ROW_ALIGN` row quantum) so a traced level index can
    gather them inside ``jax.lax.while_loop`` / ``vmap``.  ``codes`` rows stay
    sorted because the pad value :data:`PAD_CODE` exceeds every valid code.
    Arrays may carry a leading scene axis when built by
    :func:`stack_device_octrees`.
    """

    codes: jax.Array       # (..., depth+1, n_max) uint32, PAD_CODE padded
    full: jax.Array        # (..., depth+1, n_max) bool, False padded
    counts: jax.Array      # (..., depth+1) int32 occupied nodes per level
    cell_sizes: jax.Array  # (..., depth+1) float32
    scene_lo: jax.Array    # (..., 3) float32
    # CSR child pointers (see :class:`OctreeLevel`), 0-padded.  Row l indexes
    # into row l+1 of ``codes``; the leaf row is all zeros.  These give the
    # fused traversal step O(1) child expansion: occupancy is a bit test and
    # the child's node index is start + popcount(mask & ((1 << j) - 1)),
    # replacing the per-candidate ``searchsorted`` over 8x-expanded codes.
    child_start: jax.Array  # (..., depth+1, n_max) int32
    child_mask: jax.Array   # (..., depth+1, n_max) int32 (low 8 bits used)
    # Gather-optimized packed row table: the CSR traversal arms read all
    # per-node metadata in ONE (cap, words) gather per level instead of
    # four row gathers.  ``meta_format`` picks the row encoding
    # (repro.core.quantize): "fp32" = [code, full, child_start,
    # child_mask] 4 x int32; "bf16" = [topology word, geometry word];
    # "u8" = [topology word] (lanes carry their own Morton code).  The
    # unpacked channel planes above are retained in every format — the
    # non-CSR arms and the fused step's code re-gather read them.
    node_meta: jax.Array    # (..., depth+1, n_max, words) int32
    depth: int             # static leaf level (shared across stacked scenes)
    meta_format: str = "fp32"  # static row encoding of ``node_meta``

    def tree_flatten(self):
        return ((self.codes, self.full, self.counts, self.cell_sizes,
                 self.scene_lo, self.child_start, self.child_mask,
                 self.node_meta), (self.depth, self.meta_format))

    @classmethod
    def tree_unflatten(cls, aux, children):
        depth, meta_format = aux
        return cls(*children, depth=depth, meta_format=meta_format)


def device_octree(tree: Octree, meta_format: str = "fp32") -> DeviceOctree:
    """Pad the ragged level lists of ``tree`` into rectangular device arrays.

    Rows are additionally padded to the :data:`META_ROW_ALIGN` quantum
    (level-major row alignment): occupied nodes stay at the front of each
    row, and the per-level row extents live in ``counts`` — together these
    make the streamed metadata windows of the persistent megakernel
    contiguous fixed-chunk gathers.

    ``meta_format`` picks the packed ``node_meta`` row encoding
    (:data:`repro.core.quantize.META_FORMATS`); packing raises if the
    scene's child pointers overflow a compressed format's field width
    (the executor's chooser gates on :func:`~repro.core.quantize.
    format_eligible` so it never asks for an overflowing format).
    """
    n_max = align_rows(max(len(l.codes) for l in tree.levels))
    L = tree.depth + 1
    codes = np.full((L, n_max), PAD_CODE, np.uint32)
    full = np.zeros((L, n_max), bool)
    counts = np.zeros((L,), np.int32)
    child_start = np.zeros((L, n_max), np.int32)
    child_mask = np.zeros((L, n_max), np.int32)
    for l, lvl in enumerate(tree.levels):
        n = len(lvl.codes)
        codes[l, :n] = lvl.codes
        full[l, :n] = lvl.full
        counts[l] = n
        child_start[l, :n] = lvl.child_start
        child_mask[l, :n] = lvl.child_mask
    cells = np.asarray([tree.cell_size(l) for l in range(L)], np.float32)
    meta = _pack_node_meta(codes, full, child_start, child_mask, meta_format)
    return DeviceOctree(codes=jnp.asarray(codes), full=jnp.asarray(full),
                        counts=jnp.asarray(counts),
                        cell_sizes=jnp.asarray(cells),
                        scene_lo=jnp.asarray(tree.scene_lo, jnp.float32),
                        child_start=jnp.asarray(child_start),
                        child_mask=jnp.asarray(child_mask),
                        node_meta=jnp.asarray(meta),
                        depth=tree.depth, meta_format=meta_format)


def stack_device_octrees(trees: List[Octree]) -> DeviceOctree:
    """Stack scenes into one DeviceOctree with a leading scene axis.

    All trees must share a depth; levels are padded to the widest level of
    the widest scene so the batch traverses in one compiled call.
    """
    assert trees, "need at least one octree"
    depth = trees[0].depth
    assert all(t.depth == depth for t in trees), "scene depths must match"
    devs = [device_octree(t) for t in trees]
    n_max = max(d.codes.shape[-1] for d in devs)

    def pad(d: DeviceOctree) -> DeviceOctree:
        extra = n_max - d.codes.shape[-1]
        codes = jnp.pad(d.codes, ((0, 0), (0, extra)),
                        constant_values=PAD_CODE)
        full = jnp.pad(d.full, ((0, 0), (0, extra)))
        child_start = jnp.pad(d.child_start, ((0, 0), (0, extra)))
        child_mask = jnp.pad(d.child_mask, ((0, 0), (0, extra)))
        # Rebuild the packed view from the padded columns so its code
        # channel keeps the PAD_CODE invariant of ``codes``.
        node_meta = jnp.stack(
            [jax.lax.bitcast_convert_type(codes, jnp.int32),
             full.astype(jnp.int32), child_start, child_mask], axis=-1)
        return DeviceOctree(
            codes=codes, full=full, counts=d.counts,
            cell_sizes=d.cell_sizes, scene_lo=d.scene_lo,
            child_start=child_start, child_mask=child_mask,
            node_meta=node_meta, depth=depth)

    devs = [pad(d) for d in devs]
    return DeviceOctree(
        codes=jnp.stack([d.codes for d in devs]),
        full=jnp.stack([d.full for d in devs]),
        counts=jnp.stack([d.counts for d in devs]),
        cell_sizes=jnp.stack([d.cell_sizes for d in devs]),
        scene_lo=jnp.stack([d.scene_lo for d in devs]),
        child_start=jnp.stack([d.child_start for d in devs]),
        child_mask=jnp.stack([d.child_mask for d in devs]),
        node_meta=jnp.stack([d.node_meta for d in devs]),
        depth=depth)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class MultiSceneOctree:
    """Flat multi-scene CSR table: one row per level, scenes concatenated.

    The ragged alternative to :func:`stack_device_octrees`: instead of a
    scene axis padded to the widest scene, level ``l`` holds the nodes of
    ALL scenes back to back (scene-major), so the pad per row is shared by
    the whole batch and total work scales with the *sum* of scene sizes,
    not ``S x max``.  ``child_start`` is rebased to global next-level
    indices at build time, so traversal code is identical to the
    single-scene CSR path; Morton codes stay scene-local (a node's AABB
    derives from its code plus its scene's ``scene_lo`` / cell size, both
    gathered per pair via ``scene_of_query``).  Scene ``s``'s root sits at
    flat index ``s`` of the level-0 row.
    """

    node_meta: jax.Array   # (depth+1, n_max, words) int32 packed rows
    codes: jax.Array       # (depth+1, n_max) uint32 scene-local Morton codes
    counts: jax.Array      # (depth+1,) int32 total nodes per level
    cell_sizes: jax.Array  # (S, depth+1) float32 per-scene cell edge
    scene_lo: jax.Array    # (S, 3) float32
    # Per-scene sub-extents of the concatenated level rows: scene ``s``'s
    # nodes at level ``l`` occupy flat indices [scene_off[s, l],
    # scene_off[s, l] + scene_counts[s, l]).  The persistent megakernel's
    # streamed window schedule uses these to fetch only the windows a
    # tile's scene can touch (per-scene windows), so one huge scene in a
    # ragged batch no longer forces the whole concatenated row resident.
    scene_off: jax.Array     # (S, depth+1) int32 flat row offset per scene
    scene_counts: jax.Array  # (S, depth+1) int32 occupied nodes per scene
    depth: int             # static shared leaf level
    meta_format: str = "fp32"  # static row encoding (repro.core.quantize)

    @property
    def num_scenes(self) -> int:
        return self.cell_sizes.shape[0]

    def tree_flatten(self):
        return ((self.node_meta, self.codes, self.counts, self.cell_sizes,
                 self.scene_lo, self.scene_off, self.scene_counts),
                (self.depth, self.meta_format))

    @classmethod
    def tree_unflatten(cls, aux, children):
        depth, meta_format = aux
        return cls(*children, depth=depth, meta_format=meta_format)


def concat_device_octrees(trees: List[Octree],
                          meta_format: str = "fp32") -> MultiSceneOctree:
    """Concatenate scenes into one flat per-level CSR table (see
    :class:`MultiSceneOctree`).  All trees must share a depth; node counts
    may differ arbitrarily — no per-scene padding happens.

    ``meta_format`` packs the flat rows like :func:`device_octree` does
    (codes stay scene-local, child pointers are rebased to flat indices
    BEFORE packing, so the compressed pointer fields must hold the
    concatenated level widths)."""
    assert trees, "need at least one octree"
    depth = trees[0].depth
    assert all(t.depth == depth for t in trees), "scene depths must match"
    L = depth + 1
    totals = [sum(len(t.levels[l].codes) for t in trees) for l in range(L)]
    n_max = align_rows(max(totals))
    codes = np.full((L, n_max), PAD_CODE, np.uint32)
    full = np.zeros((L, n_max), bool)
    child_start = np.zeros((L, n_max), np.int32)
    child_mask = np.zeros((L, n_max), np.int32)
    for l in range(L):
        off = 0
        off_next = np.cumsum(
            [0] + [len(t.levels[l + 1].codes) for t in trees]
        ) if l < depth else None
        for s, t in enumerate(trees):
            lvl = t.levels[l]
            n = len(lvl.codes)
            codes[l, off:off + n] = lvl.codes
            full[l, off:off + n] = lvl.full
            if l < depth:   # rebase child pointers into the flat next row
                child_start[l, off:off + n] = lvl.child_start + off_next[s]
                child_mask[l, off:off + n] = lvl.child_mask
            off += n
    meta = _pack_node_meta(codes, full, child_start, child_mask, meta_format)
    cells = np.asarray([[t.cell_size(l) for l in range(L)] for t in trees],
                       np.float32)
    los = np.stack([np.asarray(t.scene_lo, np.float32) for t in trees])
    per_scene = np.asarray([[len(t.levels[l].codes) for l in range(L)]
                            for t in trees], np.int32)       # (S, L)
    offs = (np.cumsum(per_scene, axis=0) - per_scene).astype(np.int32)
    return MultiSceneOctree(node_meta=jnp.asarray(meta),
                            codes=jnp.asarray(codes),
                            counts=jnp.asarray(totals, jnp.int32),
                            cell_sizes=jnp.asarray(cells),
                            scene_lo=jnp.asarray(los),
                            scene_off=jnp.asarray(offs),
                            scene_counts=jnp.asarray(per_scene), depth=depth,
                            meta_format=meta_format)


def node_centers_from_xyz(xyz: jax.Array, scene_lo: jax.Array,
                          cell_size) -> Tuple[jax.Array, jax.Array]:
    """Integer cell coords (K, 3) at a level -> (centers, halves) (K, 3).

    The shared float formula of every traversal arm: identical int
    coordinates give bitwise-identical geometry, which is what lets the
    compressed metadata formats (whose decode reproduces the SAME ints
    the Morton path would) keep verdicts and counters bitwise-equal.
    """
    xyz = xyz.astype(jnp.float32)
    cell = jnp.asarray(cell_size, jnp.float32)
    if cell.ndim:
        cell = cell[..., None]
    lo = scene_lo if scene_lo.ndim > 1 else scene_lo[None, :]
    center = lo + (xyz + 0.5) * cell
    half = jnp.broadcast_to(cell / 2.0, center.shape)
    return center, half


def node_centers_from_codes(codes: jax.Array, scene_lo: jax.Array,
                            cell_size) -> Tuple[jax.Array, jax.Array]:
    """Codes (K,) at a level -> (centers (K,3), halves (K,3)). jit-safe.

    ``scene_lo`` is (3,) or per-code (K, 3); ``cell_size`` a scalar or a
    per-code (K,) array — the ragged multi-scene frontier gathers both per
    pair, single-scene traversals pass the scalars.
    """
    return node_centers_from_xyz(jnp_morton_decode(codes), scene_lo,
                                 cell_size)


def build_octree(points: np.ndarray, depth: int = 6,
                 scene_lo: np.ndarray | None = None,
                 scene_size: float | None = None) -> Octree:
    """Build a linear octree from a point cloud (host-side, once per scene)."""
    points = np.asarray(points, np.float32)
    assert 1 <= depth <= MAX_DEPTH
    if scene_lo is None or scene_size is None:
        lo = points.min(0)
        hi = points.max(0)
        pad = 1e-3 * float(np.max(hi - lo) + 1e-6)
        scene_lo = lo - pad
        scene_size = float(np.max(hi - lo) + 2 * pad)
    scene_lo = np.asarray(scene_lo, np.float32)

    res = 1 << depth
    rel = (points - scene_lo[None, :]) / scene_size
    cells = np.clip((rel * res).astype(np.int64), 0, res - 1).astype(np.uint32)
    pt_codes = morton_encode(cells[:, 0], cells[:, 1], cells[:, 2])

    order = np.argsort(pt_codes, kind="stable")
    pt_codes_sorted = pt_codes[order]
    points_sorted = points[order]

    leaf_codes, leaf_start, leaf_count = np.unique(
        pt_codes_sorted, return_index=True, return_counts=True)
    leaf_codes = leaf_codes.astype(np.uint32)

    # Bottom-up levels with full flags.  A leaf is full by definition; an
    # internal node is full iff all 8 children exist and are full.
    levels: List[OctreeLevel] = [None] * (depth + 1)  # type: ignore
    n_leaf = len(leaf_codes)
    levels[depth] = OctreeLevel(codes=leaf_codes, full=np.ones(n_leaf, bool),
                                child_start=np.zeros(n_leaf, np.int32),
                                child_mask=np.zeros(n_leaf, np.uint8))
    child_codes = leaf_codes
    child_full = levels[depth].full
    for l in range(depth - 1, -1, -1):
        parent_of_child = child_codes >> np.uint32(3)
        codes_l, inv = np.unique(parent_of_child, return_inverse=True)
        n_children = np.zeros(len(codes_l), np.int32)
        np.add.at(n_children, inv, 1)
        n_full = np.zeros(len(codes_l), np.int32)
        np.add.at(n_full, inv, child_full.astype(np.int32))
        full_l = (n_children == 8) & (n_full == 8)
        # CSR child pointers: sorted child codes group contiguously by
        # parent, so the first-child offset is an exclusive scan of the
        # per-parent child counts; the occupancy bitmask ORs each child's
        # octant (low 3 code bits) into its parent's slot.
        start_l = (np.cumsum(n_children) - n_children).astype(np.int32)
        mask_l = np.zeros(len(codes_l), np.uint8)
        np.bitwise_or.at(
            mask_l, inv,
            (np.uint8(1) << (child_codes & np.uint32(7)).astype(np.uint8)))
        levels[l] = OctreeLevel(codes=codes_l.astype(np.uint32), full=full_l,
                                child_start=start_l, child_mask=mask_l)
        child_codes, child_full = codes_l.astype(np.uint32), full_l

    return Octree(scene_lo=scene_lo, scene_size=float(scene_size), depth=depth,
                  levels=levels, points_sorted=points_sorted,
                  point_index=order.astype(np.int32),
                  leaf_point_start=leaf_start.astype(np.int32),
                  leaf_point_count=leaf_count.astype(np.int32))


def lookup_children(level_codes: jax.Array, parent_codes: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Occupancy lookup for the 8 children of each parent code.

    Args:
      level_codes: (n_{l+1},) sorted occupied codes at the child level.
      parent_codes: (K,) parent codes (level l).
    Returns:
      (child_codes (K, 8) uint32, child_idx (K, 8) int32 with -1 = empty).
    """
    cand = (parent_codes[:, None].astype(jnp.uint32) << jnp.uint32(3)
            ) | jnp.arange(8, dtype=jnp.uint32)[None, :]
    pos = jnp.searchsorted(level_codes, cand.reshape(-1)).reshape(cand.shape)
    pos_c = jnp.clip(pos, 0, level_codes.shape[0] - 1)
    found = level_codes[pos_c] == cand
    return cand, jnp.where(found, pos_c, -1).astype(jnp.int32)
