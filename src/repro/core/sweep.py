"""Swept-edge (CCD) validation of motion-planning graph edges.

A planning-graph *edge* is a straight segment in joint space; validating it
means finding the first colliding configuration along the motion — the
workload cuRobo-style planners batch by the thousand, and the paper's
control-flow argument in miniature: an edge wants to STOP at its first
hit, not sample every waypoint.

The approach maps continuous collision detection onto the existing
wavefront traversal via the plan layer (:mod:`repro.engine.plan`):

1. The edge is discretized at ``resolution`` sub-intervals (the comparison
   resolution of dense waypoint sampling); forward kinematics runs once
   for every waypoint of every edge.
2. Each configuration-space segment ``[t0, t1]`` is enclosed in
   **conservative swept OBBs** (one per robot link): in the frame of the
   segment's middle waypoint, the box fitted around the corner points of
   every contained waypoint's link OBB.  An OBB is the convex hull of its
   corners, so the enclosure contains every contained waypoint box — the
   soundness invariant (a swept verdict upper-bounds any sampled waypoint
   verdict, test-enforced).
3. **Left-first bisection**: per edge, a queue of disjoint untested
   segments sorted by ``t0`` (initially the whole edge).  Each round pops
   every undecided edge's *earliest* segment into one flat pool of
   (edge, link, segment) query slots — the segment's links grouped under
   one verdict owner so a hit retires all of them — and bisects only
   segments whose swept volume hit occupied leaves.  A segment that
   misses retires its whole sub-interval; later segments are never
   touched until everything earlier is resolved, so the first
   confirmation IS the first hit and the rest of the edge is skipped —
   the edge-level analogue of the traversal's early exit.
4. Width-1 queue prefixes go through the **payload lane**: every slot's
   payload is its sub-interval rank, the owner lane groups a whole edge,
   and the traversal keeps the per-edge minimum payload that hit —
   in-traversal per-edge early exit, with later sub-intervals compacted
   out of the frontier exactly like decided waypoint lanes.  Host-loop
   engines run the same rounds as boolean plans and reduce the minimum on
   the host (identical result, no in-traversal exit).

``pipeline.check_edges`` is the front-end; ``benchmarks fig_edges``
measures swept vs dense axis tests at equal resolution.
"""
from __future__ import annotations

import time
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.counters import Counters
from repro.core.geometry import NUM_LINKS, OBBs, arm_link_obbs, obb_corners
from repro.core.sact import PAYLOAD_INF
from repro.engine.plan import plan_edges, plan_queries

#: Absolute inflation of fitted enclosures: keeps containment strict under
#: float32 rounding of the two rotation transforms (world -> mid frame ->
#: world), so the soundness invariant survives exact SACT comparisons.
_FIT_EPS = 1e-5


def edge_waypoints(q_from: np.ndarray, q_to: np.ndarray,
                   resolution: int) -> np.ndarray:
    """(E, 7) endpoint configs -> (E, R+1, 7) linear joint-space waypoints."""
    t = np.linspace(0.0, 1.0, resolution + 1, dtype=np.float32)[None, :, None]
    qf = np.asarray(q_from, np.float32)[:, None, :]
    qt = np.asarray(q_to, np.float32)[:, None, :]
    return qf * (1.0 - t) + qt * t


def edge_link_geometry(q_from: np.ndarray, q_to: np.ndarray, resolution: int,
                       base_pos=None) -> Tuple[np.ndarray, np.ndarray]:
    """FK every edge waypoint once.

    Returns (corners (E, R+1, L, 8, 3), rot (E, R+1, L, 3, 3)) — all the
    geometry the bisection ever needs; refinement rounds only re-fit
    enclosures over subsets of these corner points.
    """
    E = np.asarray(q_from).shape[0]
    R = resolution
    cfgs = edge_waypoints(q_from, q_to, R)
    obbs = arm_link_obbs(jnp.asarray(cfgs), base_pos=base_pos)
    corners = np.asarray(obb_corners(obbs)).reshape(E, R + 1, NUM_LINKS, 8, 3)
    rot = np.asarray(obbs.rot).reshape(E, R + 1, NUM_LINKS, 3, 3)
    return corners, rot


def swept_obbs(corners: np.ndarray, rot: np.ndarray, edge: np.ndarray,
               lo: np.ndarray, hi: np.ndarray) -> OBBs:
    """Conservative swept enclosures for segments [lo, hi] of some edges.

    All segments must share a width (one bisection round).  For each
    (segment, link): in the frame of the link's rotation at the middle
    waypoint, fit the min/max extents of the corner points of every
    contained waypoint box.  Returns flat OBBs, segment-major x link-minor
    (``n_seg * NUM_LINKS`` boxes).
    """
    edge = np.asarray(edge)
    lo = np.asarray(lo)
    hi = np.asarray(hi)
    # Mixed widths share one gather: clamping the waypoint span to ``hi``
    # duplicates the last contained waypoint, which cannot move a min/max.
    w = int((hi - lo).max())
    span = np.minimum(lo[:, None] + np.arange(w + 1)[None, :], hi[:, None])
    pts = corners[edge[:, None], span]                        # (N, w+1, L, 8, 3)
    r_mid = rot[edge, (lo + hi) // 2]                         # (N, L, 3, 3)
    local = np.einsum("nlji,nwlkj->nwlki", r_mid, pts)
    mn = local.min(axis=(1, 3))                               # (N, L, 3)
    mx = local.max(axis=(1, 3))
    half = (mx - mn) * 0.5 + _FIT_EPS
    center = np.einsum("nlij,nlj->nli", r_mid, (mn + mx) * 0.5)
    n = len(edge) * NUM_LINKS
    return OBBs(center=jnp.asarray(center.reshape(n, 3), jnp.float32),
                half=jnp.asarray(half.reshape(n, 3), jnp.float32),
                rot=jnp.asarray(r_mid.reshape(n, 3, 3), jnp.float32))


def _segment_hits(engine, obbs: OBBs, n_seg: int,
                  in_traversal_exit: bool = True
                  ) -> Tuple[np.ndarray, object]:
    """One coarse refinement round: per-segment any-link hit flags."""
    if engine.cfg.device_resident and in_traversal_exit:
        owner = np.repeat(np.arange(n_seg, dtype=np.int32), NUM_LINKS)
        best, c = engine.execute(plan_edges(obbs, owner, n_seg))
        return best < PAYLOAD_INF, c
    collide, c = engine.execute(plan_queries(obbs))
    return collide.reshape(n_seg, NUM_LINKS).any(axis=1), c


def _first_hits(engine, obbs: OBBs, edge: np.ndarray, lo: np.ndarray,
                in_traversal_exit: bool = True
                ) -> Tuple[np.ndarray, object]:
    """One payload round over width-1 segments: per-edge first hit.

    ``edge`` may repeat (several sub-intervals of one edge race in one
    traversal); returns the (E',) best payload per *distinct* edge in
    ``np.unique(edge)`` order, ``PAYLOAD_INF`` where nothing hit.
    """
    uniq, local = np.unique(edge, return_inverse=True)
    if engine.cfg.device_resident and in_traversal_exit:
        owner = np.repeat(local.astype(np.int32), NUM_LINKS)
        payload = np.repeat(lo.astype(np.int32), NUM_LINKS)
        got, c = engine.execute(
            plan_edges(obbs, owner, len(uniq), payload=payload))
        return np.asarray(got, np.int64), c
    collide, c = engine.execute(plan_queries(obbs))
    seg_hit = collide.reshape(len(edge), NUM_LINKS).any(axis=1)
    best = np.full(len(uniq), PAYLOAD_INF, np.int64)
    np.minimum.at(best, local[seg_hit], lo[seg_hit].astype(np.int64))
    return best, c


def sweep_edges(engine, q_from, q_to, resolution: int = 16,
                base_pos=None, in_traversal_exit: bool = True
                ) -> Tuple[np.ndarray, np.ndarray, Counters]:
    """Batched first-hit validation of E joint-space edges (see module doc).

    Returns ``(first_hit (E,) float32, collide (E,) bool, counters)``:
    ``first_hit[e]`` is the parameter t0 of the first colliding
    sub-interval ``[t0, t0 + 1/resolution]`` (``inf`` for collision-free
    edges), and ``counters`` aggregates the work of every refinement
    round — the number the fig_edges benchmark compares against dense
    waypoint sampling at the same resolution.

    ``in_traversal_exit=False`` is the ablation arm: every round takes the
    ungrouped ``plan_queries`` path and reduces owner groups / payload
    minima on the host, so sibling lanes keep traversing after a group's
    verdict is already decided — identical verdicts, strictly more nodes
    visited.  The fig_edges benchmark compares node counts between the two
    arms to price the in-kernel owner early exit.
    """
    q_from = np.asarray(q_from, np.float32)
    q_to = np.asarray(q_to, np.float32)
    if q_from.ndim != 2 or q_from.shape != q_to.shape:
        raise ValueError("q_from / q_to must both be (E, 7) configurations")
    R = int(resolution)
    if R < 1 or (R & (R - 1)) != 0:
        # The bisection halves segments down to width 1; a non-power-of-two
        # grid would split unevenly and misalign first_hit = best / R.
        raise ValueError(f"resolution must be a power of two, got {R}")
    E = q_from.shape[0]
    t0_wall = time.perf_counter()
    corners, rot = edge_link_geometry(q_from, q_to, R, base_pos=base_pos)
    total = Counters()

    # Left-first descent (module docstring #3/#4).  Queues hold disjoint
    # untested segments sorted by t0; popping always takes the earliest, so
    # segments deeper in a queue start at or after everything ever popped —
    # the first width-1 confirmation is the edge's true first hit.
    queues = [[(0, R)] for _ in range(E)]
    best = np.full(E, PAYLOAD_INF, np.int64)
    decided = np.zeros(E, bool)
    while True:
        ce, clo, chi = [], [], []            # this round's coarse pops
        fe, flo = [], []                     # width-1 prefix pops
        for e in range(E):
            if decided[e] or not queues[e]:
                continue
            if queues[e][0][1] - queues[e][0][0] == 1:
                while queues[e] and queues[e][0][1] - queues[e][0][0] == 1:
                    s = queues[e].pop(0)
                    fe.append(e)
                    flo.append(s[0])
            else:
                s = queues[e].pop(0)
                ce.append(e)
                clo.append(s[0])
                chi.append(s[1])
        if not ce and not fe:
            break
        if fe:
            fe = np.asarray(fe, np.int32)
            flo = np.asarray(flo, np.int32)
            got, c = _first_hits(
                engine, swept_obbs(corners, rot, fe, flo, flo + 1), fe, flo,
                in_traversal_exit=in_traversal_exit)
            total.merge(c)
            uniq = np.unique(fe)
            hit = got < PAYLOAD_INF
            best[uniq[hit]] = got[hit]
            decided[uniq[hit]] = True
        if ce:
            ce = np.asarray(ce, np.int32)
            clo = np.asarray(clo, np.int32)
            chi = np.asarray(chi, np.int32)
            hits, c = _segment_hits(
                engine, swept_obbs(corners, rot, ce, clo, chi), len(ce),
                in_traversal_exit=in_traversal_exit)
            total.merge(c)
            for e, lo, hi in zip(ce[hits], clo[hits], chi[hits]):
                mid = (lo + hi) // 2
                queues[e].insert(0, (mid, hi))
                queues[e].insert(0, (lo, mid))

    first_hit = np.where(best < PAYLOAD_INF,
                         best.astype(np.float32) / np.float32(R),
                         np.inf).astype(np.float32)
    total.wall_time_s = time.perf_counter() - t0_wall
    return first_hit, best < PAYLOAD_INF, total

