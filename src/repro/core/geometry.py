"""Geometry primitives for the collision engine.

Struct-of-arrays layouts throughout (TPU-friendly): a *batch* of OBBs is
(centers (M,3), half_extents (M,3), rot (M,3,3)); a batch of AABBs is
(centers (N,3), half_extents (N,3)).  ``rot[m]`` columns are the OBB's local
axes expressed in world coordinates, so ``world = rot @ local + center``.

Also provides a minimal 7-DOF serial arm (Franka-like DH chain) whose links
carry fixed local OBBs, used to turn joint-space trajectories into the OBB
sets the paper collision-checks (Table III).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class OBBs:
    """Batch of oriented bounding boxes (SoA)."""

    center: jax.Array  # (M, 3)
    half: jax.Array    # (M, 3)
    rot: jax.Array     # (M, 3, 3), columns = local axes in world frame

    def tree_flatten(self):
        return (self.center, self.half, self.rot), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.center.shape[0]

    def bounding_sphere_radius(self) -> jax.Array:
        """Radius of the sphere that encloses each OBB (||half||)."""
        return jnp.linalg.norm(self.half, axis=-1)

    def inscribed_sphere_radius(self) -> jax.Array:
        """Radius of the largest sphere inside each OBB (min(half))."""
        return jnp.min(self.half, axis=-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AABBs:
    """Batch of axis-aligned bounding boxes (SoA)."""

    center: jax.Array  # (N, 3)
    half: jax.Array    # (N, 3)

    def tree_flatten(self):
        return (self.center, self.half), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.center.shape[0]

    @property
    def lo(self) -> jax.Array:
        return self.center - self.half

    @property
    def hi(self) -> jax.Array:
        return self.center + self.half


def rotation_from_euler(rpy: jax.Array) -> jax.Array:
    """Rotation matrices from (…, 3) roll/pitch/yaw angles -> (…, 3, 3)."""
    r, p, y = rpy[..., 0], rpy[..., 1], rpy[..., 2]
    cr, sr = jnp.cos(r), jnp.sin(r)
    cp, sp = jnp.cos(p), jnp.sin(p)
    cy, sy = jnp.cos(y), jnp.sin(y)
    row0 = jnp.stack([cy * cp, cy * sp * sr - sy * cr, cy * sp * cr + sy * sr], -1)
    row1 = jnp.stack([sy * cp, sy * sp * sr + cy * cr, sy * sp * cr - cy * sr], -1)
    row2 = jnp.stack([-sp, cp * sr, cp * cr], -1)
    return jnp.stack([row0, row1, row2], -2)


def point_aabb_sq_distance(points: jax.Array, aabb_center: jax.Array,
                           aabb_half: jax.Array) -> jax.Array:
    """Squared distance from points (...,3) to AABBs (...,3)/(...,3), broadcast."""
    d = jnp.abs(points - aabb_center) - aabb_half
    return jnp.sum(jnp.square(jnp.maximum(d, 0.0)), axis=-1)


# ---------------------------------------------------------------------------
# Serial arm forward kinematics (Franka-Emika-Panda-like DH chain).
# ---------------------------------------------------------------------------

# Modified DH parameters (a, d, alpha) per joint; 7 revolute joints.
_PANDA_DH = np.array(
    [
        # a      d       alpha
        [0.0000, 0.3330, 0.0],
        [0.0000, 0.0000, -np.pi / 2],
        [0.0000, 0.3160, np.pi / 2],
        [0.0825, 0.0000, np.pi / 2],
        [-0.0825, 0.3840, -np.pi / 2],
        [0.0000, 0.0000, np.pi / 2],
        [0.0880, 0.0000, np.pi / 2],
    ],
    dtype=np.float32,
)

# Per-link local OBB half-extents (rough Panda link volumes, metres).
_PANDA_LINK_HALF = np.array(
    [
        [0.060, 0.060, 0.170],
        [0.060, 0.090, 0.060],
        [0.060, 0.060, 0.160],
        [0.060, 0.085, 0.060],
        [0.055, 0.055, 0.195],
        [0.060, 0.080, 0.055],
        [0.050, 0.050, 0.080],
    ],
    dtype=np.float32,
)

# Local OBB centre offset (in the link frame) so boxes sit mid-link.
_PANDA_LINK_OFF = np.array(
    [
        [0.0, 0.0, -0.170],
        [0.0, 0.0, 0.0],
        [0.0, 0.0, -0.160],
        [0.0825, 0.0, 0.0],
        [-0.0825, 0.0, -0.190],
        [0.0, 0.0, 0.0],
        [0.088, 0.0, 0.080],
    ],
    dtype=np.float32,
)

NUM_LINKS = 7


def _dh_transform(theta: jax.Array, a: jax.Array, d: jax.Array,
                  alpha: jax.Array) -> jax.Array:
    """Modified-DH 4x4 transform for one joint; theta (...,) -> (...,4,4)."""
    ct, st = jnp.cos(theta), jnp.sin(theta)
    ca, sa = jnp.cos(alpha), jnp.sin(alpha)
    zeros = jnp.zeros_like(ct)
    ones = jnp.ones_like(ct)
    rows = [
        jnp.stack([ct, -st, zeros, a * ones], -1),
        jnp.stack([st * ca, ct * ca, -sa * ones, -d * sa * ones], -1),
        jnp.stack([st * sa, ct * sa, ca * ones, d * ca * ones], -1),
        jnp.stack([zeros, zeros, zeros, ones], -1),
    ]
    return jnp.stack(rows, -2)


def arm_link_obbs(joint_angles: jax.Array,
                  base_pos: jax.Array | None = None) -> OBBs:
    """Forward kinematics: joint angles (..., 7) -> per-link world OBBs.

    Returns OBBs with leading dims flattened: (prod(...)*7,) boxes.
    """
    joint_angles = jnp.asarray(joint_angles, jnp.float32)
    batch_shape = joint_angles.shape[:-1]
    q = joint_angles.reshape((-1, NUM_LINKS))
    B = q.shape[0]
    dh = jnp.asarray(_PANDA_DH)
    base = jnp.eye(4, dtype=jnp.float32)
    if base_pos is not None:
        base = base.at[:3, 3].set(jnp.asarray(base_pos, jnp.float32))
    T = jnp.broadcast_to(base, (B, 4, 4))
    centers, rots = [], []
    link_off = jnp.asarray(_PANDA_LINK_OFF)
    for j in range(NUM_LINKS):
        Tj = _dh_transform(q[:, j], dh[j, 0], dh[j, 1], dh[j, 2])
        T = jnp.einsum("bij,bjk->bik", T, Tj)
        R = T[:, :3, :3]
        c = T[:, :3, 3] + jnp.einsum("bij,j->bi", R, link_off[j])
        centers.append(c)
        rots.append(R)
    center = jnp.stack(centers, 1).reshape((-1, 3))          # (B*7, 3)
    rot = jnp.stack(rots, 1).reshape((-1, 3, 3))             # (B*7, 3, 3)
    half = jnp.tile(jnp.asarray(_PANDA_LINK_HALF), (B, 1))   # (B*7, 3)
    del batch_shape
    return OBBs(center=center, half=half, rot=rot)


def trajectory_obbs(start: jax.Array, goal: jax.Array, num_waypoints: int,
                    base_pos: jax.Array | None = None) -> OBBs:
    """Discretize a straight joint-space path into waypoints and emit OBBs."""
    t = jnp.linspace(0.0, 1.0, num_waypoints)[:, None]
    qs = (1.0 - t) * start[None, :] + t * goal[None, :]
    return arm_link_obbs(qs, base_pos=base_pos)


def random_obbs(key: jax.Array, n: int, scene_lo: float = -1.0,
                scene_hi: float = 1.0, min_half: float = 0.02,
                max_half: float = 0.25) -> OBBs:
    """Random OBBs for testing."""
    k1, k2, k3 = jax.random.split(key, 3)
    center = jax.random.uniform(k1, (n, 3), minval=scene_lo, maxval=scene_hi)
    half = jax.random.uniform(k2, (n, 3), minval=min_half, maxval=max_half)
    rot = rotation_from_euler(
        jax.random.uniform(k3, (n, 3), minval=-np.pi, maxval=np.pi))
    return OBBs(center=center, half=half, rot=rot)


def random_aabbs(key: jax.Array, n: int, scene_lo: float = -1.0,
                 scene_hi: float = 1.0, min_half: float = 0.02,
                 max_half: float = 0.25) -> AABBs:
    k1, k2 = jax.random.split(key)
    center = jax.random.uniform(k1, (n, 3), minval=scene_lo, maxval=scene_hi)
    half = jax.random.uniform(k2, (n, 3), minval=min_half, maxval=max_half)
    return AABBs(center=center, half=half)


def obb_corners(obbs: OBBs) -> jax.Array:
    """All 8 world-space corners of each OBB -> (M, 8, 3)."""
    signs = jnp.asarray(
        [[sx, sy, sz] for sx in (-1, 1) for sy in (-1, 1) for sz in (-1, 1)],
        jnp.float32)                                           # (8, 3)
    local = signs[None, :, :] * obbs.half[:, None, :]          # (M, 8, 3)
    return obbs.center[:, None, :] + jnp.einsum("mij,mkj->mki", obbs.rot, local)
