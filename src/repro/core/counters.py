"""Work model for the collision engine.

The paper evaluates RoboCore in a cycle-level simulator; on CPU we cannot
measure TPU cycles, so every engine variant reports *architecture-neutral work
counters* next to wall clock: axis tests executed (what a conditional-return
machine runs) vs decoded (what predication still pays for), sphere tests,
nodes traversed per level, exit-code histogram, modeled bytes moved
(fused VMEM-resident kernel vs unfused HBM-materialized stages), and the
Mochi-style shader-handoff overhead.

Bytes model (f32):
  OBB record 60 B, AABB 24 B, staged intermediates (t,R,absR,halves) 108 B,
  margins 15*4 B, result 4 B.
  unfused test  = 84 (boxes) + 2*108 (terms round trip) + 2*60 (margins) + 4
                = 424 B
  fused test    = 84 + 8 (result+exit code)              = 92 B
  shader handoff (Mochi) = 128 B per reported hit.

Fused traversal step (kernels/traverse, ``mode="wavefront_fused"``): the
whole level is one kernel, so per live (query, node) pair per level the
HBM-resident traffic reduces to frontier-in / frontier-out:
  frontier triple in  (q_idx, Morton code, CSR node index)   = 12 B
  node metadata gather (full flag, child_start, child_mask)  = 12 B
  packed verdict word out (collide | is_term | exit_code)    =  4 B
  compacted next-frontier triple out (amortized, <= 1 slot
  per surviving pair per level)                              = 12 B
  fused step                                                 = 40 B
The query OBB table streams HBM->VMEM once per level and is amortized
across the whole frontier, so it does not appear in the per-pair cost —
exactly the paper's "intermediates never leave the unit" discipline.  The
unfused device arm instead materializes ~5 capacity-sized arrays per level
(4-field SactResult, searchsorted probe vectors, 8x-expanded candidate
codes, compaction scratch), which the 424 B/test figure models.

Persistent megakernel (kernels/persist, ``mode="wavefront_persistent"``):
the WHOLE traversal is one kernel and the frontier lives in VMEM for its
entire life, so HBM-resident frontier traffic collapses from
40 B/pair/level to a per-QUERY cost paid once:
  seed (query, root) pair in                                 = 12 B
  packed verdict word out                                    =  4 B
  per-query cost                                             = 16 B
plus spill traffic only when a tile's frontier overflows VMEM and pairs
take the HBM spill ring (out + replay back in, 12 B each way):
  per spilled pair                                           = 24 B
Under the RESIDENT metadata layout the node-metadata and OBB tables
stream HBM->VMEM once per *kernel* (not per level), amortized across
every pair of every level — the closest TPU analogue of the paper's
conditional returns never leaving the core.  Under the STREAMED layout
(scenes past the VMEM residency budget, DESIGN.md §3) the metadata table
stays in HBM and each query tile double-buffers per-level row windows
instead; that traffic is explicit, not amortized, and priced at the
metadata row FORMAT's packed width (repro.core.quantize):
  per fetched fp32 row ([code, full, start, mask] int32)     = 16 B
  per fetched bf16 row (topo word + 10-bit fixed-point xyz)  =  8 B
  per fetched u8 row (single topo+octant word)               =  4 B
``Counters.meta_rows_streamed`` counts the rows the window schedule
fetched (level extents rounded up to whole DMA chunks, once per tile per
level the tile's frontier visits; 0 under the resident layout) — the row
COUNT is format-independent, so compression divides the streamed bytes by
exactly 2x/4x.  ``BYTES_META_STREAM`` / ``BYTES_META_STREAM_BF16`` /
``BYTES_META_STREAM_U8`` price the rows, and the product lands in
``Counters.meta_bytes_streamed``.

Payload lanes (swept-edge / first-hit plans, see ``repro.engine.plan``):
a grouped plan carries extra int32 lanes per query slot — the owner lane
(verdict-group id) and/or the payload lane (sub-interval rank) — that the
traversal gathers per frontier pair and folds into the per-group ``best``
with a min.  Each carried lane is modeled as ``BYTES_PAYLOAD_LANE`` extra
bytes per pair per level for the per-level arms, and per seed for the
persistent megakernel (the lanes ride the seed in and the best word out
replaces the boolean verdict word at equal width).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

BYTES_UNFUSED_TEST = 424
BYTES_FUSED_TEST = 92
BYTES_FUSED_STEP = 40
BYTES_PERSIST_QUERY = 16
BYTES_PERSIST_SPILL = 24
BYTES_META_STREAM = 16
BYTES_META_STREAM_BF16 = 8
BYTES_META_STREAM_U8 = 4
BYTES_PAYLOAD_LANE = 4
BYTES_SHADER_HANDOFF = 128
NUM_EXIT_CODES = 18


@dataclasses.dataclass
class Counters:
    """Aggregate work counters for one engine invocation."""

    num_queries: int = 0
    nodes_traversed: int = 0            # (query, node) pairs tested
    nodes_per_level: List[int] = dataclasses.field(default_factory=list)
    leaf_tests: int = 0                 # tests against terminal (leaf/full) nodes
    axis_tests_executed: int = 0        # conditional-return work model
    axis_tests_decoded: int = 0         # predication / no-exit work model
    sphere_tests: int = 0
    exit_histogram: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(NUM_EXIT_CODES, np.int64))
    shader_invocations: int = 0
    bytes_moved: int = 0
    frontier_overflow: int = 0          # entries dropped at capacity (should be 0)
    escalations: int = 0                # overflow replays before a clean run
    meta_rows_streamed: int = 0         # HBM metadata rows DMA'd (streamed layout)
    meta_bytes_streamed: int = 0        # rows x the format's packed row width
    pad_queries: int = 0                # dead pool slots added by sharding /
    #                                     batch coalescing (zero work each —
    #                                     the live-prefix num_valid lane masks
    #                                     them — but they occupy pool width)
    ref_arm_fallbacks: int = 0          # persistent-mode plans the executor
    #                                     routed to the jnp ref arm instead of
    #                                     the Pallas kernel (capability gap,
    #                                     e.g. an owner group past MAX_TILE_BQ;
    #                                     each is also logged with the plan
    #                                     shape — MUST stay 0 in the kernel
    #                                     figure benches)
    # Service reliability counters (DESIGN.md §7): accumulated by the
    # RequestBatcher, reported in the fig_serve SLO rows.
    rejected: int = 0                   # shed at admission (malformed plan,
    #                                     full queue, or submit after close)
    retried: int = 0                    # transient-failure launch retries
    deadline_missed: int = 0            # failed pre-launch: deadline unmeetable
    launch_splits: int = 0              # bisect-retry splits isolating a
    #                                     poisoned request from co-riders
    worker_restarts: int = 0            # watchdog-detected worker deaths
    reshards: int = 0                   # device-loss recoveries: sharded
    #                                     launches re-sharded over the
    #                                     surviving device set and relaunched
    shards_lost: int = 0                # shard devices dropped from the
    #                                     collision mesh by those recoveries
    shard_rescales: int = 0             # elastic-width changes the batcher
    #                                     applied between launches (queue
    #                                     depth / p99 drifted past the SLO)
    degraded_launches: int = 0          # launches served in declared
    #                                     degraded mode (halved pad bucket,
    #                                     capped max_depth) instead of shed
    wall_time_s: float = 0.0

    def merge_exit_codes(self, codes: np.ndarray, valid: np.ndarray) -> None:
        hist = np.bincount(codes[valid].astype(np.int64),
                           minlength=NUM_EXIT_CODES)
        self.exit_histogram[:len(hist)] += hist

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["exit_histogram"] = self.exit_histogram.tolist()
        return d

    def merge(self, other: "Counters") -> None:
        """Accumulate another invocation's work into this one (batched
        front-end; wall clock is owned by the caller and left untouched)."""
        self.num_queries += other.num_queries
        self.nodes_traversed += other.nodes_traversed
        self.leaf_tests += other.leaf_tests
        self.axis_tests_executed += other.axis_tests_executed
        self.axis_tests_decoded += other.axis_tests_decoded
        self.sphere_tests += other.sphere_tests
        self.shader_invocations += other.shader_invocations
        self.bytes_moved += other.bytes_moved
        self.frontier_overflow += other.frontier_overflow
        self.escalations += other.escalations
        self.meta_rows_streamed += other.meta_rows_streamed
        self.meta_bytes_streamed += other.meta_bytes_streamed
        self.pad_queries += other.pad_queries
        self.ref_arm_fallbacks += other.ref_arm_fallbacks
        self.rejected += other.rejected
        self.retried += other.retried
        self.deadline_missed += other.deadline_missed
        self.launch_splits += other.launch_splits
        self.worker_restarts += other.worker_restarts
        self.reshards += other.reshards
        self.shards_lost += other.shards_lost
        self.shard_rescales += other.shard_rescales
        self.degraded_launches += other.degraded_launches
        self.exit_histogram += other.exit_histogram
        a, b = self.nodes_per_level, other.nodes_per_level
        self.nodes_per_level = [
            (a[i] if i < len(a) else 0) + (b[i] if i < len(b) else 0)
            for i in range(max(len(a), len(b)))]

    def early_exit_fraction(self, half: int = 7) -> float:
        """Fraction of tests that terminate within ``half`` axis tests.

        Paper §I: "around 60% of collision queries can be terminated early
        after less than half of the total tests".
        """
        total = int(self.exit_histogram.sum())
        if total == 0:
            return 0.0
        # sphere exits (codes 0,1) + axis exits with index < half
        early = int(self.exit_histogram[0] + self.exit_histogram[1]
                    + self.exit_histogram[2:2 + half].sum())
        return early / total
