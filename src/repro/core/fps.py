"""Point sampling for PointNet++ set abstraction.

Furthest-point sampling (FPS) is 38.6% of MpiNet inference in the paper's
profile (Fig. 9); the paper's counter-proposal is *random* sampling, which is
5.5% at a small success-rate cost that the explicit collision-detection gate
recovers.  Both are provided; the FPS distance-update inner loop is also
implemented as a Pallas kernel in :mod:`repro.kernels.fps`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("m",))
def farthest_point_sampling(points: jax.Array, m: int,
                            first: int | jax.Array = 0) -> jax.Array:
    """Iterative FPS: returns (m,) int32 indices into points (N, 3)."""
    N = points.shape[0]
    first = jnp.asarray(first, jnp.int32)

    def body(i, carry):
        dist, idx = carry
        latest = points[idx[i - 1]]
        d = jnp.sum(jnp.square(points - latest[None, :]), -1)
        dist = jnp.minimum(dist, d)
        idx = idx.at[i].set(jnp.argmax(dist).astype(jnp.int32))
        return dist, idx

    dist0 = jnp.full((N,), jnp.inf, points.dtype)
    idx0 = jnp.zeros((m,), jnp.int32).at[0].set(first)
    _, idx = jax.lax.fori_loop(1, m, body, (dist0, idx0))
    return idx


def random_sampling(key: jax.Array, n_points: int, m: int) -> jax.Array:
    """Uniform sampling without replacement: (m,) int32 indices."""
    return jax.random.choice(key, n_points, (m,), replace=False).astype(
        jnp.int32)


def sampling_spread(points: jax.Array, idx: jax.Array) -> jax.Array:
    """Quality metric: mean distance from every point to its nearest sample.

    Lower = better coverage.  FPS should beat random sampling on this; used
    by tests and the Fig. 9 benchmark.
    """
    sel = points[idx]                                     # (m, 3)
    d2 = jnp.sum(jnp.square(points[:, None, :] - sel[None, :, :]), -1)
    return jnp.mean(jnp.sqrt(jnp.min(d2, axis=-1)))
