"""Compatibility shim over the plan/execute engine split.

The batched wavefront collision engine that used to live here — mode
dispatch, the device-resident ``lax.while_loop`` traversals, the traversal
jit cache, the escalate-on-overflow capacity policy, and counter assembly
— now lives in :mod:`repro.engine`:

* :mod:`repro.engine.plan` lowers every front-end batch shape (single
  query set, (B, M) batch, ragged multi-scene, trajectory, swept edge)
  into one canonical flat pair pool (query slot, scene id, CSR node,
  payload lanes) plus an un-flattening recipe;
* :mod:`repro.engine.executor` executes any plan under any
  ``EngineConfig.mode`` (DESIGN.md §2) — the four hand-routed
  ``_query_*`` / ``query_batched_scenes`` code paths of the pre-split
  engine collapsed into one executor consuming plans.

This module re-exports the public names so existing imports
(``from repro.core.wavefront import CollisionEngine, EngineConfig, ...``)
keep working; new code should import from :mod:`repro.engine` directly.
Verdicts and work counters of every pre-split mode are bitwise-identical
through the refactor (CI-enforced).
"""
from repro.engine import executor as _executor
from repro.engine.executor import (CSR_MODES, DEVICE_MODES, MODES,
                                   CollisionEngine, EngineConfig,
                                   frontier_capacity_bound,
                                   query_batched_scenes,
                                   traversal_cache_info)

# Private aliases kept for callers that reached into the old module.
_escalate = _executor._escalate
_initial_capacity = _executor._initial_capacity
_stats_to_counters = _executor._stats_to_counters
_traversal_fn = _executor._traversal_fn
_traverse = _executor._traverse
_traverse_fused = _executor._traverse_fused

__all__ = [
    "CSR_MODES", "CollisionEngine", "DEVICE_MODES", "EngineConfig", "MODES",
    "frontier_capacity_bound", "query_batched_scenes", "traversal_cache_info",
]
