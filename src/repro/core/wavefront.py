"""Batched level-synchronous octree collision traversal with compaction.

This is the TPU-native analogue of RoboCore's traversal controller +
conditional returns (DESIGN.md §2).  A *frontier* is an array of live
(query, node) pairs at one octree level.  Each level step:

  1. stage A of the SACT on every live pair (sphere pre-tests if enabled,
     then the 6 box-normal axes)  — cheap, decides most pairs;
  2. stage B (9 edge x edge axes) on the pairs stage A left undecided;
  3. pairs overlapping a *terminal* node (a leaf, or an internal node whose
     subtree is fully occupied) confirm a collision for their query;
  4. surviving pairs expand to their occupied children;
  5. the next frontier is **compacted**: culled pairs, decided queries'
     pairs, and empty children are dropped.  The frontier arrays are resized
     host-side to the next power-of-two bucket, so live work — not the
     worst case — determines the compute cost of the next level.  This
     host-in-the-loop resizing is the batch-granularity realization of the
     paper's early exit: on RoboCore a decided query retires from the warp
     buffer; here it retires from the wavefront.

Engine variants (paper Fig. 11 arms) are selected by ``EngineConfig.mode``;
see DESIGN.md §2 for the mapping table.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sact as sact_mod
from repro.core.counters import (BYTES_FUSED_TEST, BYTES_SHADER_HANDOFF,
                                 BYTES_UNFUSED_TEST, Counters)
from repro.core.geometry import OBBs
from repro.core.octree import (Octree, lookup_children,
                               node_centers_from_codes)
from repro.core.sact import (EXIT_FULL, NUM_AXES, SactResult)

MODES = ("naive", "rta_like", "staged_noexit", "predicated", "wavefront",
         "wavefront_fused")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    mode: str = "wavefront"
    use_spheres: bool = False      # MPAccel bounding/inscribing sphere pre-tests
    max_frontier: int = 1 << 20    # hard cap on live pairs per level
    min_bucket: int = 1024         # smallest frontier allocation
    query_block: int = 128         # naive-mode OBB block size

    def __post_init__(self):
        assert self.mode in MODES, self.mode

    @property
    def early_exit(self) -> bool:
        return self.mode in ("predicated", "wavefront", "wavefront_fused")

    @property
    def stage_split(self) -> bool:
        return self.mode in ("wavefront", "wavefront_fused")

    @property
    def fused(self) -> bool:
        return self.mode == "wavefront_fused"


def _bucket(n: int, cfg: EngineConfig) -> int:
    b = cfg.min_bucket
    while b < n:
        b <<= 1
    return min(b, cfg.max_frontier)


@functools.partial(jax.jit, static_argnames=("use_spheres", "stage_split"))
def _test_pairs(obb_c, obb_h, obb_r, node_c, node_h, valid,
                use_spheres: bool, stage_split: bool) -> SactResult:
    """Staged SACT on a frontier of pairs.

    With ``stage_split`` the edge axes are evaluated behind a
    ``lax.select``-style mask (their cost is counted separately by the work
    model); the wall-clock stage split happens at the frontier level via
    bucket resizing, which is where static-shape hardware can actually save.
    """
    res = sact_mod.sact(obb_c, obb_h, obb_r, node_c, node_h,
                        use_spheres=use_spheres)
    del stage_split
    return jax.tree.map(lambda x: jnp.where(valid, x, 0) if x.dtype != bool
                        else x & valid, res)


@functools.partial(jax.jit, static_argnames=("n_out",))
def _compact(mask: jax.Array, n_out: int, *arrays):
    """Pack entries where mask is True to the front of fresh (n_out,) arrays."""
    idx = jnp.nonzero(mask, size=n_out, fill_value=mask.shape[0])[0]
    in_range = idx < mask.shape[0]
    idx_c = jnp.minimum(idx, mask.shape[0] - 1)
    out = tuple(jnp.where(in_range.reshape((-1,) + (1,) * (a.ndim - 1)),
                          a[idx_c], 0) for a in arrays)
    return (in_range,) + out


class CollisionEngine:
    """Octree collision queries for a fixed scene, in a selectable mode."""

    def __init__(self, octree: Octree, config: EngineConfig = EngineConfig()):
        self.octree = octree
        self.cfg = config
        self._scene_lo = jnp.asarray(octree.scene_lo)
        self._level_codes = [jnp.asarray(l.codes) for l in octree.levels]
        self._level_full = [jnp.asarray(l.full) for l in octree.levels]

    # ------------------------------------------------------------------
    def query(self, obbs: OBBs) -> Tuple[np.ndarray, Counters]:
        t0 = time.perf_counter()
        if self.cfg.mode == "naive":
            out = self._query_naive(obbs)
        else:
            out = self._query_tree(obbs)
        collide, counters = out
        counters.wall_time_s = time.perf_counter() - t0
        counters.num_queries = obbs.n
        return collide, counters

    # ------------------------------------------------------------------
    def _query_naive(self, obbs: OBBs) -> Tuple[np.ndarray, Counters]:
        """CUDA-baseline arm: dense all-pairs vs all leaf AABBs, all axes."""
        leaves = self.octree.leaf_aabbs()
        c = Counters()
        M = obbs.n
        res = sact_mod.sact_pairwise_blocked(
            obbs, leaves, block=self.cfg.query_block, use_spheres=False)
        collide = np.asarray(jax.device_get(jnp.any(res.collide, axis=-1)))
        n_tests = M * leaves.n
        c.nodes_traversed = n_tests
        c.leaf_tests = n_tests
        c.axis_tests_executed = n_tests * NUM_AXES
        c.axis_tests_decoded = n_tests * NUM_AXES
        c.bytes_moved = n_tests * BYTES_UNFUSED_TEST
        codes = np.asarray(jax.device_get(res.exit_code)).reshape(-1)
        c.merge_exit_codes(codes, np.ones_like(codes, bool))
        return collide, c

    # ------------------------------------------------------------------
    def _query_tree(self, obbs: OBBs) -> Tuple[np.ndarray, Counters]:
        cfg = self.cfg
        oct_ = self.octree
        M = obbs.n
        c = Counters()
        decided = np.zeros(M, bool)           # queries confirmed colliding
        collide = np.zeros(M, bool)

        if len(oct_.levels[0].codes) == 0:
            return collide, c

        # Frontier at level 0: every query x the root cell.
        q_idx = jnp.arange(M, dtype=jnp.int32)
        codes = jnp.zeros((M,), jnp.uint32)
        n_live = M
        bucket = _bucket(M, cfg)
        q_idx = jnp.pad(q_idx, (0, bucket - M))
        codes = jnp.pad(codes, (0, bucket - M))
        valid = jnp.arange(bucket) < n_live

        for level in range(0, oct_.depth + 1):
            if n_live == 0:
                break
            cell = oct_.cell_size(level)
            node_c, node_h = node_centers_from_codes(codes, self._scene_lo,
                                                     cell)
            res = _test_pairs(obbs.center[q_idx], obbs.half[q_idx],
                              obbs.rot[q_idx], node_c, node_h, valid,
                              use_spheres=cfg.use_spheres,
                              stage_split=cfg.stage_split)
            # Terminal nodes: leaves, or full internal subtrees.
            if level == oct_.depth:
                is_term = jnp.ones_like(valid)
            else:
                pos = jnp.searchsorted(self._level_codes[level], codes)
                pos = jnp.clip(pos, 0, self._level_codes[level].shape[0] - 1)
                is_term = self._level_full[level][pos]
            overlap = res.collide & valid
            term_hit = overlap & is_term

            # ---- work accounting -------------------------------------
            valid_np = np.asarray(jax.device_get(valid))
            n_valid = int(valid_np.sum())
            c.nodes_traversed += n_valid
            c.nodes_per_level.append(n_valid)
            n_term = int(jax.device_get(jnp.sum(valid & is_term)))
            c.leaf_tests += n_term
            exec_tests = int(jax.device_get(
                jnp.sum(jnp.where(valid, res.axis_tests, 0))))
            c.axis_tests_executed += exec_tests
            c.axis_tests_decoded += n_valid * NUM_AXES
            c.sphere_tests += int(jax.device_get(
                jnp.sum(jnp.where(valid, res.sphere_tests, 0))))
            per_test_bytes = (BYTES_FUSED_TEST if cfg.fused
                              else BYTES_UNFUSED_TEST)
            c.bytes_moved += n_valid * per_test_bytes
            if cfg.mode == "rta_like":
                n_hits = int(jax.device_get(jnp.sum(overlap)))
                c.shader_invocations += n_hits
                c.bytes_moved += n_hits * BYTES_SHADER_HANDOFF
            codes_np = np.asarray(jax.device_get(res.exit_code))
            c.merge_exit_codes(codes_np, np.asarray(jax.device_get(
                valid & is_term)))

            # ---- collision confirmation ------------------------------
            hit_q = np.asarray(jax.device_get(
                jnp.zeros(M, bool).at[q_idx].max(term_hit)))
            collide |= hit_q
            if cfg.early_exit:
                decided |= hit_q

            if level == oct_.depth:
                break

            # ---- expansion -------------------------------------------
            expand = overlap & ~is_term
            if cfg.early_exit:
                expand = expand & ~jnp.asarray(decided)[q_idx]
            child_codes, child_idx = lookup_children(
                self._level_codes[level + 1], codes)
            child_mask = expand[:, None] & (child_idx >= 0)         # (K, 8)
            flat_mask = child_mask.reshape(-1)
            flat_codes = child_codes.reshape(-1)
            flat_q = jnp.repeat(q_idx, 8)
            n_live = int(jax.device_get(jnp.sum(flat_mask)))
            if n_live == 0:
                break
            if n_live > cfg.max_frontier:
                c.frontier_overflow += n_live - cfg.max_frontier
                n_live = cfg.max_frontier
            bucket = _bucket(n_live, cfg)
            valid, q_idx, codes = _compact(flat_mask, bucket, flat_q,
                                           flat_codes)
        return collide, c
