"""Monte Carlo Localization (RoWild DeliBot) with dynamic engine switching.

The paper (§V-3, §VI-C) accelerates the MCL ray-casting kernel — 74% of
DeliBot's latency — on RoboCore, and *dynamically switches* between the
RoboCore and CUDA-core implementations per filter iteration, keyed on the
average number of cells traversed per ray in the previous iteration: early in
the trace particles are spread out, rays are long, and the traversal engine
wins; once converged, rays terminate quickly and its launch overhead loses to
the plain kernel.

TPU adaptation: the 2-D occupancy-grid DDA becomes
  * ``dense``      — fixed-trip-count masked marching (every ray pays
                     max_steps lanes; the "CUDA cores" arm), and
  * ``compacted``  — chunked marching with host-side wavefront compaction
                     every ``chunk`` steps (finished rays retire; the
                     "RoboCore" arm, which pays a per-chunk relaunch cost).
The switch heuristic is the paper's, verbatim: mean cells traversed in the
previous iteration vs a threshold.

When a 3-D scene octree is available, the filter can additionally gate
particles through the batched wavefront engine: every particle's robot
footprint OBB is collision-checked against the scene in ONE compiled call
(a flat P-query plan on ``CollisionEngine.query``), and particles
embedded in obstacles are suppressed before resampling.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import OBBs


@dataclasses.dataclass(frozen=True)
class OccupancyGrid:
    occ: jax.Array        # (H, W) bool
    cell: float           # metres per cell
    origin: Tuple[float, float] = (0.0, 0.0)

    @property
    def shape(self):
        return self.occ.shape


def make_corridor_world(key, size: int = 256, n_boxes: int = 24,
                        cell: float = 0.05) -> OccupancyGrid:
    """Synthetic indoor floor plan: border walls + random box obstacles."""
    occ = np.zeros((size, size), bool)
    occ[0, :] = occ[-1, :] = occ[:, 0] = occ[:, -1] = True
    rs = np.random.RandomState(int(jax.device_get(
        jax.random.randint(key, (), 0, 2**31 - 1))))
    for _ in range(n_boxes):
        h, w = rs.randint(4, 20, 2)
        r, c = rs.randint(1, size - 20, 2)
        occ[r:r + h, c:c + w] = True
    return OccupancyGrid(occ=jnp.asarray(occ), cell=cell)


def _march_step(grid: OccupancyGrid, pos, dirv, dist, active, max_range):
    """One DDA step for all rays; step length = one cell."""
    step = grid.cell
    npos = pos + dirv * step
    ij = jnp.floor((npos - jnp.asarray(grid.origin)) / grid.cell).astype(
        jnp.int32)
    H, W = grid.shape
    inb = ((ij[:, 0] >= 0) & (ij[:, 0] < H) & (ij[:, 1] >= 0) & (ij[:, 1] < W))
    occ = jnp.where(inb, grid.occ[jnp.clip(ij[:, 0], 0, H - 1),
                                  jnp.clip(ij[:, 1], 0, W - 1)], True)
    ndist = dist + step
    hit = active & (occ | (ndist >= max_range))
    pos = jnp.where(active[:, None], npos, pos)
    dist = jnp.where(active, ndist, dist)
    active = active & ~hit
    return pos, dist, active


def ray_cast_dense(grid: OccupancyGrid, origins: jax.Array, angles: jax.Array,
                   max_range: float) -> Tuple[jax.Array, int]:
    """Fixed-trip masked marcher ("CUDA cores" arm).

    Returns (ranges (R,), cells_traversed_total).  Every lane pays
    ``max_steps`` iterations regardless of when it hits (SIMT-style waste).
    """
    R = origins.shape[0]
    dirv = jnp.stack([jnp.cos(angles), jnp.sin(angles)], -1)
    max_steps = int(np.ceil(max_range / grid.cell)) + 1

    def body(_, carry):
        pos, dist, active = carry
        return _march_step(grid, pos, dirv, dist, active, max_range)

    pos, dist, active = jax.lax.fori_loop(
        0, max_steps, body,
        (origins, jnp.zeros((R,)), jnp.ones((R,), bool)))
    return dist, R * max_steps


def ray_cast_compacted(grid: OccupancyGrid, origins: jax.Array,
                       angles: jax.Array, max_range: float,
                       chunk: int = 16) -> Tuple[jax.Array, int]:
    """Chunked marcher with wavefront compaction ("RoboCore" arm).

    Marches ``chunk`` steps, then retires finished rays host-side and
    re-buckets the live set; cells traversed counts only live lanes.
    """
    R = origins.shape[0]
    dirv = jnp.stack([jnp.cos(angles), jnp.sin(angles)], -1)
    max_steps = int(np.ceil(max_range / grid.cell)) + 1
    ranges = np.zeros((R,), np.float32)
    idx = jnp.arange(R, dtype=jnp.int32)
    pos, dist = origins, jnp.zeros((R,))
    cells = 0

    def chunk_fn(pos, dirv, dist, active, n_steps):
        def body(_, carry):
            p, d, a = carry
            return _march_step(grid, p, dirv, d, a, max_range)
        return jax.lax.fori_loop(0, n_steps, body, (pos, dist, active))

    active = jnp.ones((R,), bool)
    steps_done = 0
    while steps_done < max_steps:
        n = min(chunk, max_steps - steps_done)
        cells += int(pos.shape[0]) * n
        pos, dist, active = chunk_fn(pos, dirv, dist, active, n)
        steps_done += n
        live = int(jax.device_get(jnp.sum(active)))
        if live == 0:
            ranges_idx = np.asarray(jax.device_get(idx))
            ranges[ranges_idx] = np.asarray(jax.device_get(dist))
            return jnp.asarray(ranges), cells
        if live < pos.shape[0] // 2:          # compact when half retired
            done = ~active
            didx = np.asarray(jax.device_get(jnp.nonzero(done,
                size=int(pos.shape[0]) - live)[0]))
            ranges[np.asarray(jax.device_get(idx[didx]))] = np.asarray(
                jax.device_get(dist[didx]))
            keep = jnp.nonzero(active, size=live)[0]
            pos, dist, idx, dirv = pos[keep], dist[keep], idx[keep], dirv[keep]
            active = jnp.ones((live,), bool)
    ranges[np.asarray(jax.device_get(idx))] = np.asarray(jax.device_get(dist))
    return jnp.asarray(ranges), cells


def particle_collision_mask(engine, particles: jax.Array,
                            footprint_half=(0.25, 0.25, 0.4),
                            z_center: float = 0.4) -> np.ndarray:
    """Per-particle footprint collision against a 3-D scene octree.

    ``particles`` is (P, 3) x, y, theta; each particle becomes one yawed
    footprint OBB and the whole population is checked as one flat P-query
    plan in a single compiled call.  Returns (P,) bool (True = particle in
    collision).
    """
    P = particles.shape[0]
    x, y, th = particles[:, 0], particles[:, 1], particles[:, 2]
    z = jnp.zeros_like(x)
    c, s = jnp.cos(th), jnp.sin(th)
    one = jnp.ones_like(x)
    rot = jnp.stack([
        jnp.stack([c, -s, z], -1),
        jnp.stack([s, c, z], -1),
        jnp.stack([z, z, one], -1)], -2)                    # (P, 3, 3) yaw
    center = jnp.stack([x, y, jnp.full_like(x, z_center)], -1)
    half = jnp.broadcast_to(jnp.asarray(footprint_half, jnp.float32), (P, 3))
    collide, _ = engine.query(OBBs(center=center, half=half, rot=rot))
    return collide


@dataclasses.dataclass
class MCLState:
    particles: jax.Array   # (P, 3) x, y, theta
    weights: jax.Array     # (P,)


def init_particles(key, grid: OccupancyGrid, n: int) -> MCLState:
    H, W = grid.shape
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.uniform(k1, (n,), minval=grid.cell,
                           maxval=(H - 1) * grid.cell)
    y = jax.random.uniform(k2, (n,), minval=grid.cell,
                           maxval=(W - 1) * grid.cell)
    th = jax.random.uniform(k3, (n,), minval=-np.pi, maxval=np.pi)
    return MCLState(particles=jnp.stack([x, y, th], -1),
                    weights=jnp.full((n,), 1.0 / n))


def mcl_step(key, state: MCLState, grid: OccupancyGrid, observed: jax.Array,
             scan_angles: jax.Array, motion: jax.Array, engine: str,
             max_range: float = 6.0, sigma: float = 0.25,
             collision_engine=None,
             footprint_half=(0.25, 0.25, 0.4),
             ) -> Tuple[MCLState, dict]:
    """One predict-update-resample iteration; returns new state + stats.

    With ``collision_engine`` (a device-mode ``CollisionEngine`` over the
    3-D scene), particles whose footprint OBB intersects the scene are
    suppressed before resampling — one batched wavefront call per iteration.
    """
    P = state.particles.shape[0]
    A = scan_angles.shape[0]
    k1, k2 = jax.random.split(key)
    # Predict: apply motion + noise.
    noise = jax.random.normal(k1, (P, 3)) * jnp.asarray([0.02, 0.02, 0.02])
    parts = state.particles + motion[None, :] + noise
    # Measurement: cast A rays per particle.
    origins = jnp.repeat(parts[:, :2], A, axis=0)
    angles = (parts[:, 2:3] + scan_angles[None, :]).reshape(-1)
    t0 = time.perf_counter()
    if engine == "dense":
        ranges, cells = ray_cast_dense(grid, origins, angles, max_range)
    else:
        ranges, cells = ray_cast_compacted(grid, origins, angles, max_range)
    ranges.block_until_ready()
    dt = time.perf_counter() - t0
    sim = ranges.reshape(P, A)
    err = jnp.mean(jnp.square(sim - observed[None, :]), -1)
    logw = -err / (2 * sigma * sigma)
    n_colliding = 0
    if collision_engine is not None:
        colliding = jnp.asarray(particle_collision_mask(
            collision_engine, parts, footprint_half=footprint_half))
        n_colliding = int(jax.device_get(jnp.sum(colliding)))
        if n_colliding < P:            # keep the filter alive if all collide
            logw = jnp.where(colliding, -1e9, logw)
    w = jax.nn.softmax(logw)
    # Systematic resampling.
    cum = jnp.cumsum(w)
    u = (jax.random.uniform(k2, ()) + jnp.arange(P)) / P
    sel = jnp.searchsorted(cum, u)
    new_parts = parts[jnp.clip(sel, 0, P - 1)]
    stats = {"cells": int(cells), "rays": int(P * A),
             "cells_per_ray": float(cells) / float(P * A),
             "time_s": dt, "engine": engine,
             "colliding_particles": n_colliding}
    return MCLState(particles=new_parts,
                    weights=jnp.full((P,), 1.0 / P)), stats


def choose_engine(prev_cells_per_ray: float, threshold: float,
                  ) -> str:
    """Paper §VI-C: switch on mean traversal length of previous iteration."""
    return "compacted" if prev_cells_per_ray >= threshold else "dense"
