"""Separating-Axis Collision Test (SACT) between OBBs and AABBs.

Implements the staged test of RoboGPU Fig. 6:

  stage 0  bounding-sphere test      -> early NO-collision cull
  stage 1  inscribing-sphere test    -> early COLLISION confirm
  (preprocessing: t = relative translation, R = OBB rotation, AbsR)
  stages 2..7   6 box-normal axes    -> early NO-collision per axis
  stages 8..16  9 edge x edge axes   -> early NO-collision per axis
  stage 17 no separating axis        -> COLLISION

On a TPU there is no per-lane early exit: every variant below evaluates
vectorized over (pairs,) lanes.  The *work model* (``exit_code`` /
``axis_tests``) records what a conditional-return machine (the paper's
RoboCore) would have executed; actual time savings are realized one level up,
in :mod:`repro.core.wavefront`, by compacting decided pairs out of the batch
between stages — the batch-granularity analogue of conditional returns.

Axis formulas follow Ericson, *Real-Time Collision Detection* §4.4.1, with
box A = AABB (identity axes) and box B = OBB.  ``R[i, j]`` = component ``i``
of OBB axis ``j`` in world space, i.e. exactly the OBB rotation matrix whose
columns are its local axes.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.geometry import AABBs, OBBs, point_aabb_sq_distance

_EPS = 1e-6

# Exit-code layout (kept stable; benchmarks and tests rely on it).
EXIT_BSPHERE = 0          # bounding-sphere cull           -> no collision
EXIT_ISPHERE = 1          # inscribing-sphere confirm      -> collision
EXIT_AXIS0 = 2            # separating axis k found        -> no collision
# codes 2..7   = box-normal axes 0..5
# codes 8..16  = edge x edge axes 0..8
EXIT_FULL = 17            # all 15 axes overlap            -> collision
NUM_AXES = 15
NUM_BOX_NORMAL = 6
NUM_EDGE = 9

#: Payload-lane "no hit" sentinel.  Grouped traversals (see
#: :mod:`repro.engine.plan`) keep one int32 ``best`` cell per verdict group
#: instead of a boolean per query: a terminal hit folds the pair's payload in
#: with a min, so ``best`` ends as the smallest payload that hit (the first
#: colliding sub-interval of a swept edge) and ``PAYLOAD_INF`` means the
#: group never hit.  Boolean verdicts are the ``payload == 0`` special case.
PAYLOAD_INF = 2**31 - 1


class PairTerms(NamedTuple):
    """Precomputed per-pair quantities shared by all axis tests."""

    t: jax.Array       # (..., 3)  OBB centre in AABB frame
    R: jax.Array       # (..., 3, 3)
    absR: jax.Array    # (..., 3, 3)  |R| + eps
    a_half: jax.Array  # (..., 3)  AABB half extents
    b_half: jax.Array  # (..., 3)  OBB half extents


def make_pair_terms(obb_center, obb_half, obb_rot, aabb_center, aabb_half
                    ) -> PairTerms:
    """Preprocessing stage.  All args broadcast against each other."""
    t = obb_center - aabb_center
    absR = jnp.abs(obb_rot) + _EPS
    return PairTerms(t=t, R=obb_rot, absR=absR, a_half=aabb_half,
                     b_half=obb_half)


def box_normal_margins(p: PairTerms) -> jax.Array:
    """Margins for the 6 box-normal axes -> (..., 6).

    margin = |t . L| - (r_a + r_b); positive => separating axis.
    Axes 0..2 are the AABB axes, 3..5 the OBB axes.
    """
    # L = A_i (AABB axes): |t[i]| vs a_half[i] + sum_j b_half[j] * absR[i, j]
    ra_a = p.a_half
    rb_a = jnp.einsum("...j,...ij->...i", p.b_half, p.absR)
    m_a = jnp.abs(p.t) - (ra_a + rb_a)                       # (..., 3)
    # L = B_j (OBB axes): |t . R[:, j]| vs sum_i a_half[i]*absR[i, j] + b_half[j]
    t_in_b = jnp.einsum("...i,...ij->...j", p.t, p.R)
    ra_b = jnp.einsum("...i,...ij->...j", p.a_half, p.absR)
    m_b = jnp.abs(t_in_b) - (ra_b + p.b_half)                # (..., 3)
    return jnp.concatenate([m_a, m_b], axis=-1)


def edge_margins(p: PairTerms) -> jax.Array:
    """Margins for the 9 edge x edge axes A_i x B_j -> (..., 9).

    Axis order: (i, j) row-major, i.e. axis k = A_{k//3} x B_{k%3}.
    """
    margins = []
    for i in range(3):
        i1, i2 = (i + 1) % 3, (i + 2) % 3
        for j in range(3):
            j1, j2 = (j + 1) % 3, (j + 2) % 3
            ra = (p.a_half[..., i1] * p.absR[..., i2, j]
                  + p.a_half[..., i2] * p.absR[..., i1, j])
            rb = (p.b_half[..., j1] * p.absR[..., i, j2]
                  + p.b_half[..., j2] * p.absR[..., i, j1])
            lhs = jnp.abs(p.t[..., i2] * p.R[..., i1, j]
                          - p.t[..., i1] * p.R[..., i2, j])
            margins.append(lhs - (ra + rb))
    return jnp.stack(margins, axis=-1)


def all_axis_margins(p: PairTerms) -> jax.Array:
    """All 15 axis margins, stage order -> (..., 15)."""
    return jnp.concatenate([box_normal_margins(p), edge_margins(p)], axis=-1)


def sphere_tests(obb_center, obb_half, aabb_center, aabb_half
                 ) -> Tuple[jax.Array, jax.Array]:
    """Bounding / inscribing sphere pre-tests (RoboGPU Fig. 6 stages 0-1).

    Returns (bsphere_miss, isphere_hit):
      bsphere_miss: the OBB's bounding sphere misses the AABB -> no collision.
      isphere_hit:  the OBB's inscribed sphere overlaps the AABB -> collision.
    """
    d2 = point_aabb_sq_distance(obb_center, aabb_center, aabb_half)
    r_out = jnp.linalg.norm(obb_half, axis=-1)
    r_in = jnp.min(obb_half, axis=-1)
    bsphere_miss = d2 > jnp.square(r_out)
    isphere_hit = d2 < jnp.square(r_in)
    return bsphere_miss, isphere_hit


class SactResult(NamedTuple):
    collide: jax.Array      # (...,) bool
    exit_code: jax.Array    # (...,) int32, see EXIT_* above
    axis_tests: jax.Array   # (...,) int32 axis tests a CR machine would run
    sphere_tests: jax.Array  # (...,) int32 sphere tests executed (0 or 2)


def axis_tests_from_exit(exit_code: jax.Array) -> jax.Array:
    """Recover the conditional-return axis-test count from an exit code.

    Sphere exits (codes 0/1) run no axis tests; a separating axis k (code
    2 + k) costs k + 1 tests; EXIT_FULL costs all 15.  This is the single
    source of truth shared by the jnp staged test and the Pallas kernels,
    which emit only (collide, exit_code) per pair.
    """
    code = exit_code.astype(jnp.int32)
    return jnp.where(code <= EXIT_ISPHERE, 0,
                     jnp.minimum(code - 1, NUM_AXES)).astype(jnp.int32)


def _staged_result(bsphere_miss, isphere_hit, margins, use_spheres: bool
                   ) -> SactResult:
    sep = margins > 0.0                                      # (..., 15)
    any_sep = jnp.any(sep, axis=-1)
    # First separating axis index (15 if none).
    first_sep = jnp.argmax(sep, axis=-1)
    first_sep = jnp.where(any_sep, first_sep, NUM_AXES)
    collide_sat = ~any_sep
    if use_spheres:
        collide = jnp.where(bsphere_miss, False,
                            jnp.where(isphere_hit, True, collide_sat))
        exit_code = jnp.where(
            bsphere_miss, EXIT_BSPHERE,
            jnp.where(isphere_hit, EXIT_ISPHERE,
                      jnp.where(any_sep, EXIT_AXIS0 + first_sep, EXIT_FULL)))
        n_sphere = jnp.full(exit_code.shape, 2, jnp.int32)
    else:
        collide = collide_sat
        exit_code = jnp.where(any_sep, EXIT_AXIS0 + first_sep, EXIT_FULL)
        n_sphere = jnp.zeros(exit_code.shape, jnp.int32)
    exit_code = exit_code.astype(jnp.int32)
    return SactResult(collide=collide,
                      exit_code=exit_code,
                      axis_tests=axis_tests_from_exit(exit_code),
                      sphere_tests=n_sphere)


def sact(obb_center, obb_half, obb_rot, aabb_center, aabb_half,
         use_spheres: bool = False) -> SactResult:
    """Elementwise staged SACT over broadcastable box batches."""
    p = make_pair_terms(obb_center, obb_half, obb_rot, aabb_center, aabb_half)
    margins = all_axis_margins(p)
    if use_spheres:
        bs, is_ = sphere_tests(obb_center, obb_half, aabb_center, aabb_half)
    else:
        shape = margins.shape[:-1]
        bs = jnp.zeros(shape, bool)
        is_ = jnp.zeros(shape, bool)
    return _staged_result(bs, is_, margins, use_spheres)


def payload_min_update(best, owner_lane, payload_lane, hit):
    """Fold a frontier's terminal hits into the per-group ``best`` lane.

    ``best`` is (G,) int32 (``PAYLOAD_INF`` = undecided); ``owner_lane`` /
    ``payload_lane`` are the frontier lanes' verdict-group ids and payloads;
    ``hit`` the terminal-hit mask.  Non-hit lanes contribute the sentinel, so
    the scatter-min is a no-op for them — the payload-lane generalization of
    ``collide.at[q_idx].max(term_hit)``.  Shared by the unfused / fused /
    persistent-ref traversal arms (the persistent megakernel re-derives the
    same min with a one-hot reduction; see kernels/persist/kernel.py).
    """
    return best.at[owner_lane].min(
        jnp.where(hit, payload_lane, jnp.int32(PAYLOAD_INF)))


def mask_frontier_result(res: SactResult, valid) -> SactResult:
    """Clear booleans / zero counters on invalid (padding) lanes."""
    return jax.tree.map(
        lambda x: x & valid if x.dtype == bool else jnp.where(valid, x, 0),
        res)


def sact_frontier(obb_center, obb_half, obb_rot, aabb_center, aabb_half,
                  valid, use_spheres: bool = False) -> SactResult:
    """Staged SACT over a frontier of gathered pairs with a validity mask.

    Shape-polymorphic over leading dims — the same code serves the host
    engine's (K,) frontier, the device engine's fixed-capacity buffer inside
    ``lax.while_loop``, and (B, K) batches under ``vmap``.  Invalid lanes are
    zeroed (counters) / cleared (booleans) so padding never contributes work
    or verdicts.
    """
    res = sact(obb_center, obb_half, obb_rot, aabb_center, aabb_half,
               use_spheres=use_spheres)
    return mask_frontier_result(res, valid)


def sact_frontier_staged(obb_center, obb_half, obb_rot, aabb_center,
                         aabb_half, valid, use_spheres: bool = False
                         ) -> SactResult:
    """Two-phase frontier SACT, bitwise-identical to :func:`sact_frontier`.

    Phase 1 runs the sphere pre-tests plus the 6 box-normal axes on every
    live pair; the 9 edge x edge margins (phase 2) are only computed — via
    ``lax.cond`` — when some valid pair survives phase 1 undecided.  This is
    the frontier-level analogue of the Pallas SACT kernel's tile-level
    conditional return: on typical scenes most deep-level frontiers decide
    entirely in phase 1, so the 9 costliest axis formulas are skipped for
    the whole batch.  (Under ``vmap`` the cond lowers to a select and both
    phases execute — correctness is unaffected; the persistent engine
    flattens batches into one frontier pool instead of vmapping partly for
    this reason.)  Served frontiers: the fused step's capacity-wide buffer
    (:mod:`repro.kernels.traverse`), and the persistent ref's live-prefix
    slices (:mod:`repro.kernels.persist`), where the skip decision is per
    processing width — finer than capacity-wide, coarser than per-tile.

    Exit codes and axis-test counts are untouched by the skip: phase-2
    margins only influence lanes that reach phase 2, and when the cond takes
    the skip branch no valid lane does.
    """
    p = make_pair_terms(obb_center, obb_half, obb_rot, aabb_center, aabb_half)
    m_box = box_normal_margins(p)                            # (..., 6)
    shape = m_box.shape[:-1]
    if use_spheres:
        bs, is_ = sphere_tests(obb_center, obb_half, aabb_center, aabb_half)
    else:
        bs = jnp.zeros(shape, bool)
        is_ = jnp.zeros(shape, bool)
    undecided = valid & ~bs & ~is_ & ~jnp.any(m_box > 0.0, axis=-1)

    def phase2():
        # Recompute the pair terms in-branch: the cond's operands stay the
        # raw (already-live) box arrays, so skipping phase 2 never forces
        # the (t, R, |R|) intermediates to materialize for the branch.
        p2 = make_pair_terms(obb_center, obb_half, obb_rot, aabb_center,
                             aabb_half)
        return edge_margins(p2)

    m_edge = jax.lax.cond(
        jnp.any(undecided), phase2,
        lambda: jnp.zeros(shape + (NUM_EDGE,), m_box.dtype))
    res = _staged_result(bs, is_,
                         jnp.concatenate([m_box, m_edge], axis=-1),
                         use_spheres)
    return mask_frontier_result(res, valid)


def sact_pairwise(obbs: OBBs, aabbs: AABBs, use_spheres: bool = False
                  ) -> SactResult:
    """Dense all-pairs staged SACT: (M,) OBBs x (N,) AABBs -> (M, N) results."""
    return sact(
        obbs.center[:, None, :], obbs.half[:, None, :], obbs.rot[:, None, :, :],
        aabbs.center[None, :, :], aabbs.half[None, :, :],
        use_spheres=use_spheres)


def sact_collide_only(obb_center, obb_half, obb_rot, aabb_center, aabb_half
                      ) -> jax.Array:
    """Cheapest full test: just the boolean, no work model (naive baseline)."""
    p = make_pair_terms(obb_center, obb_half, obb_rot, aabb_center, aabb_half)
    return ~jnp.any(all_axis_margins(p) > 0.0, axis=-1)


def sact_pairwise_blocked(obbs: OBBs, aabbs: AABBs, block: int = 256,
                          use_spheres: bool = False) -> SactResult:
    """All-pairs SACT processed in OBB blocks to bound peak memory.

    Pads M up to a multiple of ``block``; callers slice the first M rows.
    """
    M = obbs.n
    pad = (-M) % block
    def pad0(x):
        return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    centers = pad0(obbs.center).reshape((-1, block, 3))
    halves = pad0(obbs.half).reshape((-1, block, 3))
    rots = pad0(obbs.rot).reshape((-1, block, 3, 3))

    def body(args):
        c, h, r = args
        return sact(c[:, None, :], h[:, None, :], r[:, None, :, :],
                    aabbs.center[None, :, :], aabbs.half[None, :, :],
                    use_spheres=use_spheres)

    res = jax.lax.map(body, (centers, halves, rots))
    res = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:])[:M], res)
    return res
