"""Quantized node-metadata formats for bandwidth-bound traversal.

The streamed metadata layout (kernels/persist, DESIGN.md §3) made node
rows the explicit HBM cost of large-scene traversal: 16 B per fetched
row.  This module defines the compressed row formats that shrink that
row — and with it the resident table — without ever changing a verdict:

* ``fp32`` — the original 4 x int32 row ``[code, full, child_start,
  child_mask]``; 16 B, decode-free.
* ``bf16`` — 2 x int32: a packed topology word (full flag, 23-bit CSR
  child pointer, 8-bit child-occupancy mask) plus a geometry word
  holding the node's lo corner as 3 x 10-bit fixed-point coordinates on
  the scene's leaf grid (``2**GRID_BITS`` cells per axis); 8 B.  The
  name marks the half-width tier of the ISSUE's bf16/u8 ladder: three
  IEEE bf16 coordinates plus the CSR topology cannot fit 8 B, so the
  half row spends its geometry bits on fixed point instead — which is
  *exact* for octree-aligned cells (a level-``l`` cell coordinate is an
  integer on the leaf grid), where true bf16 mantissas would have to
  round (see :func:`quantize_aabb_bf16` for the genuine-bf16 outward
  rounding used on general, non-aligned boxes).
* ``u8`` — 1 x int32: the topology word alone (full flag, 3-bit octant,
  20-bit child pointer, 8-bit mask); 4 B.  Geometry travels with the
  frontier instead of the row: each lane carries its own Morton code
  (seeded 0 at the root, child = ``(code << 3) | octant``), so the row
  only needs the child's octant — the uint8-offsets-relative-to-parent
  scheme collapsed to its information content, since an octree child's
  bounds relative to its parent cell ARE its 3-bit octant.

Outward rounding is what keeps compressed culling *sound*: a quantized
bound must contain the fp32 bound so a quantized node can only be
visited MORE, never culled when fp32 would visit.  For the aligned
octree cells above the packed coordinates are exact, so verdicts and
every work counter stay bitwise-identical to fp32 (CI-enforced).  The
generic conservative quantizers (:func:`quantize_child_aabb_u8`,
:func:`quantize_aabb_bf16`) implement the outward rounding for
arbitrary boxes — degenerate thin ones included — and are
property-tested for containment in ``tests/test_quantize.py``.

Host-side packing is pure numpy; the in-register dequantize lives in
the traversal arms (kernels/persist/{kernel,ref}.py, kernels/traverse/
ops.py).  Byte pricing lives with the rest of the bytes model in
:mod:`repro.core.counters` (``BYTES_META_STREAM{,_BF16,_U8}``).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

#: Node-metadata row formats (drift-guarded against the DESIGN.md §3 /
#: README META_FORMATS tables, like ``META_LAYOUTS``).
META_FORMATS = ("fp32", "bf16", "u8")

#: int32 words per node-metadata row; bytes = 4 * words (the counters
#: module prices them as ``BYTES_META_STREAM{,_BF16,_U8}``).
META_FORMAT_WORDS = {"fp32": 4, "bf16": 2, "u8": 1}

#: Leaf-grid resolution exponent of the packed geometry word: 10-bit
#: fixed point per axis = the finest Morton grid
#: (``repro.core.octree.MAX_DEPTH`` levels; octree.py asserts the two
#: stay equal).
GRID_BITS = 10

#: CSR child-pointer field widths of the packed topology word.  The
#: word is ``full << 31 | [octant << 28 |] child_start << 8 | mask``;
#: a format can only index scenes whose widest level fits its pointer
#: field (:func:`format_eligible` — the chooser's gate, with fp32 as
#: the always-eligible fallback).
BF16_START_BITS = 23
U8_START_BITS = 20

#: Grid of the generic parent-relative uint8 quantizer (offsets are
#: 1/256ths of the parent cell).
U8_GRID = 256


def format_eligible(fmt: str, n_max: int) -> bool:
    """Can ``fmt``'s packed child pointer index a scene whose widest
    level holds ``n_max`` nodes?  fp32 (unpacked int32 pointer) always
    can; the packed formats are bounded by their field width."""
    if fmt not in META_FORMATS:
        raise ValueError(f"unknown meta_format {fmt!r}; "
                         f"allowed: {', '.join(META_FORMATS)}")
    if fmt == "fp32":
        return True
    bits = BF16_START_BITS if fmt == "bf16" else U8_START_BITS
    return int(n_max) <= (1 << bits)


def _check_start(child_start: np.ndarray, bits: int, fmt: str) -> np.ndarray:
    start = np.asarray(child_start, np.int64)
    if start.size and int(start.max()) >= (1 << bits):
        raise ValueError(
            f"meta_format {fmt!r}: child_start {int(start.max())} overflows "
            f"the {bits}-bit packed pointer field; use a wider format")
    return start.astype(np.uint32)


def pack_topo_bf16(full: np.ndarray, child_start: np.ndarray,
                   child_mask: np.ndarray) -> np.ndarray:
    """bf16 topology word: ``full << 31 | child_start << 8 | mask``."""
    start = _check_start(child_start, BF16_START_BITS, "bf16")
    w = ((np.asarray(full, np.uint32) << np.uint32(31))
         | (start << np.uint32(8))
         | (np.asarray(child_mask, np.uint32) & np.uint32(0xFF)))
    return w.view(np.int32)


def pack_topo_u8(full: np.ndarray, octant: np.ndarray,
                 child_start: np.ndarray, child_mask: np.ndarray
                 ) -> np.ndarray:
    """u8 row: ``full << 31 | octant << 28 | child_start << 8 | mask``."""
    start = _check_start(child_start, U8_START_BITS, "u8")
    w = ((np.asarray(full, np.uint32) << np.uint32(31))
         | ((np.asarray(octant, np.uint32) & np.uint32(7)) << np.uint32(28))
         | (start << np.uint32(8))
         | (np.asarray(child_mask, np.uint32) & np.uint32(0xFF)))
    return w.view(np.int32)


def pack_geom_bf16(xyz: np.ndarray, level: int) -> np.ndarray:
    """bf16 geometry word from (n, 3) int cell coordinates at ``level``.

    A level-``l`` cell coordinate ``x < 2**l`` becomes the leaf-grid
    fixed-point value ``x << (GRID_BITS - l)`` (its lo corner in
    1/1024ths of the scene edge) — exact, 10 bits per axis, packed
    ``qx << 20 | qy << 10 | qz``.
    """
    q = np.asarray(xyz, np.uint32) << np.uint32(GRID_BITS - level)
    if q.size and int(q.max()) >= (1 << GRID_BITS):
        raise ValueError(f"cell coordinate overflows the {GRID_BITS}-bit "
                         f"leaf grid at level {level}")
    w = (q[:, 0] << np.uint32(20)) | (q[:, 1] << np.uint32(10)) | q[:, 2]
    return w.view(np.int32)


def unpack_geom_bf16(word: np.ndarray, level: int) -> np.ndarray:
    """Inverse of :func:`pack_geom_bf16` -> (n, 3) int32 cell coords."""
    q = np.asarray(word).view(np.uint32)
    qs = np.stack([(q >> np.uint32(20)) & np.uint32(0x3FF),
                   (q >> np.uint32(10)) & np.uint32(0x3FF),
                   q & np.uint32(0x3FF)], axis=-1)
    return (qs >> np.uint32(GRID_BITS - level)).astype(np.int32)


def unpack_topo(word: np.ndarray, fmt: str
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Packed topology word -> (full, octant, child_start, child_mask).

    ``octant`` is zeros for ``fmt="bf16"`` (its geometry word carries the
    coordinates instead).
    """
    u = np.asarray(word).view(np.uint32)
    full = (u >> np.uint32(31)) != 0
    mask = (u & np.uint32(0xFF)).astype(np.int32)
    if fmt == "u8":
        octant = ((u >> np.uint32(28)) & np.uint32(7)).astype(np.int32)
        start = ((u >> np.uint32(8))
                 & np.uint32((1 << U8_START_BITS) - 1)).astype(np.int32)
    else:
        octant = np.zeros_like(mask)
        start = ((u >> np.uint32(8))
                 & np.uint32((1 << BF16_START_BITS) - 1)).astype(np.int32)
    return full, octant, start, mask


# ---------------------------------------------------------------------------
# Generic conservative (outward-rounded) AABB quantizers.  The packed
# octree rows above never need them (aligned cells quantize exactly);
# they define — and the hypothesis suite verifies — the containment
# contract any future non-aligned compressed node (e.g. an LBVH over
# raw triangles) must satisfy: dequantized bounds ⊇ fp32 bounds.
# ---------------------------------------------------------------------------

def quantize_child_aabb_u8(child_lo, child_hi, parent_lo, parent_cell
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Child AABB ⊆ parent cell -> outward-rounded uint8 offsets.

    ``qlo`` is the lo corner's offset from the parent's lo corner and
    ``qhi`` the hi corner's offset from the parent's HI corner, both
    floored onto the parent cell's 256-grid — flooring an offset
    measured *inward from its own face* rounds each face outward.  A
    verification nudge absorbs float rounding in the grid arithmetic,
    so containment holds exactly, degenerate thin boxes included.
    """
    child_lo = np.asarray(child_lo, np.float64)
    child_hi = np.asarray(child_hi, np.float64)
    parent_lo = np.asarray(parent_lo, np.float64)
    cell = np.float64(parent_cell)
    step = cell / U8_GRID
    qlo = np.clip(np.floor((child_lo - parent_lo) / step), 0,
                  U8_GRID - 1)
    qhi = np.clip(np.floor((parent_lo + cell - child_hi) / step), 0,
                  U8_GRID - 1)
    # Guard the containment contract against rounding in the division:
    # one step outward is always enough (floor is off by at most 1 ulp).
    qlo = np.where(parent_lo + qlo * step > child_lo,
                   np.maximum(qlo - 1, 0), qlo)
    qhi = np.where(parent_lo + cell - qhi * step < child_hi,
                   np.maximum(qhi - 1, 0), qhi)
    return qlo.astype(np.uint8), qhi.astype(np.uint8)


def dequantize_child_aabb_u8(qlo, qhi, parent_lo, parent_cell
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`quantize_child_aabb_u8`; bounds ⊇ the input box."""
    parent_lo = np.asarray(parent_lo, np.float64)
    cell = np.float64(parent_cell)
    step = cell / U8_GRID
    lo = parent_lo + np.asarray(qlo, np.float64) * step
    hi = parent_lo + cell - np.asarray(qhi, np.float64) * step
    return lo, hi


def bf16_round_down(x: np.ndarray) -> np.ndarray:
    """Largest bfloat16-representable value <= ``x`` (finite float32 in).

    Pure uint32 bit arithmetic — no ``ml_dtypes`` dependency — so the
    conservative rounding works on every host; :func:`bf16_support`
    names whether a native bfloat16 cross-check is available.
    """
    x = np.asarray(x, np.float32)
    b = x.view(np.uint32)
    trunc = b & np.uint32(0xFFFF0000)
    # Truncation rounds toward zero; for negative values with dropped
    # mantissa bits that is UP, so step one bf16 ulp further from zero.
    dropped = (b & np.uint32(0xFFFF)) != 0
    neg = (b >> np.uint32(31)) != 0
    bump = np.where(dropped & neg, np.uint32(0x10000), np.uint32(0))
    return (trunc + bump).view(np.float32)


def bf16_round_up(x: np.ndarray) -> np.ndarray:
    """Smallest bfloat16-representable value >= ``x``."""
    return -bf16_round_down(-np.asarray(x, np.float32))


def quantize_aabb_bf16(lo, hi) -> Tuple[np.ndarray, np.ndarray]:
    """Outward-rounded genuine-bf16 bounds: (round_down(lo), round_up(hi));
    always contains the fp32 box, thin/degenerate boxes included."""
    return bf16_round_down(lo), bf16_round_up(hi)


def bf16_support() -> Tuple[bool, str]:
    """(ok, reason): is native bfloat16 rounding available on this host?

    The packed rows and the quantizers above are integer/bit arithmetic
    and never lower bfloat16 ops, so the engine works regardless; tests
    use this guard to cross-check :func:`bf16_round_down`/``up`` against
    ``ml_dtypes`` casts where available and to skip that cross-check —
    with this named reason — where not (satellite: no raw lowering
    errors on bf16-less hosts).
    """
    try:
        import ml_dtypes
    except Exception as e:  # pragma: no cover - ml_dtypes ships with jax
        return False, (f"ml_dtypes unavailable ({e.__class__.__name__}): "
                       f"using uint32-truncation bf16 rounding only")
    try:
        np.asarray([1.0 + 2.0 ** -10], np.float32).astype(ml_dtypes.bfloat16)
    except Exception as e:  # pragma: no cover - defensive
        return False, (f"bfloat16 cast failed on this host ({e}): "
                       f"using uint32-truncation bf16 rounding only")
    return True, "native ml_dtypes bfloat16"
