"""End-to-end motion-planning pipeline with the explicit collision gate.

RoboGPU Fig. 18: point-cloud processing (sampling + grouping) -> neural
planner rollout -> explicit collision check of the proposed trajectory.
The paper's safety argument is that the collision gate must be part of the
pipeline; with RoboCore-style acceleration it adds no wall-clock to the
critical path.  Stage timings are returned for the benchmark.

Every front-end here lowers through :mod:`repro.engine.plan` and executes
on :meth:`repro.engine.executor.CollisionEngine.execute` — host-loop and
device-resident engines consume the *same* plan, so there is no
per-front-end engine dispatch left in this module.  ``check_edges`` is
the swept-edge (CCD) workload: batched first-hit validation of planning
graph edges (see :mod:`repro.core.sweep`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sweep import sweep_edges
from repro.core.wavefront import CollisionEngine
from repro.engine.plan import plan_trajectory


@dataclasses.dataclass
class PipelineResult:
    trajectory: np.ndarray          # (T+1, 7) joint waypoints
    collision_free: bool
    colliding_waypoints: np.ndarray  # (T+1,) bool
    timings: Dict[str, float]
    counters: Optional[object] = None


@dataclasses.dataclass
class EdgeCheckResult:
    """Batched swept-edge validation verdicts (``check_edges``)."""

    first_hit: np.ndarray   # (E,) float32 t0 of first colliding sub-interval
    #                         (inf = edge collision-free)
    collide: np.ndarray     # (E,) bool
    counters: Optional[object] = None


def check_trajectory(engine: CollisionEngine, waypoints: jax.Array,
                     base_pos=None):
    """FK every waypoint -> link OBBs -> octree collision query.

    ``waypoints`` is (T, 7); the trajectory lowers to one flat link-OBB
    plan whose un-flattening recipe ORs each waypoint's links — every
    engine mode consumes the same plan in a single call (device modes: one
    compiled call with per-query early exit).  Returns (per-waypoint
    collision flags, counters).
    """
    return engine.execute(plan_trajectory(waypoints, base_pos=base_pos))


def check_trajectories(engine: CollisionEngine, waypoints: jax.Array,
                       base_pos=None):
    """Collision-gate a whole batch of trajectories in one compiled call.

    ``waypoints`` is (B, T, 7); returns ((B, T) per-waypoint flags,
    counters).  This is the batched-throughput path of the collision gate:
    B * T waypoint queries traverse the octree together, each retiring from
    the wavefront as soon as its verdict is decided.
    """
    return engine.execute(plan_trajectory(waypoints, base_pos=base_pos))


def check_edges(engine: CollisionEngine, q_from: jax.Array, q_to: jax.Array,
                resolution: int = 16, base_pos=None,
                in_traversal_exit: bool = True) -> EdgeCheckResult:
    """Swept-edge (CCD) validation of E planning-graph edges.

    Each edge ``q_from[e] -> q_to[e]`` (joint space, linear interpolation)
    is enclosed in conservative swept OBBs and bisected only where the
    swept volume hits occupied leaves; the finest round's payload lane
    returns the per-edge *first* colliding sub-interval with in-traversal
    early exit (:mod:`repro.core.sweep`).  ``first_hit[e]`` is the start
    parameter t0 of that sub-interval (``inf`` for a collision-free edge),
    an upper-bound verdict over dense waypoint sampling at the same
    ``resolution``.  ``resolution`` must be a power of two (the bisection
    halves segments down to width 1).
    """
    first_hit, collide, counters = sweep_edges(
        engine, q_from, q_to, resolution=resolution, base_pos=base_pos,
        in_traversal_exit=in_traversal_exit)
    return EdgeCheckResult(first_hit=first_hit, collide=collide,
                           counters=counters)


def plan_with_collision_gate(planner_params, planner_fns, engine:
                             CollisionEngine, cloud: jax.Array,
                             q0: jax.Array, goal: jax.Array,
                             num_steps: int = 40, sampling: str = "random",
                             key=None) -> PipelineResult:
    """One planning episode: encode -> rollout -> explicit collision gate.

    ``planner_fns`` = (encode_fn, rollout_fn) from models/planner.py
    signatures; kept injectable so benchmarks can swap sampling modes.
    Stage walls are honest: each stage blocks on its own device work
    (``block_until_ready``), so the planner's async dispatch is charged to
    ``plan_s`` and never bleeds into ``collision_s``.  ``counters`` come
    from the collision gate only.
    """
    rollout = planner_fns["rollout"]
    t0 = time.perf_counter()
    traj = rollout(planner_params, cloud[None], q0[None], goal[None],
                   num_steps, sampling, key)
    traj = jax.block_until_ready(traj)
    t_plan = time.perf_counter() - t0
    traj = jax.device_get(traj)[0]                  # (T+1, 7)

    t0 = time.perf_counter()
    flags, counters = check_trajectory(engine, jnp.asarray(traj))
    t_collision = time.perf_counter() - t0
    flags = np.asarray(flags)
    return PipelineResult(
        trajectory=traj, collision_free=not bool(flags.any()),
        colliding_waypoints=flags,
        timings={"plan_s": t_plan, "collision_s": t_collision},
        counters=counters)
