"""End-to-end motion-planning pipeline with the explicit collision gate.

RoboGPU Fig. 18: point-cloud processing (sampling + grouping) -> neural
planner rollout -> explicit collision check of the proposed trajectory.
The paper's safety argument is that the collision gate must be part of the
pipeline; with RoboCore-style acceleration it adds no wall-clock to the
critical path.  Stage timings are returned for the benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import arm_link_obbs
from repro.core.octree import Octree
from repro.core.wavefront import CollisionEngine, EngineConfig


@dataclasses.dataclass
class PipelineResult:
    trajectory: np.ndarray          # (T+1, 7) joint waypoints
    collision_free: bool
    colliding_waypoints: np.ndarray  # (T+1,) bool
    timings: Dict[str, float]
    counters: Optional[object] = None


def check_trajectory(engine: CollisionEngine, waypoints: jax.Array,
                     base_pos=None):
    """FK every waypoint -> link OBBs -> octree collision query.

    Returns (per-waypoint collision flags, counters).
    """
    obbs = arm_link_obbs(waypoints, base_pos=base_pos)
    collide, counters = engine.query(obbs)
    per_wp = collide.reshape(waypoints.shape[0], -1).any(axis=1)
    return per_wp, counters


def plan_with_collision_gate(planner_params, planner_fns, engine:
                             CollisionEngine, cloud: jax.Array,
                             q0: jax.Array, goal: jax.Array,
                             num_steps: int = 40, sampling: str = "random",
                             key=None) -> PipelineResult:
    """One planning episode: encode -> rollout -> explicit collision gate.

    ``planner_fns`` = (encode_fn, rollout_fn) from models/planner.py
    signatures; kept injectable so benchmarks can swap sampling modes.
    """
    rollout = planner_fns["rollout"]
    t0 = time.perf_counter()
    traj = rollout(planner_params, cloud[None], q0[None], goal[None],
                   num_steps, sampling, key)
    traj = jax.device_get(traj)[0]                  # (T+1, 7)
    t_plan = time.perf_counter() - t0

    t0 = time.perf_counter()
    flags, counters = check_trajectory(engine, jnp.asarray(traj))
    t_collision = time.perf_counter() - t0
    flags = np.asarray(flags)
    return PipelineResult(
        trajectory=traj, collision_free=not bool(flags.any()),
        colliding_waypoints=flags,
        timings={"plan_s": t_plan, "collision_s": t_collision},
        counters=counters)
