"""End-to-end motion-planning pipeline with the explicit collision gate.

RoboGPU Fig. 18: point-cloud processing (sampling + grouping) -> neural
planner rollout -> explicit collision check of the proposed trajectory.
The paper's safety argument is that the collision gate must be part of the
pipeline; with RoboCore-style acceleration it adds no wall-clock to the
critical path.  Stage timings are returned for the benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import NUM_LINKS, OBBs, arm_link_obbs
from repro.core.wavefront import CollisionEngine


@dataclasses.dataclass
class PipelineResult:
    trajectory: np.ndarray          # (T+1, 7) joint waypoints
    collision_free: bool
    colliding_waypoints: np.ndarray  # (T+1,) bool
    timings: Dict[str, float]
    counters: Optional[object] = None


def _waypoint_batched(obbs: OBBs, num_wp: int) -> OBBs:
    """Reshape flattened link OBBs into a (num_wp, NUM_LINKS) query batch."""
    return OBBs(center=obbs.center.reshape(num_wp, NUM_LINKS, 3),
                half=obbs.half.reshape(num_wp, NUM_LINKS, 3),
                rot=obbs.rot.reshape(num_wp, NUM_LINKS, 3, 3))


def check_trajectory(engine: CollisionEngine, waypoints: jax.Array,
                     base_pos=None):
    """FK every waypoint -> link OBBs -> octree collision query.

    Device-resident engines check the whole trajectory as one (T, 7)
    query batch in a single compiled call (per-waypoint early exit);
    host-loop engines keep the flat query.  Returns (per-waypoint collision
    flags, counters).
    """
    obbs = arm_link_obbs(waypoints, base_pos=base_pos)
    T = waypoints.shape[0]
    if engine.cfg.device_resident:
        collide, counters = engine.query_batched(_waypoint_batched(obbs, T))
        return collide.any(axis=1), counters
    collide, counters = engine.query(obbs)
    per_wp = collide.reshape(T, -1).any(axis=1)
    return per_wp, counters


def check_trajectories(engine: CollisionEngine, waypoints: jax.Array,
                       base_pos=None):
    """Collision-gate a whole batch of trajectories in one compiled call.

    ``waypoints`` is (B, T, 7); returns ((B, T) per-waypoint flags,
    counters).  This is the batched-throughput path of the collision gate:
    B * T waypoint queries traverse the octree together, each retiring from
    the wavefront as soon as its verdict is decided.
    """
    B, T = waypoints.shape[:2]
    obbs = arm_link_obbs(waypoints, base_pos=base_pos)   # (B*T*7,) flattened
    flags, counters = engine.query_batched(_waypoint_batched(obbs, B * T))
    return flags.any(axis=1).reshape(B, T), counters


def plan_with_collision_gate(planner_params, planner_fns, engine:
                             CollisionEngine, cloud: jax.Array,
                             q0: jax.Array, goal: jax.Array,
                             num_steps: int = 40, sampling: str = "random",
                             key=None) -> PipelineResult:
    """One planning episode: encode -> rollout -> explicit collision gate.

    ``planner_fns`` = (encode_fn, rollout_fn) from models/planner.py
    signatures; kept injectable so benchmarks can swap sampling modes.
    """
    rollout = planner_fns["rollout"]
    t0 = time.perf_counter()
    traj = rollout(planner_params, cloud[None], q0[None], goal[None],
                   num_steps, sampling, key)
    traj = jax.device_get(traj)[0]                  # (T+1, 7)
    t_plan = time.perf_counter() - t0

    t0 = time.perf_counter()
    flags, counters = check_trajectory(engine, jnp.asarray(traj))
    t_collision = time.perf_counter() - t0
    flags = np.asarray(flags)
    return PipelineResult(
        trajectory=traj, collision_free=not bool(flags.any()),
        colliding_waypoints=flags,
        timings={"plan_s": t_plan, "collision_s": t_collision},
        counters=counters)
