"""Fixed-radius neighbor search (PointNet++ "ball query") on the octree.

RoboGPU §IV: ball query can be posed as tree traversal two ways —
  P-Ray:    sampled points are spheres, every cloud point is a "ray" that
            traverses a small tree built over the M sampled centers;
  P-Sphere: cloud points are spheres in a deep tree, each sampled center
            traverses it (M rays over a large tree).
The paper finds P-Sphere superior *given early exit*: a query that has
already gathered ``k`` neighbors retires, and on average 6x fewer nodes are
traversed.  We realize the early exit at batch granularity: leaf visits are
processed in per-query rank chunks; queries that fill up drop out of later
chunks (wavefront compaction, DESIGN.md §2).

All routines return (idx (M, k) int32, count (M,) int32, Counters); slots
``>= count`` are filled with -1.  Neighbor *order* within a ball is
unspecified (matches PointNet++ semantics); tests compare sets/counts.
"""
from __future__ import annotations

import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.counters import Counters
from repro.core.geometry import point_aabb_sq_distance
from repro.core.octree import (Octree, build_octree, lookup_children,
                               node_centers_from_codes)


def ball_query_ref(points: jax.Array, queries: jax.Array, radius: float,
                   k: int) -> Tuple[jax.Array, jax.Array]:
    """Brute-force oracle: first-k (by point index) neighbors within radius."""
    d2 = jnp.sum(jnp.square(queries[:, None, :] - points[None, :, :]), -1)
    hit = d2 <= radius * radius                       # (M, N)
    count = jnp.minimum(jnp.sum(hit, -1), k).astype(jnp.int32)
    # first-k hit indices per row
    N = points.shape[0]
    rank = jnp.cumsum(hit, axis=-1) - 1               # rank among hits
    slot = jnp.where(hit & (rank < k), rank, k)
    M = queries.shape[0]
    out = jnp.full((M, k + 1), -1, jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(M)[:, None], (M, N))
    out = out.at[rows, slot].set(
        jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None, :], (M, N)))
    return out[:, :k], count


def _merge_candidates(out_idx, counts, q_flat, p_flat, hit):
    """Append candidate hits (q, p) into per-query buffers, capped at k."""
    M, K = out_idx.shape
    E = q_flat.shape[0]
    qk = jnp.where(hit, q_flat, M).astype(jnp.int32)
    order = jnp.argsort(qk, stable=True)
    qs = qk[order]
    ps = p_flat[order]
    seg_start = jnp.searchsorted(qs, qs, side="left")
    rank = jnp.arange(E, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    base = counts[jnp.minimum(qs, M - 1)]
    slot = base + rank
    ok = (qs < M) & (slot < K)
    rows = jnp.where(ok, qs, M)          # M = out of range -> dropped
    cols = jnp.where(ok, slot, 0)
    out_idx = out_idx.at[rows, cols].set(ps.astype(jnp.int32), mode="drop")
    counts = counts.at[rows].add(jnp.where(ok, 1, 0), mode="drop")
    return out_idx, counts


def _traverse_to_leaves(tree: Octree, centers: jax.Array, radius: float,
                        c: Counters, max_frontier: int = 1 << 22):
    """Wavefront sphere-vs-node descent; returns leaf frontier (q, leaf_pos)."""
    M = centers.shape[0]
    q_idx = jnp.arange(M, dtype=jnp.int32)
    codes = jnp.zeros((M,), jnp.uint32)
    scene_lo = jnp.asarray(tree.scene_lo)
    r2 = radius * radius
    for level in range(tree.depth + 1):
        node_c, node_h = node_centers_from_codes(codes, scene_lo,
                                                 tree.cell_size(level))
        d2 = point_aabb_sq_distance(centers[q_idx], node_c, node_h)
        overlap = d2 <= r2
        c.nodes_traversed += int(codes.shape[0])
        c.nodes_per_level.append(int(codes.shape[0]))
        if level == tree.depth:
            n = int(jax.device_get(jnp.sum(overlap)))
            keep = jnp.nonzero(overlap, size=n)[0]
            return q_idx[keep], codes[keep]
        child_codes, child_idx = lookup_children(
            jnp.asarray(tree.levels[level + 1].codes), codes)
        mask = overlap[:, None] & (child_idx >= 0)
        flat_mask = mask.reshape(-1)
        n = int(jax.device_get(jnp.sum(flat_mask)))
        if n == 0:
            return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.uint32))
        n = min(n, max_frontier)
        keep = jnp.nonzero(flat_mask, size=n)[0]
        q_idx = jnp.repeat(q_idx, 8)[keep]
        codes = child_codes.reshape(-1)[keep]
    raise AssertionError


def ball_query_psphere(tree: Octree, queries: jax.Array, radius: float,
                       k: int, chunk: int = 8, early_exit: bool = True
                       ) -> Tuple[jax.Array, jax.Array, Counters]:
    """P-Sphere: each query center traverses the point octree.

    ``chunk`` = leaf visits processed per query per round; after each round
    full queries retire (the RoboCore early exit).  ``early_exit=False``
    reproduces the RTNN baseline that keeps traversing (paper: 6x more nodes).
    """
    t0 = time.perf_counter()
    c = Counters(num_queries=queries.shape[0])
    queries = jnp.asarray(queries, jnp.float32)
    M = queries.shape[0]
    leaf_codes = jnp.asarray(tree.levels[tree.depth].codes)
    q_idx, codes = _traverse_to_leaves(tree, queries, radius, c)
    # Undo the double count of leaf entries (counted again per chunk below).
    c.nodes_traversed -= int(q_idx.shape[0])
    c.nodes_per_level.pop()
    out_idx = jnp.full((M, k), -1, jnp.int32)
    counts = jnp.zeros((M,), jnp.int32)
    if q_idx.shape[0] == 0:
        c.wall_time_s = time.perf_counter() - t0
        return out_idx, counts, c

    leaf_cap = int(np.max(tree.leaf_point_count))
    # Pad gather sources so dynamic_slice never clamps the start index.
    pts = jnp.concatenate([jnp.asarray(tree.points_sorted),
                           jnp.full((leaf_cap, 3), jnp.inf, jnp.float32)])
    pidx = jnp.concatenate([jnp.asarray(tree.point_index),
                            jnp.full((leaf_cap,), -1, jnp.int32)])
    starts_all = jnp.asarray(tree.leaf_point_start)
    counts_all = jnp.asarray(tree.leaf_point_count)
    leaf_pos = jnp.searchsorted(leaf_codes, codes).astype(jnp.int32)

    # Order each query's leaf visits CLOSEST-FIRST (the DFS a RoboCore-style
    # traversal performs): early exit then triggers after the few nearest
    # leaves instead of an arbitrary prefix.  Sort key = (query, distance
    # from query to leaf center).
    from repro.core.octree import node_centers_from_codes
    leaf_c, _ = node_centers_from_codes(codes, jnp.asarray(tree.scene_lo),
                                        tree.cell_size(tree.depth))
    d2leaf = jnp.sum(jnp.square(leaf_c - queries[q_idx]), -1)
    order = jnp.lexsort((d2leaf, q_idx))
    q_idx, leaf_pos = q_idx[order], leaf_pos[order]
    seg_start = jnp.searchsorted(q_idx, q_idx, side="left")
    rank = jnp.arange(q_idx.shape[0]) - seg_start
    max_rank = int(jax.device_get(jnp.max(rank))) if q_idx.shape[0] else 0

    r2 = radius * radius
    gather = jax.vmap(lambda s: jax.lax.dynamic_slice(pts, (s, 0),
                                                      (leaf_cap, 3)))
    gather_i = jax.vmap(lambda s: jax.lax.dynamic_slice(pidx, (s,),
                                                        (leaf_cap,)))
    for round_i in range(0, max_rank + 1, chunk):
        live = (rank >= round_i) & (rank < round_i + chunk)
        if early_exit:
            live = live & (counts[q_idx] < k)
        n = int(jax.device_get(jnp.sum(live)))
        if n == 0:
            continue
        keep = jnp.nonzero(live, size=n)[0]
        qv, lv = q_idx[keep], leaf_pos[keep]
        c.nodes_traversed += n
        st, cnt = starts_all[lv], counts_all[lv]
        cand = gather(st)                       # (n, leaf_cap, 3)
        cand_idx = gather_i(st)                 # (n, leaf_cap)
        valid = jnp.arange(leaf_cap)[None, :] < cnt[:, None]
        d2 = jnp.sum(jnp.square(cand - queries[qv][:, None, :]), -1)
        hit = (d2 <= r2) & valid
        c.leaf_tests += int(jax.device_get(jnp.sum(valid)))
        qf = jnp.repeat(qv, leaf_cap)
        out_idx, counts = _merge_candidates(
            out_idx, counts, qf, cand_idx.reshape(-1), hit.reshape(-1))
    counts = jnp.minimum(counts, k)
    c.wall_time_s = time.perf_counter() - t0
    return out_idx, counts, c


def ball_query_pray(points: jax.Array, queries: jax.Array, radius: float,
                    k: int, depth: int = 6
                    ) -> Tuple[jax.Array, jax.Array, Counters]:
    """P-Ray: every cloud point traverses a small octree over query centers.

    No early exit is possible (a point cannot know whether its queries are
    full), which is exactly why the paper finds it inferior on RoboCore.
    """
    t0 = time.perf_counter()
    points = jnp.asarray(points, jnp.float32)
    queries_np = np.asarray(queries, np.float32)
    qtree = build_octree(queries_np, depth=depth)
    c = Counters(num_queries=int(points.shape[0]))  # rays = points
    M, N = queries_np.shape[0], points.shape[0]
    q_leafcap = int(np.max(qtree.leaf_point_count))

    p_idx, codes = _traverse_to_leaves(qtree, points, radius, c)
    out_idx = jnp.full((M, k), -1, jnp.int32)
    counts = jnp.zeros((M,), jnp.int32)
    if p_idx.shape[0] == 0:
        c.wall_time_s = time.perf_counter() - t0
        return out_idx, counts, c

    leaf_codes = jnp.asarray(qtree.levels[qtree.depth].codes)
    leaf_pos = jnp.searchsorted(leaf_codes, codes).astype(jnp.int32)
    starts = jnp.asarray(qtree.leaf_point_start)[leaf_pos]
    cnts = jnp.asarray(qtree.leaf_point_count)[leaf_pos]
    qpts = jnp.concatenate([jnp.asarray(qtree.points_sorted),
                            jnp.full((q_leafcap, 3), jnp.inf, jnp.float32)])
    qmap = jnp.concatenate([jnp.asarray(qtree.point_index),
                            jnp.full((q_leafcap,), -1, jnp.int32)])
    gather = jax.vmap(lambda s: jax.lax.dynamic_slice(qpts, (s, 0),
                                                      (q_leafcap, 3)))
    gather_i = jax.vmap(lambda s: jax.lax.dynamic_slice(qmap, (s,),
                                                        (q_leafcap,)))
    cand_q = gather(starts)                      # (E, cap, 3) query centers
    cand_qi = gather_i(starts)                   # (E, cap) original q index
    valid = jnp.arange(q_leafcap)[None, :] < cnts[:, None]
    d2 = jnp.sum(jnp.square(cand_q - points[p_idx][:, None, :]), -1)
    hit = (d2 <= radius * radius) & valid
    c.leaf_tests += int(jax.device_get(jnp.sum(valid)))
    pf = jnp.repeat(p_idx, q_leafcap).astype(jnp.int32)
    out_idx, counts = _merge_candidates(
        out_idx, counts, cand_qi.reshape(-1), pf, hit.reshape(-1))
    counts = jnp.minimum(counts, k)
    c.wall_time_s = time.perf_counter() - t0
    return out_idx, counts, c
