"""Fault tolerance: preemption handling, straggler mitigation, elasticity.

This container is single-process; the mechanisms below are the real ones,
exercised by tests at reduced scale and documented for 1000+ nodes:

* Preemption (SIGTERM/SIGINT): `PreemptionGuard` flips a flag; the train
  loop checkpoints at the next step boundary and exits cleanly.  On TPU
  pods this hooks the maintenance-event notice instead.
* Stragglers: `PrefetchingLoader` keeps a bounded queue filled by a
  background thread; if the producer misses the deadline the loop reuses
  the last good batch (skip-batch policy) and counts the event — the
  standard "don't let one slow host stall the step barrier" mitigation.
  At scale the same policy applies per-host before the all-gather.
* Elasticity: checkpoints are mesh-free (train/checkpoint.py); a restart
  with a different device count re-device_puts under the new mesh.  The
  launcher recomputes batch sharding from the new mesh size.
"""
from __future__ import annotations

import queue
import signal
import threading
from typing import Iterator


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._installed = False
        self._signals = signals

    def install(self):
        for s in self._signals:
            try:
                signal.signal(s, self._handler)
            except ValueError:
                pass  # non-main thread (tests)
        self._installed = True
        return self

    def _handler(self, signum, frame):
        self._flag.set()

    def trigger(self):                    # for tests
        self._flag.set()

    @property
    def should_checkpoint(self) -> bool:
        return self._flag.is_set()


class PrefetchingLoader:
    """Bounded-queue prefetcher with straggler skip.

    ``next_batch(deadline_s)``: returns the next batch, or — if the
    producer is slower than the deadline — the previous batch again
    (counted in .skipped).  Never blocks the step loop indefinitely.
    """

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._last = None
        self.skipped = 0
        self._done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._done = True

    def next_batch(self, deadline_s: float = 10.0):
        try:
            b = self._q.get(timeout=deadline_s)
            self._last = b
            return b
        except queue.Empty:
            if self._last is None:
                # cold start: block until the first batch exists
                b = self._q.get()
                self._last = b
                return b
            self.skipped += 1
            return self._last
