"""AdamW with optional bf16 moment states and cosine schedule.

bf16 m/v (``state_dtype="bfloat16"``) is what lets the 340B/480B archs fit
16 GiB/chip on the production mesh (DESIGN.md §5): weights bf16 + m/v bf16
under 512-way FSDP ≈ 5.6 GiB/chip for arctic-480b.  Numerics follow the
bf16-state recipes used at scale: moments are quantized after the fp32
update; the weight update itself is computed in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"         # "bfloat16" for the giant archs


def init_opt_state(params, cfg: OptConfig) -> Dict:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, state: Dict, cfg: OptConfig
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decoupled WD on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def opt_state_pspecs(param_specs, step_spec) -> Dict:
    """Optimizer-state PartitionSpecs mirror the parameter shardings."""
    return {"m": param_specs, "v": param_specs, "step": step_spec}
