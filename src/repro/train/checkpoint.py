"""Fault-tolerant checkpointing: async, atomic, keep-k, elastic reshard.

Design (DESIGN.md §5):
  * Layout: one directory per step, one .npy per pytree leaf (flattened
    path-keyed), plus meta.json.  A ``COMMITTED`` marker written after
    fsync-rename makes partial checkpoints (node failure mid-save)
    invisible to restore.
  * Async: save runs on a daemon thread from a host copy of the arrays, so
    the train loop only blocks for the device->host transfer.
  * Elastic: leaves are saved as *logical* (fully-gathered) arrays with no
    mesh metadata; restore device_puts them under whatever mesh/sharding
    the restarted job uses (tested 8 -> 4 fake devices).  At real 1000-node
    scale the same layout is written per-process with ocdbt-style sharding;
    the commit protocol is identical.
  * keep_last_k garbage-collects old steps after each commit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, extra_meta: Optional
                    [Dict] = None, async_save: bool = True,
                    keep_last_k: int = 3) -> threading.Thread | None:
    """Write checkpoint for `step`.  Returns the writer thread if async."""
    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    meta = {"step": int(step), "keys": sorted(host.keys()),
            "time": time.time(), **(extra_meta or {})}

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        for k, v in host.items():
            np.save(os.path.join(tmp, k.replace("/", "_") + ".npy"), v)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(final, "COMMITTED"), "w") as f:
            f.write(str(step))
        _gc(ckpt_dir, keep_last_k)

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        if (name.startswith("step_") and not name.endswith(".tmp")
                and os.path.exists(os.path.join(full, "COMMITTED"))):
            out.append(int(name[5:]))
    return sorted(out)


def restore_checkpoint(ckpt_dir: str, like_tree, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of `like_tree` (abstract or concrete).

    ``shardings``: optional pytree of NamedShardings — arrays are placed
    directly under the (possibly different) mesh: elastic restart.
    Returns (tree, step) or (None, -1) if no committed checkpoint exists.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        return None, -1
    step = step if step is not None else steps[-1]
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    leaves = []
    for (path, like), sh in zip(flat, shard_flat):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = np.load(os.path.join(d, key.replace("/", "_") + ".npy"))
        arr = arr.astype(like.dtype) if hasattr(like, "dtype") else arr
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree), leaves)
    return tree, step
