"""Train/serve step factories with sharding, microbatching, and remat.

``make_sharded_train_step`` returns a jit-compiled SPMD step with explicit
in/out shardings from parallel/sharding.py — the object the multi-pod
dry-run lowers and the launcher executes.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import api
from repro.parallel import sharding as shd
from repro.train import optimizer as opt_mod


def make_train_step(cfg: ModelConfig, opt_cfg: opt_mod.OptConfig,
                    num_microbatches: int = 1,
                    use_specs=None) -> Callable:
    """Pure train step: (params, opt_state, batch) -> (params, opt_state,
    metrics).  Gradient accumulation over leading batch splits when
    num_microbatches > 1."""
    loss_fn = api.make_loss_fn(cfg, use_specs=use_specs)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def reshape(x):
                B = x.shape[0]
                assert B % num_microbatches == 0
                return x.reshape((num_microbatches, B // num_microbatches)
                                 + x.shape[1:])
            micro = jax.tree.map(reshape, batch)

            def acc_body(carry, mb):
                acc, loss_acc = carry
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), ms = jax.lax.scan(
                acc_body, (zeros, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
            loss = loss_sum / num_microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)
        new_params, new_opt, om = opt_mod.adamw_update(params, grads,
                                                       opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return new_params, new_opt, metrics

    return step


def make_sharded_train_step(cfg: ModelConfig, mesh: Mesh,
                            opt_cfg: opt_mod.OptConfig,
                            shape: ShapeSpec,
                            num_microbatches: int = 1):
    """jit-wrapped SPMD train step + all sharding trees.

    Returns (jitted_step, param_specs, opt_specs, batch_specs).
    """
    aparams = api.abstract_params(cfg)
    uspecs = (shd.use_pspecs(cfg, aparams, mesh) if cfg.use_weight_hints
              else None)
    step = make_train_step(cfg, opt_cfg, num_microbatches, use_specs=uspecs)
    pspecs = shd.param_pspecs(cfg, aparams, mesh)
    ospecs = opt_mod.opt_state_pspecs(pspecs, P())
    bspec_tree = api.batch_spec(cfg, shape)
    bspecs = shd.batch_pspecs(cfg, bspec_tree, mesh)
    metric_specs = None  # replicated
    jstep = jax.jit(
        step,
        in_shardings=(shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                      shd.named(mesh, bspecs)),
        out_shardings=(shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                       metric_specs),
        donate_argnums=(0, 1),
    )
    return jstep, pspecs, ospecs, bspecs


def make_sharded_prefill(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec):
    """Prefill step for the inference-prefill dry-run cells."""
    aparams = api.abstract_params(cfg)
    uspecs = (shd.use_pspecs(cfg, aparams, mesh) if cfg.use_weight_hints
              else None)
    prefill = api.make_prefill_fn(cfg, max_len=shape.seq_len,
                                  use_specs=uspecs)
    pspecs = shd.param_pspecs(cfg, aparams, mesh)
    bspec_tree = api.batch_spec(cfg, shape)
    bspecs = shd.batch_pspecs(cfg, bspec_tree, mesh)

    def fn(params, batch):
        logits, caches = prefill(params, batch)
        return logits, caches

    jfn = jax.jit(fn, in_shardings=(shd.named(mesh, pspecs),
                                    shd.named(mesh, bspecs)),
                  out_shardings=None)
    return jfn, pspecs, bspecs


def make_sharded_decode(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec):
    """One-token serve_step with a seq_len KV cache (decode dry-run cells).

    cfg.serve_param_fsdp=False stores parameters replicated over the FSDP
    axes (TP kept) — the serving tradeoff for small models where per-step
    weight gathers/partial-contraction all-reduces dominate decode.

    Weight-gather use hints are deliberately NOT applied at decode: for
    giant-MoE decode they force gathering the expert weights per token
    (measured 10x collective regression on arctic decode_32k — §Perf);
    small models get their win from serve_param_fsdp=False instead.
    """
    aparams = api.abstract_params(cfg)
    decode = api.make_decode_fn(cfg, use_specs=None)
    pspecs = shd.param_pspecs(cfg, aparams, mesh)
    if not cfg.serve_param_fsdp:
        pspecs = jax.tree.map(
            lambda s: shd._strip_fsdp(s, drop_leading=False), pspecs)
    if not cfg.serve_tp:
        pspecs = jax.tree.map(lambda s: P(*(None,) * len(tuple(s))), pspecs)
    acaches = api.abstract_caches(cfg, shape)
    cspecs = shd.cache_pspecs(cfg, acaches, mesh)
    F = shd.fsdp_axes(mesh)
    b_ax = shd._div(shape.global_batch, mesh, F)
    v_ax = shd._div(cfg.vocab_size, mesh, "model")

    def fn(params, token, pos, caches):
        logits, new_caches = decode(params, token, pos, caches)
        return logits, new_caches

    jfn = jax.jit(
        fn,
        in_shardings=(shd.named(mesh, pspecs),
                      NamedSharding(mesh, P(b_ax)),
                      NamedSharding(mesh, P()),
                      shd.named(mesh, cspecs)),
        out_shardings=(NamedSharding(mesh, P(b_ax, v_ax)),
                       shd.named(mesh, cspecs)),
        donate_argnums=(3,),
    )
    return jfn, pspecs, cspecs
