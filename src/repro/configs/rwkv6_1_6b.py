"""rwkv6-1.6b (Finch) [ssm]: attention-free, data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536.
Heads = d_model/64 = 32 for the WKV state.  O(1)-state decode ->
long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_1_6b", family="ssm", num_layers=24, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=7168, vocab_size=65536,
    use_rope=False, subquadratic=True, attn_tp=False,
    train_microbatches=4, serve_param_fsdp=False,
    param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="rwkv6_smoke", num_layers=2, d_model=128, num_heads=2,
    num_kv_heads=2, d_ff=448, vocab_size=512,
    param_dtype="float32", compute_dtype="float32")
