from repro.configs.base import (ARCH_REGISTRY, ModelConfig, get_config,
                                get_smoke_config)

__all__ = ["ARCH_REGISTRY", "ModelConfig", "get_config", "get_smoke_config"]
