"""arctic-480b [moe]: 128 experts top-2 + dense residual FFN branch.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (kv=8)
d_ff=4864/expert vocab=32000.  56 heads not divisible by 16 -> attn params
FSDP-only; experts EP-sharded 8/chip on the 16-way model axis.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic_480b", family="moe", num_layers=35, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=4864, vocab_size=32000,
    num_experts=128, experts_per_token=2, dense_residual=True,
    attn_tp=False, mlp_act="swiglu", train_microbatches=8,
    seq_parallel=True,
    param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="arctic_smoke", num_layers=2, d_model=112, num_heads=7,
    num_kv_heads=1, d_ff=128, vocab_size=512, num_experts=8,
    experts_per_token=2, param_dtype="float32", compute_dtype="float32")
