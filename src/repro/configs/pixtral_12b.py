"""pixtral-12b [vlm]: pixtral-ViT frontend STUBBED + mistral-nemo backbone.

[hf:mistralai/Pixtral-12B-2409; unverified]  40L d_model=5120 32H (kv=8)
d_ff=14336 vocab=131072.  input_specs supplies 256 precomputed patch
embeddings (B, 256, 5120) prepended to the token sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral_12b", family="vlm", num_layers=40, d_model=5120,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=131072,
    head_dim=128, num_patches=256, mlp_act="swiglu",
    train_microbatches=4,
    param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="pixtral_smoke", num_layers=2, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=384, vocab_size=512, head_dim=16, num_patches=8,
    param_dtype="float32", compute_dtype="float32")
