"""glm4-9b [dense]: GQA 32q/2kv, RoPE, SwiGLU.

[hf:THUDM/glm-4-9b; hf]  40L d_model=4096 32H (kv=2) d_ff=13696
vocab=151552.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4_9b", family="dense", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=151552,
    mlp_act="swiglu", train_microbatches=4,
    param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="glm4_smoke", num_layers=2, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=384, vocab_size=512,
    param_dtype="float32", compute_dtype="float32")
