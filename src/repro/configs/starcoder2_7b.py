"""starcoder2-7b [dense]: GQA 36q/4kv, RoPE, GeLU.

[arXiv:2402.19173; hf]  32L d_model=4608 36H (kv=4) d_ff=18432 vocab=49152.
36 heads is not divisible by the 16-way model axis -> attention params are
FSDP-sharded only (attn_tp=False); FFN keeps tensor parallelism.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_7b", family="dense", num_layers=32, d_model=4608,
    num_heads=36, num_kv_heads=4, d_ff=18432, vocab_size=49152,
    mlp_act="gelu", norm="layernorm", attn_tp=False,
    train_microbatches=4,
    param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="starcoder2_smoke", num_layers=2, d_model=144, num_heads=9,
    num_kv_heads=3, d_ff=576, vocab_size=512,
    param_dtype="float32", compute_dtype="float32")
