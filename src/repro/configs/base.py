"""Model/config schema + arch registry for the assigned architectures.

Every assigned architecture lives in its own ``configs/<id>.py`` exposing
``CONFIG`` (the exact full-scale config from the assignment) and
``SMOKE_CONFIG`` (same family, reduced to CPU scale).  ``get_config(name)``
resolves either.  Input-shape sets (train_4k / prefill_32k / decode_32k /
long_500k) are defined here as :data:`SHAPES` with per-arch applicability in
``shape_applicable``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

FAMILIES = ("dense", "moe", "hybrid", "ssm", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // num_heads
    mlp_act: str = "swiglu"              # swiglu | gelu | relu2
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual: bool = False         # arctic: parallel dense FFN branch
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    # Encoder-decoder (whisper): encoder depth; num_layers = decoder depth
    encoder_layers: int = 0
    # VLM stub frontend: number of image patch embeddings prepended
    num_patches: int = 0
    # Long-context behaviour
    sliding_window: int = 0              # 0 = global attention
    subquadratic: bool = False           # may run long_500k
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # parallelism policy (see parallel/sharding.py)
    attn_tp: bool = True                 # shard attention heads over `model`
    remat: bool = True
    attn_impl: str = "auto"              # auto | dense (smoke/debug)
    seq_parallel: bool = False           # SP sharding hints on activations
    train_microbatches: int = 1          # grad-accumulation splits
    use_weight_hints: bool = True       # ZeRO-3 weight-gather use hints
    serve_param_fsdp: bool = True        # False: replicate params at decode
    serve_tp: bool = True                # False: no TP at decode (small models)
    moe_batch_group_decode: bool = True  # S=1: dispatch across the batch

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def block_type(self) -> str:
        if self.family == "ssm":
            return "rwkv"
        if self.family == "hybrid":
            return "hybrid"
        return "attn"

    @property
    def num_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        if self.mlp_act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.num_experts:
            mlp_total = self.num_experts * mlp + d * self.num_experts
            if self.dense_residual:
                mlp_total += mlp
        else:
            mlp_total = mlp
        per_layer = attn + mlp_total + 2 * d
        if self.block_type == "rwkv":
            per_layer = 4 * d * d + 3 * d * f // 2 + 6 * d  # rwkv-ish
        emb = V * d * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * (attn + mlp + 2 * d)
        return per_layer * self.num_layers + emb + enc

    @property
    def num_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.num_params
        d, f = self.d_model, self.d_ff
        mlp = (3 if self.mlp_act == "swiglu" else 2) * d * f
        inactive = (self.num_experts - self.experts_per_token) * mlp \
            * self.num_layers
        return self.num_params - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_REGISTRY = (
    "nemotron_4_340b",
    "qwen1_5_110b",
    "starcoder2_7b",
    "glm4_9b",
    "whisper_medium",
    "hymba_1_5b",
    "granite_moe_1b_a400m",
    "arctic_480b",
    "pixtral_12b",
    "rwkv6_1_6b",
)


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 524k dense-KV decode is "
                       "out of regime; skipped per assignment note")
    return True, ""


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.SMOKE_CONFIG
