"""nemotron-4-340b [dense]: GQA 96q/8kv, squared-ReLU MLP.

[arXiv:2402.16819; unverified]  96L d_model=18432 96H (kv=8) d_ff=73728
vocab=256000.  head_dim = 192.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron_4_340b", family="dense", num_layers=96, d_model=18432,
    num_heads=96, num_kv_heads=8, d_ff=73728, vocab_size=256000,
    head_dim=192, mlp_act="relu2", norm="layernorm", use_rope=True,
    train_microbatches=8, seq_parallel=True,
    param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="nemotron_smoke", num_layers=2, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=512, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32")
