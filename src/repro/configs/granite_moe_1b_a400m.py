"""granite-moe-1b-a400m [moe]: 32 experts, top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  24L d_model=1024 16H (kv=8)
d_ff=512/expert vocab=49155.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite_moe_1b_a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=512, vocab_size=49155,
    num_experts=32, experts_per_token=8, mlp_act="swiglu",
    train_microbatches=4, serve_param_fsdp=False,
    param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="granite_smoke", num_layers=2, d_model=128, num_heads=8,
    num_kv_heads=4, d_ff=128, vocab_size=512, num_experts=8,
    experts_per_token=2, param_dtype="float32", compute_dtype="float32")
