"""qwen1.5-110b [dense]: GQA 64q/8kv, SwiGLU, QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]  80L d_model=8192 64H (kv=8) d_ff=49152
vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1_5_110b", family="dense", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=49152, vocab_size=152064,
    mlp_act="swiglu", qkv_bias=True, train_microbatches=8,
    seq_parallel=True, param_dtype="bfloat16",
    compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="qwen_smoke", num_layers=2, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=384, vocab_size=512,
    param_dtype="float32", compute_dtype="float32")
