"""whisper-medium [audio, enc-dec]: conv frontend STUBBED.

[arXiv:2212.04356; unverified]  24L (dec) d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865.  Encoder depth 24; input_specs supplies precomputed
frame embeddings (B, S, 1024).  MHA (kv=16 == heads), LayerNorm, GeLU,
learned positions in the real model -> we keep RoPE off.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium", family="encdec", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=51865,
    encoder_layers=24, mlp_act="gelu", norm="layernorm", use_rope=False,
    train_microbatches=4,
    param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="whisper_smoke", num_layers=2, encoder_layers=2, d_model=128,
    num_heads=8, num_kv_heads=8, d_ff=256, vocab_size=512,
    param_dtype="float32", compute_dtype="float32")
