"""hymba-1.5b [hybrid]: parallel attention + Mamba heads per layer.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  25 heads not divisible by 16 -> attn params FSDP-only.
Sub-quadratic long context: sliding-window attention (4096) + SSM state,
so long_500k decode runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba_1_5b", family="hybrid", num_layers=32, d_model=1600,
    num_heads=25, num_kv_heads=5, d_ff=5504, vocab_size=32001,
    ssm_state=16, sliding_window=4096, subquadratic=True, attn_tp=False,
    train_microbatches=4, serve_param_fsdp=False,
    mlp_act="swiglu", param_dtype="bfloat16", compute_dtype="bfloat16")

SMOKE_CONFIG = CONFIG.replace(
    name="hymba_smoke", num_layers=2, d_model=160, num_heads=5,
    num_kv_heads=1, d_ff=384, vocab_size=512, ssm_state=8,
    sliding_window=64, param_dtype="float32", compute_dtype="float32")
