"""Sharding rules: FSDP (ZeRO-3) x TP (Megatron) x EP x decode-KV context
parallelism, expressed as PartitionSpecs over the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  * FSDP: every weight's non-TP giant dim is sharded over ("pod","data");
    GSPMD inserts the use-site all-gather and grad reduce-scatter.
  * TP: attention Q/O over heads (when divisible and cfg.attn_tp), FFN
    hidden over `model`, vocab/logits over `model`; GQA KV projections are
    small and stay replicated over `model`.
  * EP: MoE expert dim over `model`.
  * Decode caches: sequence/time dim over `model` (context parallelism) —
    the softmax/LSE merge across shards is derived by the partitioner from
    the reduction structure of decode_attention.
Dims that do not divide the axis size stay unsharded (exception: the vocab
dim may shard unevenly; XLA pads).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# jax >= 0.5 promotes shard_map to the top level; fall back to experimental.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map

STACK_KEYS = ("blocks", "enc_blocks", "dec_blocks")


def fsdp_axes(mesh: Mesh):
    """FSDP sharding entry: ('pod', 'data') multi-pod, bare 'data' otherwise
    (a singleton tuple and the bare name shard identically; the bare name
    keeps PartitionSpecs canonical for comparison/printing)."""
    return (("pod", "data") if "pod" in mesh.axis_names else "data")


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def _div(dim: int, mesh: Mesh, axes) -> Optional[Any]:
    """Return axes if dim divides the axes size, else None (no sharding)."""
    return axes if dim % axis_size(mesh, axes) == 0 else None


def _param_spec(name: str, shape: Tuple[int, ...], cfg: ModelConfig,
                mesh: Mesh, stacked: bool) -> P:
    """Sharding rule for one parameter by name/rank."""
    F = fsdp_axes(mesh)
    M = "model"
    body = shape[1:] if stacked else shape

    def spec(*parts):
        parts = tuple(_div(body[i], mesh, parts[i]) for i in range(len(parts)))
        return P(*((None,) + parts if stacked else parts))

    r = len(body)
    attn_tp = cfg.attn_tp
    if name in ("embed",):
        # (V, d): vocab over model (when divisible), d over FSDP
        return P(_div(body[0], mesh, M), _div(body[1], mesh, F))
    if name in ("lm_head",):
        return P(_div(body[0], mesh, F), _div(body[1], mesh, M))
    if name in ("wq",) and r == 3:          # (d, H, hd)
        return spec(F, M if attn_tp else None, None)
    if name in ("wk", "wv") and r == 3:     # (d, K, hd): KV replicated on M
        return spec(F, None, None)
    if name == "wo" and r == 3:             # (H, hd, d)
        return spec(M if attn_tp else None, None, F)
    if name == "bq":
        return spec(M if attn_tp else None, None)
    if name in ("bk", "bv"):
        return spec(None, None)
    if name in ("w_gate", "w_up", "w_in") and r == 2:    # (d, f)
        return spec(F, M)
    if name in ("w_down", "w_out") and r == 2:           # (f, d)
        return spec(M, F)
    if name in ("w_gate", "w_up", "w_in") and r == 3:    # MoE (E, d, f)
        return spec(M, F, None)
    if name in ("w_down", "w_out") and r == 3:           # MoE (E, f, d)
        return spec(M, None, F)
    if name == "router":
        return spec(F, None)
    # SSM branch
    if name in ("w_in_ssm", "w_z"):
        return spec(F, M)
    if name == "w_bc" or name == "w_dt":
        return spec(M, None)
    if name == "a_log":
        return spec(M, None)
    if name == "d_skip":
        return spec(M)
    # RWKV
    if name in ("wr", "wk2", "wv2", "wd", "cr"):
        return spec(F, M)
    if name == "ck":
        return spec(F, M)
    if name == "cv":
        return spec(M, F)
    # Norms, mixes, small vectors: replicated.
    return P(*((None,) * len(shape)))


# Names that collide between modules get disambiguated by their parent key.
_RENAME_BY_PARENT = {
    ("ssm", "w_in"): "w_in_ssm",
    ("ssm", "w_out"): "w_out_ssm",
}
_RWKV_RENAME = {"wk": "wk2", "wv": "wv2", "wo": "wo2"}


def _leaf_name(path) -> Tuple[str, Tuple[str, ...]]:
    keys = [k.key for k in path if hasattr(k, "key")]
    return keys[-1], tuple(keys)


def param_pspecs(cfg: ModelConfig, abstract_params: Dict, mesh: Mesh) -> Dict:
    """PartitionSpec pytree matching the params pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for path, leaf in flat:
        name, keys = _leaf_name(path)
        stacked = any(k in STACK_KEYS for k in keys)
        parent = keys[-2] if len(keys) >= 2 else ""
        if (parent, name) in _RENAME_BY_PARENT:
            name = _RENAME_BY_PARENT[(parent, name)]
        if cfg.block_type == "rwkv" and name in _RWKV_RENAME:
            name = _RWKV_RENAME[name]
        # rwkv wo2 (d,d): shard (M, F) like an output proj
        if name == "wo2":
            body = leaf.shape[1:] if stacked else leaf.shape
            s = (("model" if body[0] % axis_size(mesh, "model") == 0
                  else None),
                 (fsdp_axes(mesh) if body[1] % axis_size(
                     mesh, fsdp_axes(mesh)) == 0 else None))
            specs.append(P(*((None,) + s if stacked else s)))
            continue
        if name == "w_out_ssm":
            body = leaf.shape[1:] if stacked else leaf.shape
            s = (("model" if body[0] % axis_size(mesh, "model") == 0
                  else None),
                 (fsdp_axes(mesh) if body[1] % axis_size(
                     mesh, fsdp_axes(mesh)) == 0 else None))
            specs.append(P(*((None,) + s if stacked else s)))
            continue
        specs.append(_param_spec(name, leaf.shape, cfg, mesh, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(cfg: ModelConfig, spec_tree: Dict, mesh: Mesh) -> Dict:
    """Input batch sharding: global batch over FSDP axes (when divisible —
    long_500k has global_batch=1, which stays replicated)."""
    F = fsdp_axes(mesh)

    def one(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(*((_div(leaf.shape[0], mesh, F),) + (None,) * (nd - 1)))

    return jax.tree_util.tree_map_with_path(one, spec_tree)


def cache_pspecs(cfg: ModelConfig, abstract_caches, mesh: Mesh) -> Dict:
    """Decode caches: batch over FSDP, sequence/state dim over `model`.

    Layouts (stacked leading L):
      kv k/v   (L, B, T, K, hd)   -> (None, F, M, None, None)
      ssm      (L, B, di, n)      -> (None, F, M, None)
      rwkv wkv (L, B, H, D, D)    -> (None, F, M, None, None)
      shifts   (L, B, d)          -> (None, F, M-if-divisible)
      cross xk (L, B, S, K, hd)   -> (None, F, M, None, None)
    """
    F = fsdp_axes(mesh)
    M = "model"

    def one(path, leaf):
        name, _ = _leaf_name(path)
        shp = leaf.shape
        nd = len(shp)
        if nd == 5:                      # (L,B,T,K,hd) or (L,B,H,D,D)
            return P(None, _div(shp[1], mesh, F), _div(shp[2], mesh, M),
                     None, None)
        if nd == 4:                      # ssm (L,B,di,n)
            return P(None, _div(shp[1], mesh, F), _div(shp[2], mesh, M),
                     None)
        if nd == 3:                      # shift (L,B,d)
            return P(None, _div(shp[1], mesh, F), _div(shp[2], mesh, M))
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(one, abstract_caches)


def logical_out_pspec(mesh: Mesh) -> P:
    return P(fsdp_axes(mesh), "model")        # logits (B, V)


def _strip_fsdp(spec: P, drop_leading: bool) -> P:
    """Remove FSDP ('pod'/'data') axes from a spec; optionally drop the
    leading (layer-stack) entry — the use-site spec for one scanned layer."""
    entries = tuple(spec)
    if drop_leading and entries:
        entries = entries[1:]

    def strip(a):
        if a is None:
            return None
        axes = (a,) if isinstance(a, str) else tuple(a)
        kept = tuple(x for x in axes if x not in ("pod", "data"))
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    return P(*(strip(a) for a in entries))


def use_pspecs(cfg: ModelConfig, abstract_params: Dict, mesh: Mesh) -> Dict:
    """Use-site sharding for parameters: ZeRO-3 semantics.

    Parameters are *stored* FSDP-sharded (param_pspecs) but must be
    *consumed* gathered over the FSDP axes (TP sharding kept).  Without
    these hints GSPMD may instead partially contract against the FSDP-
    sharded weight and all-reduce the activations over `data` every layer
    (observed: 39 GiB/layer on nemotron train_4k — see EXPERIMENTS §Perf).
    Leaves keep the layer-stack dim dropped: hints apply inside the scan.
    """
    pflat = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        param_pspecs(cfg, abstract_params, mesh))
    out = []
    for (path, spec), (_, leaf) in zip(flat, pflat):
        keys = [k.key for k in path if hasattr(k, "key")]
        stacked = any(k in STACK_KEYS for k in keys)
        name = keys[-1] if keys else ""
        rank = len(leaf.shape) - (1 if stacked else 0)
        if cfg.num_experts and rank == 3 and name in (
                "w_gate", "w_up", "w_in", "w_down", "w_out"):
            # MoE expert tensors: a gather hint here gets hoisted out of
            # the layer scan by XLA and materializes the WHOLE gathered
            # expert stack (arctic prefill: +106 GiB/chip — §Perf P3).
            # Leave experts to GSPMD's partial-contraction strategy.
            # ("skip" sentinel: None would vanish as an empty pytree.)
            out.append("skip")
            continue
        out.append(_strip_fsdp(spec, drop_leading=stacked))
    return jax.tree_util.tree_unflatten(treedef, out)


def named(mesh: Mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


def constrain_activations(x: jax.Array, mesh: Mesh,
                          seq_parallel: bool = False) -> jax.Array:
    """Sharding hint for (B, S, d) activations inside the step function."""
    F = fsdp_axes(mesh)
    if seq_parallel:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(F, "model", None)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(F, None, None)))


# ---------------------------------------------------------------------------
# Collision service: shard the canonical flat pair pool (DESIGN.md §6)
# ---------------------------------------------------------------------------

#: The collision mesh's single axis: the flat query pool is split over it,
#: the scene octree is replicated on every device.
COLLISION_AXIS = "shard"


def make_collision_mesh(shards: int) -> Mesh:
    """1-D mesh of ``shards`` devices for sharded collision traversal."""
    devs = jax.devices()
    if not 1 <= shards <= len(devs):
        raise ValueError(
            f"collision mesh wants {shards} device(s) but the backend "
            f"exposes {len(devs)}")
    return Mesh(devs[:shards], (COLLISION_AXIS,))


def shard_collision_traversal(fn, mesh: Mesh):
    """shard_map a single-scene traversal over the collision mesh.

    ``fn(num_valid, c, h, r, dev) -> (verdict, stats)`` is the per-device
    traversal body; the wrapper maps it over :data:`COLLISION_AXIS` with
    the (padded) query pool split into equal contiguous blocks and the
    scene tables replicated, then reduces the stats dict so the caller
    sees the same values a single-device run would produce:

      * every work counter is summed over shards (traversal of each query
        is independent, so partitioning the pool partitions the sums —
        bitwise equality, CI-enforced);
      * ``overflow`` takes the **global max** over per-shard overflow
        flags — the executor's escalation loop replays ALL shards at 4x
        capacity as soon as any one of them spilled, keeping the replay
        ladder (and therefore the traced capacities) globally coordinated.

    The wrapped callable takes ``(counts (shards,) int32, c, h, r, dev)``
    and returns the still-sharded verdict plus the reduced stats with a
    leading shard axis of identical rows (the traversal's ``while_loop``
    has no shard_map replication rule, so the wrapper runs with
    ``check_rep=False`` and cannot declare replicated ``P()`` outputs —
    callers read row 0).
    """
    axis = COLLISION_AXIS

    def local(counts, c, h, r, dev):
        verdict, st = fn(counts[0], c, h, r, dev)
        red = {k: (jax.lax.pmax(v, axis) if k == "overflow"
                   else jax.lax.psum(v, axis))[None]
               for k, v in st.items()}
        return verdict, red

    return shard_map(local, mesh=mesh,
                     in_specs=(P(axis), P(axis), P(axis), P(axis), P()),
                     out_specs=(P(axis), P(axis)), check_rep=False)
