"""Gradient compression for the DP all-reduce: int8 + error feedback.

Used by the manual-DP trainer variant (shard_map over the data axis): each
shard quantizes its local gradient to int8 with a per-tensor scale, psums
the int8 payload (decoded), and keeps the quantization residual locally,
adding it back before the next step (error feedback), which preserves
convergence (Seide et al.; 1-bit Adam lineage).  Cuts DP gradient traffic
4x vs f32 / 2x vs bf16.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads, residuals, axis_name: str):
    """Inside shard_map: all-reduce int8-quantized grads with error feedback.

    Returns (mean_grads, new_residuals).  Residual pytree has grad shapes.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        new_r = g32 - deq                       # local quantization error
        summed = jax.lax.psum(deq, axis_name)
        n = jax.lax.psum(jnp.ones(()), axis_name)
        return (summed / n).astype(g.dtype), new_r

    out = jax.tree.map(one, grads, residuals)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return mean, res


def init_residuals(params) -> Dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
