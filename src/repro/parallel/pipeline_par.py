"""GPipe-style pipeline parallelism over a mesh axis (optional feature).

Layers are split into ``n_stages`` contiguous groups; microbatches flow
through stages via ``jax.lax.ppermute`` inside shard_map.  The schedule is
the classic GPipe loop with (n_micro + n_stages - 1) ticks; each tick every
stage processes one resident microbatch and then the ring rotates
activations forward.  Intended for the `pod` axis on the multi-pod mesh
(cross-DCN traffic = one activation tensor per tick), as an alternative to
pure FSDP over pods.  Forward-only demonstration + tests; the training path
in this repo uses FSDP/TP which covers the assigned cells.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _pcast(x, axis_names, to="varying"):
    """jax.lax.pcast when available (varying-type marking for the new
    shard_map); identity on older jax, whose shard_map has no varying
    check."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, axis_names, to=to)


def pipeline_forward(stage_fn: Callable, n_stages: int, n_micro: int,
                     axis_name: str):
    """Build a shard_map-able pipelined forward.

    stage_fn(stage_params, x) -> x, applied by each stage to its resident
    microbatch.  Inputs inside shard_map: stage_params (this stage's layer
    stack), microbatches (n_micro, mb, ...) resident on stage 0.
    """

    def fn(stage_params, micro):
        stage = jax.lax.axis_index(axis_name)
        mb_shape = micro.shape[1:]
        n_ticks = n_micro + n_stages - 1
        # `current` holds the activation resident on this stage this tick.
        # pcast marks the carries as varying over the stage axis (their
        # values genuinely differ per stage once the ring rotates).
        current = _pcast(jnp.zeros(mb_shape, micro.dtype),
                         (axis_name,), to="varying")
        outputs = _pcast(
            jnp.zeros((n_micro,) + mb_shape, micro.dtype),
            (axis_name,), to="varying")

        def tick(t, carry):
            current, outputs = carry
            # Stage 0 injects microbatch t (if any remain).
            inject = jax.lax.dynamic_index_in_dim(
                micro, jnp.minimum(t, n_micro - 1), 0, keepdims=False)
            current = jnp.where((stage == 0) & (t < n_micro), inject,
                                current)
            # Every stage applies its layers to its resident activation.
            current = stage_fn(stage_params, current)
            # Last stage emits output for microbatch (t - n_stages + 1).
            # Predicated update (a lax.cond here trips shard_map's varying-
            # type check across branches).
            out_idx = t - (n_stages - 1)
            emit = (stage == n_stages - 1) & (out_idx >= 0)
            updated = jax.lax.dynamic_update_index_in_dim(
                outputs, current, jnp.maximum(out_idx, 0), 0)
            outputs = jnp.where(emit, updated, outputs)
            # Rotate the ring: stage i -> stage i+1.
            current = jax.lax.ppermute(
                current, axis_name,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return current, outputs

        _, outputs = jax.lax.fori_loop(0, n_ticks, tick,
                                       (current, outputs))
        # Outputs live on stage n-1; broadcast so every stage returns them.
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, 0.0), axis_name)
        return outputs

    return fn


def run_pipelined(mesh: Mesh, axis_name: str, stage_fn: Callable,
                  stacked_params, micro: jax.Array, n_stages: int):
    """Convenience wrapper: shard params/layers over the stage axis and run.

    stacked_params leaves have leading dim n_stages (one slice per stage).
    micro: (n_micro, mb, ...) global.
    """
    n_micro = micro.shape[0]
    fn = pipeline_forward(stage_fn, n_stages, n_micro, axis_name)
    pspec = jax.tree.map(lambda _: P(axis_name), stacked_params)
    sm = shard_map(
        lambda p, m: fn(jax.tree.map(lambda a: a[0], p), m),
        mesh=mesh,
        in_specs=(pspec, P()),
        out_specs=P(),
    )
    return sm(stacked_params, micro)
