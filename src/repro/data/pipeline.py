"""Synthetic LM data pipeline: host-sharded, deterministic, prefetchable.

Produces the batch dicts of models/api.batch_spec.  Synthetic but
structured (Zipf-ish marginals + short-range correlations) so losses
decrease meaningfully in the examples.  At multi-host scale each process
generates only its local shard (seeded by (step, host)); here host count
is 1 but the slicing logic is exercised by tests.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


def _zipf_tokens(rs: np.random.RandomState, shape, vocab: int) -> np.ndarray:
    """Zipf marginal + Markov-ish repetition for learnable structure."""
    u = rs.uniform(size=shape)
    toks = np.minimum((vocab * (u ** 2.5)).astype(np.int64), vocab - 1)
    # repeat previous token with p=0.3 to create local structure
    rep = rs.uniform(size=shape) < 0.3
    toks[..., 1:] = np.where(rep[..., 1:], toks[..., :-1], toks[..., 1:])
    return toks.astype(np.int32)


def synth_batch(cfg: ModelConfig, shape: ShapeSpec, step: int,
                host_index: int = 0, host_count: int = 1,
                batch_override: Optional[int] = None,
                seq_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    """One (host-local) batch for `step`; deterministic in (step, host)."""
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    assert B % host_count == 0
    Bl = B // host_count
    rs = np.random.RandomState((step * 1000003 + host_index * 7919) %
                               (2 ** 31 - 1))
    toks = _zipf_tokens(rs, (Bl, S + 1), cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["patch_embeds"] = rs.normal(
            size=(Bl, cfg.num_patches, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        batch["frames"] = rs.normal(size=(Bl, S, cfg.d_model)
                                    ).astype(np.float32)
    return batch


def batch_iterator(cfg: ModelConfig, shape: ShapeSpec, start_step: int = 0,
                   **kw) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synth_batch(cfg, shape, step, **kw)
        step += 1
