"""Synthetic reconstructions of the MpiNet evaluation environments.

The MpiNet dataset is not available offline; we procedurally rebuild the four
environment families of Table III (Cubby, Dresser, Merged Cubby, Tabletop)
as box-obstacle scenes, sample 524 288 surface points (same count as the
paper), and generate robot-arm trajectories whose link OBB counts land in the
paper's range (9.8k–32k).  Also provides the smaller MPAccel-style scenarios
(Fig. 14): 10 sparse scenes x 100 start/goal pairs.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core.geometry import OBBs, trajectory_obbs

ENVIRONMENTS = ("cubby", "dresser", "merged_cubby", "tabletop")

#: Panda-like joint limits used for every sampled configuration (scene
#: trajectories, PRM edge batches in benchmarks/tests).
PANDA_JOINT_LO = np.asarray([-2.8, -1.7, -2.8, -3.0, -2.8, 0.0, -2.8],
                            np.float32)
PANDA_JOINT_HI = np.asarray([2.8, 1.7, 2.8, -0.1, 2.8, 3.7, 2.8],
                            np.float32)


@dataclasses.dataclass(frozen=True)
class Scene:
    name: str
    points: np.ndarray          # (P, 3) surface point cloud
    boxes_lo: np.ndarray        # (B, 3) ground-truth obstacle AABBs
    boxes_hi: np.ndarray        # (B, 3)
    robot_base: np.ndarray      # (3,)


def _sample_box_surfaces(rs: np.random.RandomState, lo: np.ndarray,
                         hi: np.ndarray, n: int) -> np.ndarray:
    """Sample n points uniformly (area-weighted) on the faces of B boxes."""
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    size = hi - lo                                       # (B, 3)
    areas = 2 * (size[:, 0] * size[:, 1] + size[:, 1] * size[:, 2]
                 + size[:, 0] * size[:, 2])
    pbox = areas / areas.sum()
    box = rs.choice(len(lo), size=n, p=pbox)
    u = rs.uniform(size=(n, 3)).astype(np.float32)
    pts = lo[box] + u * size[box]
    # project each point to a random face (axis + side), area-weighted per box
    s = size[box]
    face_area = np.stack([s[:, 1] * s[:, 2], s[:, 0] * s[:, 2],
                          s[:, 0] * s[:, 1]], -1)
    face_area = face_area / face_area.sum(-1, keepdims=True)
    axis = np.array([rs.choice(3, p=fa) for fa in face_area]) if n < 20000 \
        else _vector_choice(rs, face_area)
    side = rs.randint(0, 2, n)
    rows = np.arange(n)
    pts[rows, axis] = np.where(side == 1, hi[box, :][rows, axis],
                               lo[box, :][rows, axis])
    return pts


def _vector_choice(rs: np.random.RandomState, probs: np.ndarray) -> np.ndarray:
    """Vectorized categorical sampling over rows of probs (n, k)."""
    c = np.cumsum(probs, -1)
    u = rs.uniform(size=(len(probs), 1)).astype(np.float32)
    return (u > c[:, :-1]).sum(-1)


def _cubby_boxes(rs, origin=(0.45, -0.5, 0.0), n_rows=3, n_cols=3,
                 cw=0.32, ch=0.30, depth=0.35, t=0.02):
    """Shelf with n_rows x n_cols open compartments."""
    ox, oy, oz = origin
    W = n_cols * cw + (n_cols + 1) * t
    H = n_rows * ch + (n_rows + 1) * t
    los, his = [], []
    # back panel
    los.append([ox + depth, oy, oz]); his.append([ox + depth + t, oy + W, oz + H])
    # horizontal slabs
    for r in range(n_rows + 1):
        z = oz + r * (ch + t)
        los.append([ox, oy, z]); his.append([ox + depth, oy + W, z + t])
    # vertical dividers
    for c_ in range(n_cols + 1):
        y = oy + c_ * (cw + t)
        los.append([ox, y, oz]); his.append([ox + depth, y + t, oz + H])
    return np.asarray(los, np.float32), np.asarray(his, np.float32)


def _dresser_boxes(rs, origin=(0.5, -0.45, 0.0), w=0.9, d=0.4, h=0.85,
                   n_drawers=4, t=0.02):
    ox, oy, oz = origin
    los, his = [], []
    los.append([ox + d, oy, oz]); his.append([ox + d + t, oy + w, oz + h])
    los.append([ox, oy, oz]); his.append([ox + d, oy + t, oz + h])       # side
    los.append([ox, oy + w - t, oz]); his.append([ox + d, oy + w, oz + h])
    los.append([ox, oy, oz + h - t]); his.append([ox + d, oy + w, oz + h])
    los.append([ox, oy, oz]); his.append([ox + d, oy + w, oz + t])       # base
    for k in range(1, n_drawers):
        z = oz + k * h / n_drawers
        # partially open drawer fronts (slabs sticking out)
        pull = 0.05 + 0.1 * rs.uniform()
        los.append([ox - pull, oy + t, z - t])
        his.append([ox, oy + w - t, z + t])
    return np.asarray(los, np.float32), np.asarray(his, np.float32)


def _tabletop_boxes(rs, n_objects=9):
    los = [[0.30, -0.55, 0.30]]
    his = [[0.95, 0.55, 0.34]]                      # table slab
    for _ in range(n_objects):
        sx, sy, sz = rs.uniform(0.04, 0.22, 3)
        x = rs.uniform(0.32, 0.9 - sx)
        y = rs.uniform(-0.5, 0.5 - sy)
        los.append([x, y, 0.34])
        his.append([x + sx, y + sy, 0.34 + sz])
    return np.asarray(los, np.float32), np.asarray(his, np.float32)


def make_scene(name: str, seed: int = 0, num_points: int = 524288) -> Scene:
    rs = np.random.RandomState(seed + hash(name) % 1000)
    if name == "cubby":
        lo, hi = _cubby_boxes(rs)
    elif name == "dresser":
        lo, hi = _dresser_boxes(rs)
    elif name == "merged_cubby":
        lo1, hi1 = _cubby_boxes(rs)
        lo2, hi2 = _cubby_boxes(rs, origin=(0.45, 0.55, 0.0))
        lo, hi = np.concatenate([lo1, lo2]), np.concatenate([hi1, hi2])
    elif name == "tabletop":
        lo, hi = _tabletop_boxes(rs)
    else:
        raise ValueError(name)
    pts = _sample_box_surfaces(rs, lo, hi, num_points)
    return Scene(name=name, points=pts, boxes_lo=lo, boxes_hi=hi,
                 robot_base=np.asarray([0.0, 0.0, 0.0], np.float32))


def scene_trajectories(scene: Scene, num_trajectories: int = 25,
                       waypoints: int = 60, seed: int = 0) -> OBBs:
    """Random joint-space trajectories -> link OBBs (paper Table III scale:
    num_trajectories * waypoints * 7 links OBBs)."""
    rs = np.random.RandomState(seed)
    lo, hi = PANDA_JOINT_LO, PANDA_JOINT_HI
    all_obbs: List[OBBs] = []
    for _ in range(num_trajectories):
        q0 = rs.uniform(lo, hi).astype(np.float32)
        q1 = rs.uniform(lo, hi).astype(np.float32)
        all_obbs.append(trajectory_obbs(jnp.asarray(q0), jnp.asarray(q1),
                                        waypoints,
                                        base_pos=jnp.asarray(scene.robot_base)))
    return OBBs(
        center=jnp.concatenate([o.center for o in all_obbs]),
        half=jnp.concatenate([o.half for o in all_obbs]),
        rot=jnp.concatenate([o.rot for o in all_obbs]))


def make_mpaccel_scenario(idx: int, num_points: int = 65536) -> Scene:
    """Small sparse scenes in the style of MPAccel (paper Fig. 14)."""
    rs = np.random.RandomState(1000 + idx)
    n_obs = rs.randint(3, 7)
    los, his = [], []
    for _ in range(n_obs):
        s = rs.uniform(0.05, 0.25, 3)
        c = rs.uniform(-0.7, 0.7, 3) + np.array([0.6, 0.0, 0.4])
        los.append(c - s / 2)
        his.append(c + s / 2)
    lo = np.asarray(los, np.float32)
    hi = np.asarray(his, np.float32)
    pts = _sample_box_surfaces(rs, lo, hi, num_points)
    return Scene(name=f"mpaccel_{idx}", points=pts, boxes_lo=lo, boxes_hi=hi,
                 robot_base=np.asarray([0.0, 0.0, 0.0], np.float32))
