"""Training launcher: checkpointed, preemption-safe, straggler-tolerant.

CPU-runnable at smoke scale (the default) and mesh-ready at production
scale: the same code path lowers for the 256/512-chip meshes in the
dry-run.

  PYTHONPATH=src python -m repro.lm.train --arch glm4_9b --steps 20
  ... --resume            # continue from the latest committed checkpoint
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from repro.configs.base import ShapeSpec, get_config, get_smoke_config
from repro.data.pipeline import batch_iterator
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models import api
from repro.parallel import sharding as shd
from repro.train import checkpoint as ckpt_mod
from repro.train import ft
from repro.train import optimizer as opt_mod
from repro.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4_9b")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: smoke config)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full
           else get_smoke_config(args.arch))
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    opt_cfg = opt_mod.OptConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(1, args.steps // 10))
    mesh = make_host_mesh()
    step_fn, pspecs, ospecs, bspecs = train_loop.make_sharded_train_step(
        cfg, mesh, opt_cfg, shape, num_microbatches=args.microbatches)

    ckpt_dir = os.path.join(args.ckpt_dir, cfg.name)
    start = 0
    with use_mesh(mesh):
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, shd.named(mesh, pspecs))
        opt_state = opt_mod.init_opt_state(params, opt_cfg)
        if args.resume:
            state = {"params": params, "opt": opt_state}
            restored, step = ckpt_mod.restore_checkpoint(
                ckpt_dir, state,
                shardings={"params": shd.named(mesh, pspecs),
                           "opt": shd.named(mesh, ospecs)})
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                start = step + 1
                print(f"resumed from step {step}")

        guard = ft.PreemptionGuard().install()
        loader = ft.PrefetchingLoader(
            batch_iterator(cfg, shape, start_step=start))
        writer = None
        for step in range(start, args.steps):
            batch = loader.next_batch()
            batch = jax.device_put(batch, shd.named(mesh, bspecs))
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % args.log_every == 0:
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                print(f"step {step} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms skipped={loader.skipped}",
                      flush=True)
            if (step % args.ckpt_every == args.ckpt_every - 1
                    or guard.should_checkpoint):
                writer = ckpt_mod.save_checkpoint(
                    ckpt_dir, step, {"params": params, "opt": opt_state})
                if guard.should_checkpoint:
                    print("preemption: checkpointed, exiting")
                    break
        if writer is not None:
            writer.join()
    print("done")


if __name__ == "__main__":
    main()
