import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# NOTE: the two lines above MUST run before any jax-importing module:
# jax locks the device count at first init.  Everything else imports below.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real SPMD step function (train_step /
prefill_step / serve_step) against ShapeDtypeStruct inputs (no allocation),
compiles it for the production mesh, prints memory_analysis / cost_analysis,
and records the roofline-relevant numbers to
benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  PYTHONPATH=src python -m repro.lm.dryrun               # all cells
  ... --arch glm4_9b --shape train_4k --mesh single          # one cell
  ... --force                                                # recompute
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import (ARCH_REGISTRY, SHAPES, get_config,
                                shape_applicable)
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models import api
from repro.roofline import analysis as ra
from repro.train import optimizer as opt_mod
from repro.train import train_loop

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _opt_config(cfg) -> opt_mod.OptConfig:
    big = cfg.num_params > 20e9
    return opt_mod.OptConfig(state_dtype="bfloat16" if big else "float32")


def _apply_overrides(cfg, overrides: dict):
    if not overrides:
        return cfg
    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in ("1", "true", "True") if isinstance(v, str) \
                else bool(v)
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return cfg.replace(**typed)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Lower + compile one cell; returns (compiled, cost, chips, kind)."""
    from repro.roofline.jaxpr_cost import jaxpr_cost as jcost
    cfg = _apply_overrides(get_config(arch), overrides or {})
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    aparams = api.abstract_params(cfg)

    with use_mesh(mesh):
        if shape.kind == "train":
            step, pspecs, ospecs, bspecs = train_loop.make_sharded_train_step(
                cfg, mesh, _opt_config(cfg), shape,
                num_microbatches=cfg.train_microbatches)
            aopt = jax.eval_shape(
                lambda p: opt_mod.init_opt_state(p, _opt_config(cfg)),
                aparams)
            abatch = api.batch_spec(cfg, shape)
            traced = step.trace(aparams, aopt, abatch)
        elif shape.kind == "prefill":
            fn, pspecs, bspecs = train_loop.make_sharded_prefill(cfg, mesh,
                                                                 shape)
            abatch = api.batch_spec(cfg, shape)
            traced = fn.trace(aparams, abatch)
        else:  # decode
            fn, pspecs, cspecs = train_loop.make_sharded_decode(cfg, mesh,
                                                                shape)
            acaches = api.abstract_caches(cfg, shape)
            dspec = api.decode_input_spec(cfg, shape)
            traced = fn.trace(aparams, dspec["token"], dspec["pos"],
                              acaches)
        cost = jcost(traced.jaxpr)
        compiled = traced.lower().compile()
    return compiled, cost, chips, shape.kind


def run_cell(arch: str, shape_name: str, mesh_name: str, force: bool,
             out_dir: str, overrides: dict | None = None,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag
                                                      else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": reason}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    try:
        compiled, cost, chips, kind = lower_cell(arch, shape_name,
                                                 mesh_name == "multi",
                                                 overrides)
        mem = compiled.memory_analysis()
        print(f"[{cell_id}] memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        print(f"[{cell_id}] cost_analysis(once-per-loop) "
              f"flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}; "
              f"jaxpr loop-aware flops={cost.flops:.3e} "
              f"bytes={cost.bytes:.3e}")
        terms = ra.analyze_compiled(compiled, chips, jaxpr_cost=cost)
        mf = ra.model_flops(cfg, shape, backward=(kind == "train"))
        rec = {
            "cell": cell_id, "status": "ok", "arch": arch,
            "shape": shape_name, "mesh": mesh_name, "kind": kind,
            "chips": chips, "compile_s": time.time() - t0,
            "model_flops": mf,
            "useful_flops_ratio": (mf / terms.total_flops
                                   if terms.total_flops else 0.0),
            **terms.as_dict(),
        }
    except Exception as e:  # sharding bug, OOM at compile, etc.
        traceback.print_exc()
        rec = {"cell": cell_id, "status": "error", "error": repr(e)[:2000],
               "compile_s": time.time() - t0}
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec.get("dominant", rec.get("error", rec.get("reason", "")))
    print(f"[{cell_id}] {rec['status']} ({rec.get('compile_s', 0):.1f}s) "
          f"-> {status}", flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (hillclimb variants)")
    ap.add_argument("--tag", default="", help="suffix for variant cells")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    overrides = dict(kv.split("=", 1) for kv in args.override)

    n_dev = len(jax.devices())
    assert n_dev == 512, f"expected 512 host devices, got {n_dev}"

    archs = [args.arch] if args.arch else list(ARCH_REGISTRY)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape, mesh_name, args.force, args.out,
                               overrides=overrides, tag=args.tag)
                failures += rec["status"] == "error"
    print(f"dry-run complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
