"""JIT wrapper for the tiled ball-query kernel."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ballquery.kernel import make_ballquery_call


@functools.partial(jax.jit, static_argnames=("radius", "k", "bm", "bn",
                                             "interpret"))
def ball_query_tiled(queries: jax.Array, points: jax.Array, radius: float,
                     k: int, bm: int = 64, bn: int = 128,
                     interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Dense tiled ball query: (idx (M,k) int32 [-1 padded], count (M,)).

    Point padding sits at 1e9 so it never hits; query padding likewise.
    """
    M, N = queries.shape[0], points.shape[0]
    qp = jnp.pad(queries.astype(jnp.float32), (((0, (-M) % bm), (0, 0))),
                 constant_values=1e9)
    pp = jnp.pad(points.astype(jnp.float32), (((0, (-N) % bn), (0, 0))),
                 constant_values=-1e9)
    call = make_ballquery_call(qp.shape[0], pp.shape[0], bm, bn,
                               float(radius), int(k), interpret)
    cnt, idx = call(qp, pp)
    return idx[:M], jnp.minimum(cnt[:M], k)
