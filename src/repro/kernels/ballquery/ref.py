"""Oracle for the tiled ball-query kernel: the core brute-force reference."""
from repro.core.ballquery import ball_query_ref  # noqa: F401
