"""Tiled fixed-radius neighbor search with per-tile early stop (Pallas).

RoboGPU §IV: ball query on RoboCore wins because (a) the custom intersection
program runs inside the accelerator instead of bouncing to shader cores, and
(b) traversal stops once a query's neighbor group is full.  This kernel is
the dense-tile analogue: the grid walks point blocks sequentially for each
query block, neighbor lists accumulate in a VMEM-resident output block, and a
tile whose queries are ALL full skips its distance stage entirely
(`lax.cond` — the tile-granular conditional return).

Matches `ball_query_ref` exactly (first-k by ascending point index) because
point blocks are visited in ascending order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def ballquery_kernel(q_ref, p_ref, cnt_ref, idx_ref, *, radius: float,
                     k: int, bn: int):
    j = pl.program_id(1)
    bm = q_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    cnt = cnt_ref[...]

    def tile(cnt):
        # d2[a, b] = |q_a - p_b|^2, component-unrolled (3-vectors).
        d2 = jnp.zeros((bm, bn), jnp.float32)
        for c in range(3):
            d = q_ref[:, c][:, None] - p_ref[:, c][None, :]
            d2 = d2 + d * d
        hit = d2 <= radius * radius
        pos = cnt[:, None] + jnp.cumsum(hit.astype(jnp.int32), axis=1) - 1
        sel = hit & (pos < k)
        col = (j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1))
        onehot = sel[:, :, None] & (pos[:, :, None]
                                    == jax.lax.broadcasted_iota(
                                        jnp.int32, (bm, bn, k), 2))
        upd = jnp.max(jnp.where(onehot, col[:, :, None], -1), axis=1)
        idx_ref[...] = jnp.where(upd >= 0, upd, idx_ref[...])
        return cnt + jnp.sum(sel.astype(jnp.int32), axis=1)

    # Tile-level conditional return: skip if every query here is full.
    cnt_ref[...] = jax.lax.cond(jnp.all(cnt >= k), lambda c: c, tile, cnt)


def make_ballquery_call(m_pad: int, n_pad: int, bm: int, bn: int,
                        radius: float, k: int, interpret: bool):
    kernel = functools.partial(ballquery_kernel, radius=radius, k=k, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=(m_pad // bm, n_pad // bn),
        in_specs=[
            pl.BlockSpec((bm, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 3), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad,), jnp.int32),
            jax.ShapeDtypeStruct((m_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )
