from repro.kernels.ballquery.ops import ball_query_tiled  # noqa: F401
