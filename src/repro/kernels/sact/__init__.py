from repro.kernels.sact.ops import sact_fused  # noqa: F401
