"""Pure-jnp oracle for the fused SACT kernel: reuses the core staged test."""
from __future__ import annotations

from typing import Tuple

import jax

from repro.core import sact as sact_mod


def sact_ref(obb_center, obb_half, obb_rot, aabb_center, aabb_half,
             use_spheres: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Dense (M, N) staged SACT: (collide bool, exit_code int32)."""
    res = sact_mod.sact(
        obb_center[:, None, :], obb_half[:, None, :], obb_rot[:, None, :, :],
        aabb_center[None, :, :], aabb_half[None, :, :],
        use_spheres=use_spheres)
    return res.collide, res.exit_code
