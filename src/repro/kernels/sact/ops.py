"""JIT wrapper for the fused SACT kernel: packing, padding, unpadding."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.geometry import AABBs, OBBs
from repro.kernels.sact.kernel import make_sact_call


def pack_obbs(center, half, rot) -> jax.Array:
    """(M,3),(M,3),(M,3,3) -> (M,15) [center half rot-row-major]."""
    return jnp.concatenate(
        [center, half, rot.reshape(rot.shape[0], 9)], axis=-1
    ).astype(jnp.float32)


def pack_aabbs(center, half) -> jax.Array:
    return jnp.concatenate([center, half], axis=-1).astype(jnp.float32)


def _pad_rows(x: jax.Array, mult: int, fill: float) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0)), constant_values=fill)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "use_spheres",
                                             "interpret"))
def sact_fused(obb_center, obb_half, obb_rot, aabb_center, aabb_half,
               bm: int = 128, bn: int = 128, use_spheres: bool = False,
               interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Fused staged SACT over all (OBB, AABB) pairs.

    Returns (collide (M,N) bool, exit_code (M,N) int32).  ``interpret=True``
    executes the kernel body on CPU (this container); on a real TPU pass
    ``interpret=False``.  Padding rows use far-away unit boxes so they decide
    at the first axis and never flip the tile-level conditional return.
    """
    M, N = obb_center.shape[0], aabb_center.shape[0]
    obb = pack_obbs(obb_center, obb_half, obb_rot)
    aabb = pack_aabbs(aabb_center, aabb_half)
    # Far-away padding: centre 1e6, half 1, rot rows -> identity-ish zeros
    # would make AbsR eps-only; keep zeros, the |t| > ra+rb test still
    # separates instantly because t is huge.
    obb_p = _pad_rows(obb, bm, 0.0)
    obb_p = obb_p.at[M:, 0].set(1e6) if obb_p.shape[0] > M else obb_p
    aabb_p = _pad_rows(aabb, bn, 0.0)
    aabb_p = aabb_p.at[N:, 0].set(-1e6) if aabb_p.shape[0] > N else aabb_p
    call = make_sact_call(obb_p.shape[0], aabb_p.shape[0], bm, bn,
                          use_spheres, interpret)
    collide, exit_code = call(obb_p, aabb_p)
    return collide[:M, :N], exit_code[:M, :N]


def sact_fused_boxes(obbs: OBBs, aabbs: AABBs, **kw):
    return sact_fused(obbs.center, obbs.half, obbs.rot, aabbs.center,
                      aabbs.half, **kw)
