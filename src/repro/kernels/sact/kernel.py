"""Fused staged SACT Pallas kernel — the "collision OP unit" on TPU.

RoboGPU §III-C replaces 47 interconnect-hopping TTA+ µops with dedicated
Box-Normal and Edge×Edge OP units so intermediates never leave the unit.
The TPU analogue: one `pallas_call` that keeps an OBB tile and an AABB tile
resident in VMEM and evaluates the *entire* staged test (sphere pre-tests,
6 box-normal axes, 9 edge×edge axes) without materializing any intermediate
in HBM.  Unfused jnp stages move ~424 B/test HBM-side; this kernel moves
~92 B/test (boxes in, verdict out) — see core/counters.py.

Early exit inside the kernel is *predication* (lanes that found a separating
axis stop contributing via masks) plus a *conditional return* at tile
granularity: once every pair in the tile is decided after the box-normal
stage, the edge×edge stage is skipped with `lax.cond` — the per-tile version
of RoboCore's RETURN unit.

Geometry layout: component-unrolled SoA.  3-vectors are awkward on 8×128
vregs, so each component is its own (block,) vector and all 15 axis formulas
are unrolled scalars over the (bm, bn) tile plane — pure VPU code, no MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-6
NUM_AXES = 15


def _load_obb(obb_ref, idx):
    """obb_ref: (bm, 15) packed [center(3) half(3) rot(9 row-major)]."""
    return obb_ref[:, idx]


def sact_tile(t, Rb, A, ahb, ohb, *, use_spheres: bool):
    """Staged SACT over component-unrolled arrays of one common shape.

    Args are the per-pair quantities as plain component lists — ``t``/
    ``ahb``/``ohb`` three arrays each, ``Rb``/``A`` (= |R| + eps) 3x3 nested
    lists — every array sharing one tile shape.  Returns (collide bool,
    exit_code int32) of that shape.  Shape-agnostic so both the dense
    (bm, bn)-plane SACT kernel and the (bn,)-lane fused traversal-step
    kernel share the exact axis formulas (bitwise: same op order).

    Early exit is predication per lane plus a *conditional return* at tile
    granularity: once every pair is decided after the box-normal stage, the
    edge x edge stage is skipped with ``lax.cond`` — the per-tile version
    of RoboCore's RETURN unit.
    """
    shape = t[0].shape
    decided_sep = jnp.zeros(shape, jnp.bool_)
    exit_code = jnp.full(shape, 17, jnp.int32)

    def note_sep(decided, code, sep_now, code_val):
        newly = sep_now & ~decided
        return decided | sep_now, jnp.where(newly, code_val, code)

    # --- stage 0/1: sphere pre-tests (optional) ------------------------
    confirmed_hit = jnp.zeros(shape, jnp.bool_)
    if use_spheres:
        d2 = jnp.zeros(shape, jnp.float32)
        for i in range(3):
            d = jnp.maximum(jnp.abs(t[i]) - ahb[i], 0.0)
            d2 = d2 + d * d
        r_out2 = ohb[0] * ohb[0] + ohb[1] * ohb[1] + ohb[2] * ohb[2]
        r_in = jnp.minimum(jnp.minimum(ohb[0], ohb[1]), ohb[2])
        decided_sep, exit_code = note_sep(decided_sep, exit_code,
                                          d2 > r_out2, 0)
        newly_hit = (d2 < r_in * r_in) & ~decided_sep
        confirmed_hit = confirmed_hit | newly_hit
        exit_code = jnp.where(newly_hit, 1, exit_code)

    live0 = ~(decided_sep | confirmed_hit)

    # --- stage A: 6 box-normal axes ------------------------------------
    for i in range(3):   # L = A_i
        rb = ohb[0] * A[i][0] + ohb[1] * A[i][1] + ohb[2] * A[i][2]
        sep = (jnp.abs(t[i]) > ahb[i] + rb) & live0
        decided_sep, exit_code = note_sep(decided_sep, exit_code, sep, 2 + i)
    for j in range(3):   # L = B_j
        lhs = jnp.abs(t[0] * Rb[0][j] + t[1] * Rb[1][j] + t[2] * Rb[2][j])
        ra = ahb[0] * A[0][j] + ahb[1] * A[1][j] + ahb[2] * A[2][j]
        sep = (lhs > ra + ohb[j]) & live0
        decided_sep, exit_code = note_sep(decided_sep, exit_code, sep, 5 + j)

    # --- stage B: 9 edge x edge axes, tile-level conditional return ----
    def edge_stage(decided_sep, exit_code):
        live = live0 & ~decided_sep
        for i in range(3):
            i1, i2 = (i + 1) % 3, (i + 2) % 3
            for j in range(3):
                j1, j2 = (j + 1) % 3, (j + 2) % 3
                ra = ahb[i1] * A[i2][j] + ahb[i2] * A[i1][j]
                rb = ohb[j1] * A[i][j2] + ohb[j2] * A[i][j1]
                lhs = jnp.abs(t[i2] * Rb[i1][j] - t[i1] * Rb[i2][j])
                sep = (lhs > ra + rb) & live
                decided_sep, exit_code = note_sep(decided_sep, exit_code,
                                                  sep, 8 + 3 * i + j)
        return decided_sep, exit_code

    all_decided = jnp.all(decided_sep | confirmed_hit)
    decided_sep, exit_code = jax.lax.cond(
        all_decided, lambda d, e: (d, e), edge_stage, decided_sep, exit_code)

    collide = (~decided_sep) | confirmed_hit
    return collide, exit_code


def sact_kernel(obb_ref, aabb_ref, collide_ref, exit_ref, *,
                use_spheres: bool):
    bm = obb_ref.shape[0]
    bn = aabb_ref.shape[0]

    # --- unpack (component-unrolled) -----------------------------------
    oc = [obb_ref[:, i] for i in range(3)]            # obb centre
    oh = [obb_ref[:, 3 + i] for i in range(3)]        # obb half extents
    # rot row-major: R[i][j] = obb_ref[:, 6 + 3*i + j]
    R = [[obb_ref[:, 6 + 3 * i + j] for j in range(3)] for i in range(3)]
    ac = [aabb_ref[:, i] for i in range(3)]
    ah = [aabb_ref[:, 3 + i] for i in range(3)]

    def bc_m(x):  # (bm,) -> (bm, bn)
        return jnp.broadcast_to(x[:, None], (bm, bn))

    def bc_n(x):  # (bn,) -> (bm, bn)
        return jnp.broadcast_to(x[None, :], (bm, bn))

    t = [bc_m(oc[i]) - bc_n(ac[i]) for i in range(3)]
    Rb = [[bc_m(R[i][j]) for j in range(3)] for i in range(3)]
    A = [[jnp.abs(Rb[i][j]) + _EPS for j in range(3)] for i in range(3)]
    ahb = [bc_n(ah[i]) for i in range(3)]
    ohb = [bc_m(oh[i]) for i in range(3)]

    collide, exit_code = sact_tile(t, Rb, A, ahb, ohb,
                                   use_spheres=use_spheres)
    collide_ref[...] = collide
    exit_ref[...] = exit_code


def make_sact_call(m_pad: int, n_pad: int, bm: int, bn: int,
                   use_spheres: bool, interpret: bool):
    """Build the pallas_call for padded sizes (m_pad, n_pad)."""
    kernel = functools.partial(sact_kernel, use_spheres=use_spheres)
    return pl.pallas_call(
        kernel,
        grid=(m_pad // bm, n_pad // bn),
        in_specs=[
            pl.BlockSpec((bm, 15), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 6), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, n_pad), jnp.bool_),
            jax.ShapeDtypeStruct((m_pad, n_pad), jnp.int32),
        ],
        interpret=interpret,
    )
