"""RWKV-6 (Finch) chunked recurrence Pallas kernel.

Per head with key/value dim D, data-dependent per-channel decay w_t ∈ (0,1):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T S_{t-1} + (r_t · (u ⊙ k_t)) v_t^T

The kernel processes chunks of L steps: the running state S lives in a VMEM
scratch that persists across the sequential chunk grid dimension (reset when
a new batch·head row begins), the intra-chunk term is an (L, L) masked
matmul with pairwise decay factors, and the inter-chunk term is one (L, D) x
(D, D) matmul.  Decays are handled in log space; every exponent is ≤ 0 by
construction so nothing overflows.  This fusion (state never leaves VMEM) is
the same discipline as the paper's collision OP units — see DESIGN.md §2.

Inputs per block: r, k, v, logw (1, L, D); u (1, D).  Outputs: o (1, L, D)
and the final state (1, D, D) for decode handoff.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sout_ref, s_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0]            # (L, D)
    k = k_ref[0]
    v = v_ref[0]
    lw = lw_ref[0]          # log decay, <= 0
    u = u_ref[0]            # (D,)
    S = s_ref[...]          # (D, D) f32
    L, D = r.shape

    lc = jnp.cumsum(lw, axis=0)                       # (L, D) inclusive
    lc_prev = lc - lw                                 # exclusive cumsum

    # Inter-chunk: o_t += (r_t ⊙ exp(lc_prev_t)) @ S
    inter = (r * jnp.exp(lc_prev)) @ S                # (L, D)

    # Intra-chunk: A[t, s] = Σ_d r[t,d] k[s,d] exp(lc_prev[t,d] - lc[s,d]),
    # strictly causal (s < t); every exponent ≤ 0 for s ≤ t-1.
    e = jnp.exp(jnp.minimum(lc_prev[:, None, :] - lc[None, :, :], 0.0))
    A = jnp.sum(r[:, None, :] * k[None, :, :] * e, axis=-1)        # (L, L)
    t_i = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_i = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    A = jnp.where(t_i > s_i, A, 0.0)
    bonus = jnp.sum(r * u[None, :] * k, axis=-1)                   # (L,)
    o = inter + A @ v + bonus[:, None] * v

    # State update: S' = diag(exp(lc_L)) S + Σ_s (k_s ⊙ exp(lc_L - lc_s)) v_s^T
    lc_last = lc[-1]                                               # (D,)
    kd = k * jnp.exp(jnp.minimum(lc_last[None, :] - lc, 0.0))      # (L, D)
    S_new = jnp.exp(lc_last)[:, None] * S + kd.T @ v
    s_ref[...] = S_new
    o_ref[0] = o.astype(o_ref.dtype)
    sout_ref[0] = S_new


def make_wkv6_call(bh: int, T: int, L: int, D: int, interpret: bool,
                   dtype=jnp.float32):
    return pl.pallas_call(
        wkv6_kernel,
        grid=(bh, T // L),
        in_specs=[
            pl.BlockSpec((1, L, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, L, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, L, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, L, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, D), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, D, D), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, T, D), dtype),
            jax.ShapeDtypeStruct((bh, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )
