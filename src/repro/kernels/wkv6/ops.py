"""JIT wrapper for the WKV6 chunked kernel."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import make_wkv6_call


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, chunk: int = 32, interpret: bool = True
         ) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6 over (BH, T, D) inputs -> (o (BH,T,D), state (BH,D,D)).

    ``logw`` is log-decay (≤ 0); ``u`` is the per-channel bonus (D,) or
    (BH, D).  T is padded to a chunk multiple with zero k (no state effect)
    and logw = 0 (decay 1).
    """
    BH, T, D = r.shape
    pad = (-T) % chunk
    if pad:
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        r, k, v, logw = zpad(r), zpad(k), zpad(v), zpad(logw)
    if u.ndim == 1:
        u = jnp.broadcast_to(u[None, :], (1, D))
    else:
        u = u[:1]  # kernel broadcasts one bonus row; per-head via vmap'd call
    call = make_wkv6_call(BH, T + pad, chunk, D, interpret, dtype=r.dtype)
    o, s = call(r.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), logw.astype(jnp.float32),
                u.astype(jnp.float32))
    return o[:, :T], s


def wkv6_heads(r, k, v, logw, u, chunk: int = 32, interpret: bool = True):
    """Per-head bonus version: r..logw (B, H, T, D), u (H, D)."""
    B, H, T, D = r.shape
    fold = lambda x: x.reshape(B * H, T, D)
    outs = []
    states = []
    # Group by head so each call sees a single bonus row.
    for h in range(H):
        o, s = wkv6(fold(r[:, h:h + 1]), fold(k[:, h:h + 1]),
                    fold(v[:, h:h + 1]), fold(logw[:, h:h + 1]), u[h],
                    chunk=chunk, interpret=interpret)
        outs.append(o.reshape(B, 1, T, D))
        states.append(s.reshape(B, 1, D, D))
    return jnp.concatenate(outs, 1), jnp.concatenate(states, 1)
