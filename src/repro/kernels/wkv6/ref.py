"""Pure-jnp sequential oracle for WKV6."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
             u: jax.Array, state: jax.Array | None = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Step-by-step recurrence over (BH, T, D): the ground truth.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  o_t = r_t S_{t-1} + (r·(u⊙k)) v_t
    """
    BH, T, D = r.shape
    w = jnp.exp(logw.astype(jnp.float32))
    if u.ndim == 1:
        u = jnp.broadcast_to(u[None, :], (BH, D))
    S0 = (jnp.zeros((BH, D, D), jnp.float32) if state is None
          else state.astype(jnp.float32))

    def step(S, xs):
        rt, kt, vt, wt = xs                       # (BH, D) each
        out = jnp.einsum("bd,bde->be", rt, S)
        bonus = jnp.sum(rt * u * kt, -1)          # (BH,)
        out = out + bonus[:, None] * vt
        S = wt[:, :, None] * S + kt[:, :, None] * vt[:, None, :]
        return S, out

    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 1, 0)
               for x in (r, k, v, w))
    S, o = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), S
