"""Oracle for the FPS kernel: the core jnp implementation."""
from repro.core.fps import farthest_point_sampling as fps_ref  # noqa: F401
