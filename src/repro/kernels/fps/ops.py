"""JIT wrapper: full FPS loop driving the fused update kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fps.kernel import make_fps_call


@functools.partial(jax.jit, static_argnames=("m", "bn", "interpret"))
def fps_pallas(points: jax.Array, m: int, first: int = 0, bn: int = 256,
               interpret: bool = True) -> jax.Array:
    """Furthest point sampling via the fused Pallas update: (m,) indices."""
    N = points.shape[0]
    pad = (-N) % bn
    pts = jnp.pad(points.astype(jnp.float32), ((0, pad), (0, 0)),
                  constant_values=1e9)
    n_pad = pts.shape[0]
    call = make_fps_call(n_pad, bn, interpret)
    # padded entries: keep dist at -inf so they are never selected
    dist0 = jnp.where(jnp.arange(n_pad) < N, jnp.inf, -jnp.inf
                      ).astype(jnp.float32)
    idx0 = jnp.zeros((m,), jnp.int32).at[0].set(first)

    def body(i, carry):
        dist, idx = carry
        sel = jax.lax.dynamic_slice(pts, (idx[i - 1], 0), (1, 3))
        ndist, bmax, barg = call(pts, dist, sel)
        nxt = barg[jnp.argmax(bmax)]
        return ndist, idx.at[i].set(nxt)

    _, idx = jax.lax.fori_loop(1, m, body, (dist0, idx0))
    return idx
