from repro.kernels.fps.ops import fps_pallas  # noqa: F401
