"""Furthest-point-sampling distance-update Pallas kernel.

One FPS iteration is: ``dist = min(dist, |x - p_sel|^2)`` followed by a
global argmax.  This kernel fuses the distance update with a per-block
max/argmax reduction so the (N, 3) cloud is read exactly once per iteration
(the jnp version reads it for the update and again for the argmax).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def fps_update_kernel(pts_ref, dist_ref, sel_ref, ndist_ref, bmax_ref,
                      barg_ref):
    j = pl.program_id(0)
    bn = pts_ref.shape[0]
    d2 = jnp.zeros((bn,), jnp.float32)
    for c in range(3):
        d = pts_ref[:, c] - sel_ref[0, c]
        d2 = d2 + d * d
    nd = jnp.minimum(dist_ref[...], d2)
    ndist_ref[...] = nd
    arg = jnp.argmax(nd).astype(jnp.int32)
    bmax_ref[0] = nd[arg]
    barg_ref[0] = arg + j * bn


def make_fps_call(n_pad: int, bn: int, interpret: bool):
    return pl.pallas_call(
        fps_update_kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, 3), lambda j: (j, 0)),
            pl.BlockSpec((bn,), lambda j: (j,)),
            pl.BlockSpec((1, 3), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda j: (j,)),
            pl.BlockSpec((1,), lambda j: (j,)),
            pl.BlockSpec((1,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad // bn,), jnp.float32),
            jax.ShapeDtypeStruct((n_pad // bn,), jnp.int32),
        ],
        interpret=interpret,
    )
