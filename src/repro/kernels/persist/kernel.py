"""Persistent whole-traversal Pallas megakernel — one ``pallas_call`` for
the ENTIRE multi-level wavefront walk.

RoboGPU's central claim (§II, Fig. 11) is that a collision query should
stay *resident in the core* across the whole tree walk: conditional
returns, never spilling intermediates.  The per-level fused step
(:mod:`repro.kernels.traverse`) still launches one kernel per octree level
and round-trips the compacted frontier through HBM between levels; this
kernel removes that last HBM round trip.  The grid walks tiles of ``bq``
queries, and each grid step owns its tile's traversal end to end:

  1. the tile's frontier lives in a **double-buffered VMEM scratch** pair
     ``(2, fcap)`` of (query, CSR node index) lanes — level ``l`` reads
     slot ``l % 2`` and compacts survivors' children into slot
     ``(l + 1) % 2``; the frontier never exists in HBM;
  2. the **level loop runs inside the kernel body** (``lax.fori_loop`` over
     ``depth + 1`` levels; a drained frontier makes the remaining levels
     natural no-ops — every update is masked by ``lane < n_live``);
  3. each level gathers the lanes' query OBBs (one-hot matmul against the
     tile's own ``bq``-row OBB block — queries never leave their tile, so
     the full query table is never resident), reconstructs node AABBs from
     Morton codes in-register, and runs the two-phase staged SACT via the
     shared :func:`repro.kernels.sact.kernel.sact_tile` (tile-level
     conditional return skips the 9 edge axes once every lane is decided);
  4. CSR child expansion AND compaction happen **in-register**: per-parent
     child counts (popcount of the occupancy mask) are exclusive-scanned
     over the tile, child ``j`` of parent ``i`` lands at
     ``base[i] + popcount(mask[i] & ((1 << j) - 1))`` — no stream-compaction
     kernel, no candidate list in memory;
  5. children past ``fcap`` overflow to a per-tile **HBM spill ring**
     (``ring_cap`` most recent (query, node) pairs, wrapping) and are
     counted — the count lands in ``Counters.frontier_overflow`` and the
     engine's existing escalate-on-overflow policy replays the query set at
     a larger capacity, exactly as for the per-level arms.  Spilled pairs
     are *not* silently traversed: verdicts are exact iff the overflow
     count is zero.

Node metadata comes in one of two **layouts** (``stream`` static flag) x
three row **formats** (``meta_fmt`` static: fp32 = 16 B, bf16 = 8 B,
u8 = 4 B rows — :mod:`repro.core.quantize`), picked by the executor's
layout/format chooser (DESIGN.md §3).  The compressed formats decode
in-register via :func:`repro.kernels.persist.ref.decode_meta_rows` (shared
with the ref arm, so geometry and topology are bitwise-identical); the u8
format adds a third frontier lane carrying each lane's own Morton code,
since its rows store only the node's octant:

* ``resident`` — the whole ``(depth+1, n_max, words)`` table is a VMEM
  block, bounding scene size at roughly VMEM / row bytes / (depth+1)
  nodes;
* ``streamed`` — the table stays in HBM (``pltpu.ANY``) and the kernel
  **double-buffers per-level row windows** through a ping/pong VMEM
  scratch pair: while level ``l`` runs its SACT+expand+compact out of slot
  ``l % 2``, the DMA for level ``l + 1``'s window (the occupied row extent
  of that level, :data:`repro.core.octree.META_ROW_ALIGN`-row chunks) is
  already in flight into slot ``(l + 1) % 2``.  Windows are keyed on the
  levels the tile's frontier actually visits: a drained frontier stops the
  prefetch chain, and every started window is waited exactly once before
  its level reads it.  VMEM residency drops from ``(depth+1) * n_max``
  rows to ``2 * n_max`` — ``(depth+1)/2``x more scene per VMEM byte, 4x
  at the paper's depth-7 operating point (524k-point clouds); fixed-size
  sub-level windows decoupling scratch from the widest level are the
  recorded follow-up (ROADMAP).  Rows fetched are counted into
  the ``meta_rows`` scalar, priced by the bytes model at the format's row
  width (:data:`repro.core.counters.BYTES_META_STREAM` and its
  ``_BF16`` / ``_U8`` siblings), with the jnp ref arm modeling the
  identical per-tile window schedule.  The row *count* per format is
  unchanged — compression divides the streamed bytes by exactly 2x/4x.

Because queries are partitioned across tiles and a pair's whole subtree
stays in its query's tile, the early-exit coupling (a decided query
retires all its pairs) is tile-local, and on every clean (overflow-free)
run the union of per-tile work is *bitwise* the work of the global-frontier
fused arm: same pairs per level, same exit codes, same counters (summed
over tiles).  Overflow accounting, however, is per-tile: each tile owns
``fcap`` VMEM lanes, so with multiple tiles the aggregate frontier room is
``num_tiles * fcap`` and a frontier that overflows the ref's single global
pool may fit here (or vice versa under heavy skew).  Each backend
escalates against its *own* overflow count until clean, after which the
counters agree again; only the clamped regime (pinned
``frontier_capacity`` / ``max_frontier``), where verdicts under-approximate
by contract, may drop different pairs per backend.

Per-query HBM traffic collapses to: seed pair in, one verdict word out,
plus spill traffic — the bytes model of
:data:`repro.core.counters.BYTES_PERSIST_QUERY` — plus, under the
streamed layout, the metadata window traffic above.

The frontier carries a **payload lane** (:mod:`repro.engine.plan`): each
query's int32 payload rides its pairs, a terminal hit folds it into the
per-query ``best`` with a min (the verdict word), and a pair stays live
only while its payload could still beat its query's best.  All-zero
payloads reproduce the boolean engine bit-for-bit.  Cross-slot owner
lanes (per-EDGE first hit across a swept edge's segments) are served by
the reference arm: queries would no longer own their verdict groups
tile-exclusively — tiling by owner group is the follow-up (DESIGN.md §3).

On the CPU CI matrix the kernel (both layouts, including the DMA window
machinery) runs under ``interpret=True`` on small scenes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.counters import NUM_EXIT_CODES
from repro.core.octree import META_ROW_ALIGN
from repro.core.quantize import META_FORMAT_WORDS
from repro.core.sact import PAYLOAD_INF, axis_tests_from_exit
from repro.kernels.persist.ref import csr_child_slots, decode_meta_rows
# _EPS shared with every SACT arm: the bitwise identity across engines
# depends on all of them using the same epsilon and op order.
from repro.kernels.sact.kernel import _EPS, NUM_AXES, sact_tile

try:  # CPU-only containers may lack the TPU extension
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def persist_kernel(scal_ref, nchunk_ref, nvalid_ref, obb_ref, meta_ref,
                   payload_ref, collide_ref, perlevel_ref, hist_ref,
                   scalars_ref, ring_ref, *scratch, num_queries: int, bq: int,
                   fcap: int, depth: int, n_max: int, ring_cap: int,
                   use_spheres: bool, stream: bool, meta_fmt: str):
    # Scratch order mirrors make_persist_call's scratch_shapes: frontier
    # query/node slot pairs always; a third frontier lane (each lane's own
    # Morton code) under the u8 format, whose rows store only the octant;
    # window scratch + DMA semaphores under the streamed layout.
    fq_scr, fn_scr = scratch[0], scratch[1]
    nscr = 2
    fp_scr = None
    if meta_fmt == "u8":
        fp_scr = scratch[nscr]
        nscr += 1
    if stream:
        meta_scr, dma_sem = scratch[nscr], scratch[nscr + 1]
    t = pl.program_id(0)
    L = depth + 1
    W = META_ROW_ALIGN
    vpf = META_FORMAT_WORDS[meta_fmt]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, fcap), 1).reshape((fcap,))
    q_base = t * bq
    # Live-prefix mask: the SMEM valid count (<= the static num_queries
    # pool width) excludes the sharded executor's pad slots — a fully
    # padded tile seeds an empty frontier and contributes zero work.
    n_q = jnp.clip(nvalid_ref[0] - q_base, 0, bq)

    scal = scal_ref[...]                       # [scene_lo(3), cells(L)]
    obb_tile = obb_ref[...]                    # (bq, 15) this tile's queries
    pay_tile = payload_ref[...]                # (bq,) payload lane per query
    iota_q = jax.lax.broadcasted_iota(jnp.int32, (1, bq), 1).reshape((bq,))
    iota_hist = jax.lax.broadcasted_iota(
        jnp.int32, (1, NUM_EXIT_CODES), 1).reshape((NUM_EXIT_CODES,))

    if stream:
        # ---- HBM->VMEM metadata window DMA (ping/pong scratch pair) ----
        # A level's window is its occupied row extent, issued as
        # ``nchunk_ref[level]`` back-to-back W-row copies on the slot's
        # semaphore; wait_window re-derives the same descriptors so every
        # started chunk is waited exactly once.
        def _window(op, level, slot):
            def chunk(k, _):
                dma = pltpu.make_async_copy(
                    meta_ref.at[level, pl.ds(k * W, W)],
                    meta_scr.at[pl.ds(slot * n_max + k * W, W)],
                    dma_sem.at[slot])
                (dma.start if op == "start" else dma.wait)()
                return _
            jax.lax.fori_loop(0, nchunk_ref[level], chunk, 0)

        # Seed: level-0 window.  Gated on the tile holding queries so the
        # level-0 wait gate (prev_live = n_q) pairs with it exactly — an
        # empty tile must not leave a DMA in flight at kernel end.
        @pl.when(n_q > 0)
        def _():
            _window("start", 0, 0)
    else:
        meta_flat = meta_ref[...].reshape(L * n_max, vpf)

    def level_body(level, carry):
        (n_live, best_vec, per_level, hist, leaf, axis_exec, sphere,
         overflow, spilled, cursor, ring, meta_rows, prev_live) = carry
        slot = jax.lax.rem(level, 2)
        q = jnp.where(slot == 0, fq_scr[0, :], fq_scr[1, :])
        idx = jnp.where(slot == 0, fn_scr[0, :], fn_scr[1, :])
        pcode = (jnp.where(slot == 0, fp_scr[0, :], fp_scr[1, :])
                 if meta_fmt == "u8" else None)
        valid = lane < n_live

        # ---- one metadata gather per lane (code, full, CSR cols) ------
        if stream:
            # Wait for this level's window (started while the previous
            # level computed), then put the NEXT level's window in flight
            # before any SACT work — the copy overlaps the whole level.
            @pl.when(prev_live > 0)
            def _():
                _window("wait", level, slot)

            nxt_live = (level < depth) & (n_live > 0)

            @pl.when(nxt_live)
            def _():
                _window("start", level + 1, 1 - slot)

            meta_rows = meta_rows + jnp.where(
                nxt_live,
                nchunk_ref[jnp.minimum(level + 1, depth)] * W, 0)
            # One offset gather out of the active window half — the same
            # flat-gather idiom as the resident path, never selecting the
            # half an in-flight prefetch DMA is writing.
            meta = jnp.take(meta_scr[...],
                            slot * n_max + jnp.clip(idx, 0, n_max - 1),
                            axis=0)
        else:
            meta = jnp.take(meta_flat,
                            level * n_max + jnp.clip(idx, 0, n_max - 1),
                            axis=0)
        xyz_i, full_l, child_start, child_mask, code_own = decode_meta_rows(
            meta, meta_fmt, level, pcode)

        # ---- gather query boxes from the tile's own OBB block ---------
        # (queries never cross tiles, so lane query ids are tile-local)
        q_onehot = (q - q_base)[:, None] == iota_q[None, :]       # (fcap, bq)
        rows = jnp.dot(q_onehot.astype(jnp.float32), obb_tile,
                       preferred_element_type=jnp.float32)        # (fcap, 15)
        oc = [rows[:, i] for i in range(3)]
        oh = [rows[:, 3 + i] for i in range(3)]
        R = [[rows[:, 6 + 3 * i + k] for k in range(3)] for i in range(3)]

        # ---- node AABB from decoded cell coords, in-register ----------
        cell = jnp.take(scal, 3 + level)
        xyz = xyz_i.astype(jnp.float32)
        node_c = [scal[i] + (xyz[:, i] + 0.5) * cell for i in range(3)]
        node_h = cell * 0.5

        # ---- two-phase staged SACT (shared tile formulas) -------------
        tt = [oc[i] - node_c[i] for i in range(3)]
        A = [[jnp.abs(R[i][k]) + _EPS for k in range(3)] for i in range(3)]
        collide_l, exit_code = sact_tile(tt, R, A, [node_h] * 3, oh,
                                         use_spheres=use_spheres)

        is_term = full_l | (level == depth)
        overlap = collide_l & valid
        term_hit = overlap & is_term

        # ---- per-query payload-lane best, tile-local (queries never
        # cross tiles): a terminal hit folds the lane's payload in with a
        # min — the one-hot re-derivation of sact.payload_min_update —
        # and a lane stays live only while its payload could still beat
        # its query's best (boolean early exit == all-zero payloads).
        inf = jnp.int32(PAYLOAD_INF)
        pay_lane = jnp.sum(jnp.where(q_onehot, pay_tile[None, :], 0), axis=1)
        best_vec = jnp.minimum(best_vec, jnp.min(
            jnp.where(term_hit[:, None] & q_onehot, pay_lane[:, None], inf),
            axis=0))
        best_lane = jnp.min(jnp.where(q_onehot, best_vec[None, :], inf),
                            axis=1)

        # ---- work accounting (formulas of the fused arm, bitwise) -----
        n_valid = jnp.sum(valid.astype(jnp.int32))
        term_valid = jnp.where(valid & is_term, 1, 0)
        leaf = leaf + jnp.sum(term_valid)
        axis_exec = axis_exec + jnp.sum(
            jnp.where(valid, axis_tests_from_exit(exit_code), 0))
        sphere = sphere + (2 * n_valid if use_spheres else 0)
        per_level = per_level + jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (1, L), 1).reshape((L,))
            == level, n_valid, 0)
        hist = hist + jnp.sum(
            jnp.where((exit_code[:, None] == iota_hist[None, :])
                      & (term_valid[:, None] != 0), 1, 0), axis=0)

        # ---- in-register CSR expansion + compaction -------------------
        expand = overlap & ~is_term & (pay_lane < best_lane)
        occupied, offs = csr_child_slots(child_mask)
        n_child = jnp.where(expand,
                            jax.lax.population_count(child_mask), 0)
        base = jnp.cumsum(n_child) - n_child
        n_new = jnp.sum(n_child)
        live = expand[:, None] & occupied                          # (fcap, 8)
        pos = base[:, None] + offs
        q_rep = jnp.repeat(q, 8)
        cand = (child_start[:, None] + offs).reshape(-1)
        tgt = jnp.where(live, pos, fcap).reshape(-1)
        q_next = jnp.zeros((fcap,), jnp.int32).at[tgt].set(q_rep,
                                                           mode="drop")
        i_next = jnp.zeros((fcap,), jnp.int32).at[tgt].set(cand,
                                                           mode="drop")

        # ---- HBM spill ring: children past fcap, newest-wrapping ------
        in_ring = live & (pos >= fcap)
        ring_tgt = jnp.where(
            in_ring, jax.lax.rem(cursor + (pos - fcap), ring_cap),
            ring_cap).reshape(-1)
        ring = ring.at[ring_tgt, 0].set(q_rep, mode="drop")
        ring = ring.at[ring_tgt, 1].set(cand, mode="drop")
        spill_now = jnp.maximum(n_new - fcap, 0)
        overflow = overflow + spill_now
        spilled = spilled + spill_now
        cursor = jax.lax.rem(cursor + spill_now, ring_cap)

        # ---- double-buffer write: next level reads the other slot -----
        nxt = 1 - slot
        fq_scr[0, :] = jnp.where(nxt == 0, q_next, fq_scr[0, :])
        fq_scr[1, :] = jnp.where(nxt == 1, q_next, fq_scr[1, :])
        fn_scr[0, :] = jnp.where(nxt == 0, i_next, fn_scr[0, :])
        fn_scr[1, :] = jnp.where(nxt == 1, i_next, fn_scr[1, :])
        if meta_fmt == "u8":
            # Children inherit this lane's own code as their pcode.
            p_next = jnp.zeros((fcap,), jnp.int32).at[tgt].set(
                jnp.repeat(code_own, 8), mode="drop")
            fp_scr[0, :] = jnp.where(nxt == 0, p_next, fp_scr[0, :])
            fp_scr[1, :] = jnp.where(nxt == 1, p_next, fp_scr[1, :])
        return (jnp.minimum(n_new, fcap), best_vec, per_level, hist,
                leaf, axis_exec, sphere, overflow, spilled, cursor, ring,
                meta_rows, n_live)

    # Seed frontier (slot 0): one (query, root) pair per query of the tile.
    fq_scr[0, :] = jnp.where(lane < n_q, q_base + lane, 0)
    fn_scr[0, :] = jnp.zeros((fcap,), jnp.int32)
    if meta_fmt == "u8":
        fp_scr[0, :] = jnp.zeros((fcap,), jnp.int32)  # root's own code is 0

    meta_rows0 = (jnp.where(n_q > 0, nchunk_ref[0] * W, 0).astype(jnp.int32)
                  if stream else jnp.int32(0))
    carry0 = (jnp.minimum(n_q, fcap),
              jnp.full((bq,), PAYLOAD_INF, jnp.int32),
              jnp.zeros((L,), jnp.int32),
              jnp.zeros((NUM_EXIT_CODES,), jnp.int32),
              jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
              jnp.int32(0), jnp.int32(0),
              jnp.zeros((ring_cap, 2), jnp.int32),
              meta_rows0, n_q)
    (_, best_vec, per_level, hist, leaf, axis_exec, sphere, overflow,
     spilled, _, ring, meta_rows, _) = jax.lax.fori_loop(0, L, level_body,
                                                         carry0)

    collide_ref[...] = best_vec.reshape(1, bq)
    perlevel_ref[...] = per_level.reshape(1, L)
    hist_ref[...] = hist.reshape(1, NUM_EXIT_CODES)
    nodes = jnp.sum(per_level)
    scalars_ref[...] = jnp.stack(
        [nodes, leaf, axis_exec, nodes * NUM_AXES, sphere, overflow,
         spilled, meta_rows]).reshape(1, 8)
    ring_ref[...] = ring.reshape(1, ring_cap, 2)


def make_persist_call(num_queries: int, num_tiles: int, bq: int, fcap: int,
                      depth: int, n_max: int, ring_cap: int,
                      use_spheres: bool, interpret: bool, stream: bool,
                      meta_fmt: str = "fp32"):
    """Build the whole-traversal pallas_call.

    Inputs: scal (3 + depth+1,) f32 SMEM [scene_lo xyz, per-level cells];
    per-level window chunk counts (depth+1,) int32 SMEM (zeros under the
    resident layout); live query count (1,) int32 SMEM (the pool's
    live prefix — pad slots past it never seed, see the sharded
    executor); OBB table (num_tiles * bq, 15) f32, blocked per tile;
    node_meta (depth+1, n_max, words) int32 packed per ``meta_fmt``
    (fp32: 4 words, bf16: 2, u8: 1 — :mod:`repro.core.quantize`) — a
    resident VMEM block, or an HBM-space (``pltpu.ANY``) table streamed
    through the ping/pong window scratch when ``stream`` (the DMA
    machinery is format-agnostic: only the row width changes); payload (num_tiles * bq,) int32 per-query
    payload lane (all zeros for boolean plans).  Outputs per query tile:
    ``best`` payload words (bq,) int32 (``PAYLOAD_INF`` = query never hit;
    0 = a boolean hit), valid counts per level, exit histogram, packed work
    scalars [nodes, leaf, axis_exec, axis_dec, sphere, overflow, spilled,
    meta_rows], and the spill ring's (query, node) pairs.
    """
    if pltpu is None:  # pragma: no cover - exercised only sans TPU extra
        raise RuntimeError("pallas TPU extension unavailable")
    if stream:
        assert n_max % META_ROW_ALIGN == 0, \
            "streamed node_meta needs META_ROW_ALIGN-aligned rows"
    L = depth + 1
    vpf = META_FORMAT_WORDS[meta_fmt]
    kernel = functools.partial(
        persist_kernel, num_queries=num_queries, bq=bq, fcap=fcap,
        depth=depth, n_max=n_max, ring_cap=ring_cap,
        use_spheres=use_spheres, stream=stream, meta_fmt=meta_fmt)
    meta_spec = (pl.BlockSpec(memory_space=pltpu.ANY) if stream
                 else pl.BlockSpec((L, n_max, vpf), lambda t: (0, 0, 0)))
    scratch = [
        pltpu.VMEM((2, fcap), jnp.int32),    # frontier queries (2 slots)
        pltpu.VMEM((2, fcap), jnp.int32),    # frontier node indices
    ]
    if meta_fmt == "u8":
        scratch.append(pltpu.VMEM((2, fcap), jnp.int32))  # own-code lane
    if stream:
        scratch += [
            # meta window ping/pong pair, flat: slot s = rows
            # [s * n_max, (s + 1) * n_max)
            pltpu.VMEM((2 * n_max, vpf), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),          # per-slot window DMAs
        ]
    return pl.pallas_call(
        kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scal
            pl.BlockSpec(memory_space=pltpu.SMEM),            # window chunks
            pl.BlockSpec(memory_space=pltpu.SMEM),            # live count
            pl.BlockSpec((bq, 15), lambda t: (t, 0)),         # OBB tile
            meta_spec,                                        # node meta
            pl.BlockSpec((bq,), lambda t: (t,)),              # payload lane
        ],
        out_specs=[
            pl.BlockSpec((1, bq), lambda t: (t, 0)),
            pl.BlockSpec((1, L), lambda t: (t, 0)),
            pl.BlockSpec((1, NUM_EXIT_CODES), lambda t: (t, 0)),
            pl.BlockSpec((1, 8), lambda t: (t, 0)),
            pl.BlockSpec((1, ring_cap, 2), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles, bq), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, L), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, NUM_EXIT_CODES), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, 8), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, ring_cap, 2), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )
