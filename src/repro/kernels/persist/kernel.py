"""Persistent whole-traversal Pallas megakernel — one ``pallas_call`` for
the ENTIRE multi-level wavefront walk.

RoboGPU's central claim (§II, Fig. 11) is that a collision query should
stay *resident in the core* across the whole tree walk: conditional
returns, never spilling intermediates.  The per-level fused step
(:mod:`repro.kernels.traverse`) still launches one kernel per octree level
and round-trips the compacted frontier through HBM between levels; this
kernel removes that last HBM round trip.  The grid walks tiles of ``bq``
pool slots, and each grid step owns its tile's traversal end to end:

  1. the tile's frontier lives in a **double-buffered VMEM scratch** pair
     ``(2, fcap)`` of (query, CSR node index) lanes — level ``l`` reads
     slot ``l % 2`` and compacts survivors' children into slot
     ``(l + 1) % 2``; the frontier never exists in HBM;
  2. the **level loop runs inside the kernel body** (``lax.fori_loop`` over
     ``depth + 1`` levels; a drained frontier makes the remaining levels
     natural no-ops — every update is masked by ``lane < n_live``);
  3. each level gathers the lanes' query OBBs (one-hot matmul against the
     tile's own ``bq``-row OBB block — queries never leave their tile, so
     the full query table is never resident), reconstructs node AABBs from
     Morton codes in-register, and runs the two-phase staged SACT via the
     shared :func:`repro.kernels.sact.kernel.sact_tile` (tile-level
     conditional return skips the 9 edge axes once every lane is decided);
  4. CSR child expansion AND compaction happen **in-register**: per-parent
     child counts (popcount of the occupancy mask) are exclusive-scanned
     over the tile, child ``j`` of parent ``i`` lands at
     ``base[i] + popcount(mask[i] & ((1 << j) - 1))`` — no stream-compaction
     kernel, no candidate list in memory;
  5. children past ``fcap`` overflow to a per-tile **HBM spill ring**
     (``ring_cap`` most recent (query, node) pairs, wrapping) and are
     counted — the count lands in ``Counters.frontier_overflow`` and the
     engine's existing escalate-on-overflow policy replays the query set at
     a larger capacity, exactly as for the per-level arms.  Spilled pairs
     are *not* silently traversed: verdicts are exact iff the overflow
     count is zero.

**Owner-group tiling.**  The host packs the pool so every verdict group
(all pairs sharing an ``owner_of_query`` — e.g. the segment lanes of one
swept CCD edge) lands in ONE tile (:func:`repro.kernels.persist.ops.
build_tile_map`).  The per-tile ``owner_local`` input names each slot's
group by the group's first slot in the tile (``-1`` = pad slot; live slots
form each tile's prefix).  The payload min-fold and its early-exit gate
then run on the GROUP one-hot: a terminal hit folds the lane's payload
into ``best[owner]``, and a lane stays live only while its payload could
still beat **its group's** best — so one segment's first hit retires its
sibling lanes *in-kernel*, the per-edge first-hit early exit of
swept-edge CCD.  Identity owners (``owner_local = slot``) reproduce the
per-query boolean/payload kernel bit-for-bit.

**Ragged multi-scene batches** run on the same flat CSR table
(:class:`repro.core.octree.MultiSceneOctree`): tiles are scene-exclusive
(the tile map never mixes scenes in a tile), the per-tile ``scene_of_tile``
id picks the scene's origin/cell-size row of the flat ``scal`` table and
its rows of the per-scene level sub-extent tables (``scene_off`` /
``scene_counts``), and the tile's frontier seeds at the scene's root (flat
node index ``s`` of the level-0 row).  Child pointers are pre-rebased to
flat indices, so the walk itself is scene-blind.

Node metadata comes in one of two **layouts** (``stream`` static flag) x
three row **formats** (``meta_fmt`` static: fp32 = 16 B, bf16 = 8 B,
u8 = 4 B rows — :mod:`repro.core.quantize`), picked by the executor's
layout/format chooser (DESIGN.md §3).  The compressed formats decode
in-register via :func:`repro.kernels.persist.ref.decode_meta_rows` (shared
with the ref arm, so geometry and topology are bitwise-identical); the u8
format adds a third frontier lane carrying each lane's own Morton code,
since its rows store only the node's octant:

* ``resident`` — the whole ``(depth+1, n_max, words)`` table is a VMEM
  block, bounding scene size at roughly VMEM / row bytes / (depth+1)
  nodes;
* ``streamed`` — the table stays in HBM (``pltpu.ANY``) and each level is
  iterated through **fixed-size sub-level windows** of ``wsub`` rows over
  the tile's scene sub-extent, double-buffered through a ping/pong VMEM
  scratch pair of ``wsub + 8`` rows each: while window ``w``'s lanes run
  their SACT out of one slot, the DMA for the tile's NEXT live window is
  already in flight into the other (windows no lane points into are
  skipped entirely).  The fetched span of a window is **row-exact**: the
  occupied extent clipped to the window and rounded out to whole 8-row
  DMA chunks (a 128-row chunk tier + an 8-row remainder tier), so a
  shallow level costs 8 fetched rows, not a full
  :data:`repro.core.octree.META_ROW_ALIGN` window.  VMEM scratch is
  ``2 * (wsub + 8)`` rows — decoupled from ``n_max`` entirely, so
  arbitrarily wide levels stream through constant VMEM.  Rows fetched are
  counted into the ``meta_rows`` scalar, priced by the bytes model at the
  format's row width (:data:`repro.core.counters.BYTES_META_STREAM` and
  its ``_BF16`` / ``_U8`` siblings), with the jnp ref arm modeling the
  identical per-(tile, window) schedule.  The row *count* per format is
  unchanged — compression divides the streamed bytes by exactly 2x/4x.

Because pool slots are partitioned across tiles and a verdict group's
pairs never cross tiles, the early-exit coupling (a decided group retires
all its pairs) is tile-local, and on every clean (overflow-free) run the
union of per-tile work is *bitwise* the work of the global-frontier ref
arm: same pairs per level, same exit codes, same counters (summed over
tiles and windows — the min-fold is order-free and every per-lane SACT
result depends only on its own lane).  Overflow accounting, however, is
per-tile: each tile owns ``fcap`` VMEM lanes, so with multiple tiles the
aggregate frontier room is ``num_tiles * fcap`` and a frontier that
overflows the ref's single global pool may fit here (or vice versa under
heavy skew).  Each backend escalates against its *own* overflow count
until clean, after which the counters agree again; only the clamped
regime (pinned ``frontier_capacity`` / ``max_frontier``), where verdicts
under-approximate by contract, may drop different pairs per backend.

Per-query HBM traffic collapses to: seed pair in, one verdict word out,
plus spill traffic — the bytes model of
:data:`repro.core.counters.BYTES_PERSIST_QUERY` — plus, under the
streamed layout, the metadata window traffic above.

On the CPU CI matrix the kernel (both layouts, including the DMA window
machinery) runs under ``interpret=True`` on small scenes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.counters import NUM_EXIT_CODES
from repro.core.octree import META_ROW_ALIGN
from repro.core.quantize import META_FORMAT_WORDS
from repro.core.sact import PAYLOAD_INF, axis_tests_from_exit
from repro.kernels.persist.ref import csr_child_slots, decode_meta_rows
# _EPS shared with every SACT arm: the bitwise identity across engines
# depends on all of them using the same epsilon and op order.
from repro.kernels.sact.kernel import _EPS, NUM_AXES, sact_tile

try:  # CPU-only containers may lack the TPU extension
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def persist_kernel(scal_ref, off_ref, cnt_ref, sot_ref, nvalid_ref, obb_ref,
                   meta_ref, payload_ref, owner_ref, collide_ref,
                   perlevel_ref, hist_ref, scalars_ref, ring_ref, *scratch,
                   bq: int, fcap: int, depth: int, n_max: int, ring_cap: int,
                   use_spheres: bool, stream: bool, meta_fmt: str, wsub: int):
    # Scratch order mirrors make_persist_call's scratch_shapes: frontier
    # query/node slot pairs always; a third frontier lane (each lane's own
    # Morton code) under the u8 format, whose rows store only the octant;
    # window scratch + DMA semaphores under the streamed layout.
    fq_scr, fn_scr = scratch[0], scratch[1]
    nscr = 2
    fp_scr = None
    if meta_fmt == "u8":
        fp_scr = scratch[nscr]
        nscr += 1
    if stream:
        meta_scr, dma_sem = scratch[nscr], scratch[nscr + 1]
    t = pl.program_id(0)
    L = depth + 1
    WS = wsub + 8                       # window scratch rows per slot
    vpf = META_FORMAT_WORDS[meta_fmt]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, fcap), 1).reshape((fcap,))
    q_base = t * bq
    s = sot_ref[t]                      # this tile's scene id
    own_tile = owner_ref[...]           # (bq,) local owner slot, -1 = pad
    # Live-prefix mask: live slots form each tile's prefix (the tile map
    # pads at tile tails) AND sit before the SMEM valid count (the sharded
    # executor's pool-tail pads) — a fully padded tile seeds an empty
    # frontier and contributes zero work.
    n_q = jnp.minimum(jnp.sum(jnp.where(own_tile >= 0, 1, 0)),
                      jnp.clip(nvalid_ref[0] - q_base, 0, bq))

    sb = s * (3 + L)                    # this scene's row of the flat scal
    obb_tile = obb_ref[...]             # (bq, 15) this tile's queries
    pay_tile = payload_ref[...]         # (bq,) payload lane per query
    iota_q = jax.lax.broadcasted_iota(jnp.int32, (1, bq), 1).reshape((bq,))
    iota_hist = jax.lax.broadcasted_iota(
        jnp.int32, (1, NUM_EXIT_CODES), 1).reshape((NUM_EXIT_CODES,))
    inf = jnp.int32(PAYLOAD_INF)

    if stream:
        # ---- HBM->VMEM sub-level window DMA (ping/pong scratch pair) ----
        # Window ``w`` of this tile's scene covers flat rows
        # [off + w*wsub, off + w*wsub + min(wsub, cnt - w*wsub)); the DMA
        # span rounds that out to whole 8-row chunks and is issued as a
        # 128-row chunk tier plus an 8-row remainder tier on the slot's
        # semaphore.  The wait op re-derives the same descriptors so every
        # started chunk is waited exactly once.
        def _win_dma(op, level, w, slot):
            off = off_ref[s * L + level]
            cnt = cnt_ref[s * L + level]
            g_lo = off + w * wsub
            occ = jnp.clip(cnt - w * wsub, 0, wsub)
            win_lo = (g_lo // 8) * 8
            span = (-(-(g_lo + occ) // 8)) * 8 - win_lo
            base = slot * WS
            n128 = span // 128

            def chunk128(k, c):
                dma = pltpu.make_async_copy(
                    meta_ref.at[level, pl.ds(win_lo + k * 128, 128)],
                    meta_scr.at[pl.ds(base + k * 128, 128)],
                    dma_sem.at[slot])
                (dma.start if op == "start" else dma.wait)()
                return c
            jax.lax.fori_loop(0, n128, chunk128, 0)

            def chunk8(k, c):
                r0 = n128 * 128 + k * 8
                dma = pltpu.make_async_copy(
                    meta_ref.at[level, pl.ds(win_lo + r0, 8)],
                    meta_scr.at[pl.ds(base + r0, 8)],
                    dma_sem.at[slot])
                (dma.start if op == "start" else dma.wait)()
                return c
            jax.lax.fori_loop(0, jax.lax.rem(span, 128) // 8, chunk8, 0)

    def level_body(level, carry):
        (n_live, best_vec, per_level, hist, leaf, axis_exec, sphere,
         overflow, spilled, cursor, ring, meta_rows) = carry
        slot = jax.lax.rem(level, 2)
        q = jnp.where(slot == 0, fq_scr[0, :], fq_scr[1, :])
        idx = jnp.where(slot == 0, fn_scr[0, :], fn_scr[1, :])
        pcode = (jnp.where(slot == 0, fp_scr[0, :], fp_scr[1, :])
                 if meta_fmt == "u8" else None)
        valid = lane < n_live

        # ---- per-level query-side gathers (constant across windows) ---
        # (pool slots never cross tiles, so lane query ids are tile-local)
        q_onehot = (q - q_base)[:, None] == iota_q[None, :]       # (fcap, bq)
        rows = jnp.dot(q_onehot.astype(jnp.float32), obb_tile,
                       preferred_element_type=jnp.float32)        # (fcap, 15)
        oc = [rows[:, i] for i in range(3)]
        oh = [rows[:, 3 + i] for i in range(3)]
        R = [[rows[:, 6 + 3 * i + k] for k in range(3)] for i in range(3)]
        pay_lane = jnp.sum(jnp.where(q_onehot, pay_tile[None, :], 0), axis=1)
        # The verdict-group one-hot: folds and gates address the lane's
        # OWNER slot, so sibling lanes of one group share one best cell.
        # Identity owners make this the per-query one-hot of old.
        own_lane = jnp.sum(jnp.where(q_onehot, own_tile[None, :], 0), axis=1)
        o_onehot = own_lane[:, None] == iota_q[None, :]           # (fcap, bq)

        cell = scal_ref[sb + 3 + level]
        node_h = cell * 0.5

        def sact_window(meta, in_w, best_cur):
            """One SACT + fold + stash pass over the lanes of one gather.

            Per-lane results depend only on the lane's own inputs (the
            edge-stage skip in :func:`sact_tile` can only *run more* work
            when extra undecided lanes share the call, never change a
            decided lane), so partitioning a level's lanes across windows
            leaves every per-lane quantity — and therefore every summed
            counter and the order-free min-fold — bitwise-identical to one
            whole-level pass.
            """
            xyz_i, full_l, child_start, child_mask, code_own = \
                decode_meta_rows(meta, meta_fmt, level, pcode)
            xyz = xyz_i.astype(jnp.float32)
            node_c = [scal_ref[sb + i] + (xyz[:, i] + 0.5) * cell
                      for i in range(3)]
            tt = [oc[i] - node_c[i] for i in range(3)]
            A = [[jnp.abs(R[i][k]) + _EPS for k in range(3)]
                 for i in range(3)]
            collide_l, exit_code = sact_tile(tt, R, A, [node_h] * 3, oh,
                                             use_spheres=use_spheres)
            is_term = full_l | (level == depth)
            overlap = collide_l & in_w
            term_hit = overlap & is_term
            # Terminal hits fold the lane's payload into its GROUP's best.
            fold = jnp.minimum(best_cur, jnp.min(
                jnp.where(term_hit[:, None] & o_onehot, pay_lane[:, None],
                          inf), axis=0))
            term_valid = jnp.where(in_w & is_term, 1, 0)
            d_leaf = jnp.sum(term_valid)
            d_axis = jnp.sum(
                jnp.where(in_w, axis_tests_from_exit(exit_code), 0))
            d_hist = jnp.sum(
                jnp.where((exit_code[:, None] == iota_hist[None, :])
                          & (term_valid[:, None] != 0), 1, 0), axis=0)
            # Expansion candidates stash: a zero mask == not a candidate.
            cand_mask = jnp.where(overlap & ~is_term, child_mask, 0)
            return fold, d_leaf, d_axis, d_hist, cand_mask, child_start, \
                code_own

        if stream:
            off_l = off_ref[s * L + level]
            cnt_l = cnt_ref[s * L + level]
            nwin = -(-n_max // wsub)            # static window-index bound
            big = jnp.int32(nwin)
            win_lane = jnp.where(valid, (idx - off_l) // wsub, big)
            w0 = jnp.min(win_lane)

            @pl.when(w0 < big)
            def _():
                _win_dma("start", level, w0, 0)

            def wbody(w, wc):
                (k, fold, leaf_a, axis_a, hist_a, st_mask, st_start,
                 st_code, rows_a) = wc
                in_w = valid & (win_lane == w)
                has_w = jnp.sum(jnp.where(in_w, 1, 0)) > 0
                ks = jax.lax.rem(k, 2)

                @pl.when(has_w)
                def _():
                    _win_dma("wait", level, w, ks)

                # Put the tile's NEXT live window in flight into the other
                # slot before any SACT work — the copy overlaps the pass.
                nxt = jnp.min(jnp.where(valid & (win_lane > w), win_lane,
                                        big))

                @pl.when(has_w & (nxt < big))
                def _():
                    _win_dma("start", level, nxt, 1 - ks)

                g_lo = off_l + w * wsub
                win_lo = (g_lo // 8) * 8
                local = jnp.clip(idx - win_lo, 0, WS - 1)
                meta = jnp.take(meta_scr[...], ks * WS + local, axis=0)
                f, d_leaf, d_axis, d_hist, cm, cs, co = sact_window(
                    meta, in_w, fold)
                occ = jnp.clip(cnt_l - w * wsub, 0, wsub)
                span = (-(-(g_lo + occ) // 8)) * 8 - win_lo
                return (k + jnp.where(has_w, 1, 0), f,
                        leaf_a + d_leaf, axis_a + d_axis, hist_a + d_hist,
                        jnp.where(in_w, cm, st_mask),
                        jnp.where(in_w, cs, st_start),
                        jnp.where(in_w, co, st_code),
                        rows_a + jnp.where(has_w, span, 0))

            wmax = jnp.max(jnp.where(valid, win_lane + 1, 0))
            z = jnp.zeros((fcap,), jnp.int32)
            (_, best_vec, d_leaf, d_axis, d_hist, st_mask, st_start,
             st_code, d_rows) = jax.lax.fori_loop(
                0, wmax, wbody,
                (jnp.int32(0), best_vec, jnp.int32(0), jnp.int32(0),
                 jnp.zeros((NUM_EXIT_CODES,), jnp.int32), z, z, z,
                 jnp.int32(0)))
            meta_rows = meta_rows + d_rows
        else:
            meta = jnp.take(meta_flat,
                            level * n_max + jnp.clip(idx, 0, n_max - 1),
                            axis=0)
            (best_vec, d_leaf, d_axis, d_hist, st_mask, st_start,
             st_code) = sact_window(meta, valid, best_vec)

        # ---- group-best gate + work accounting (fused-arm formulas) ---
        best_lane = jnp.min(jnp.where(o_onehot, best_vec[None, :], inf),
                            axis=1)
        n_valid = jnp.sum(valid.astype(jnp.int32))
        leaf = leaf + d_leaf
        axis_exec = axis_exec + d_axis
        sphere = sphere + (2 * n_valid if use_spheres else 0)
        per_level = per_level + jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (1, L), 1).reshape((L,))
            == level, n_valid, 0)
        hist = hist + d_hist

        # ---- in-register CSR expansion + compaction -------------------
        # A lane expands iff it stashed a candidate mask (overlap & ~term;
        # a real candidate's mask is never 0 — a non-full internal node
        # has at least one occupied child) and its payload could still
        # beat its group's best AFTER this level's folds.
        expand = (st_mask != 0) & (pay_lane < best_lane)
        occupied, offs = csr_child_slots(st_mask)
        n_child = jnp.where(expand,
                            jax.lax.population_count(st_mask), 0)
        base = jnp.cumsum(n_child) - n_child
        n_new = jnp.sum(n_child)
        live = expand[:, None] & occupied                          # (fcap, 8)
        pos = base[:, None] + offs
        q_rep = jnp.repeat(q, 8)
        cand = (st_start[:, None] + offs).reshape(-1)
        tgt = jnp.where(live, pos, fcap).reshape(-1)
        q_next = jnp.zeros((fcap,), jnp.int32).at[tgt].set(q_rep,
                                                           mode="drop")
        i_next = jnp.zeros((fcap,), jnp.int32).at[tgt].set(cand,
                                                           mode="drop")

        # ---- HBM spill ring: children past fcap, newest-wrapping ------
        in_ring = live & (pos >= fcap)
        ring_tgt = jnp.where(
            in_ring, jax.lax.rem(cursor + (pos - fcap), ring_cap),
            ring_cap).reshape(-1)
        ring = ring.at[ring_tgt, 0].set(q_rep, mode="drop")
        ring = ring.at[ring_tgt, 1].set(cand, mode="drop")
        spill_now = jnp.maximum(n_new - fcap, 0)
        overflow = overflow + spill_now
        spilled = spilled + spill_now
        cursor = jax.lax.rem(cursor + spill_now, ring_cap)

        # ---- double-buffer write: next level reads the other slot -----
        nxt = 1 - slot
        fq_scr[0, :] = jnp.where(nxt == 0, q_next, fq_scr[0, :])
        fq_scr[1, :] = jnp.where(nxt == 1, q_next, fq_scr[1, :])
        fn_scr[0, :] = jnp.where(nxt == 0, i_next, fn_scr[0, :])
        fn_scr[1, :] = jnp.where(nxt == 1, i_next, fn_scr[1, :])
        if meta_fmt == "u8":
            # Children inherit this lane's own code as their pcode.
            p_next = jnp.zeros((fcap,), jnp.int32).at[tgt].set(
                jnp.repeat(st_code, 8), mode="drop")
            fp_scr[0, :] = jnp.where(nxt == 0, p_next, fp_scr[0, :])
            fp_scr[1, :] = jnp.where(nxt == 1, p_next, fp_scr[1, :])
        return (jnp.minimum(n_new, fcap), best_vec, per_level, hist,
                leaf, axis_exec, sphere, overflow, spilled, cursor, ring,
                meta_rows)

    if not stream:
        meta_flat = meta_ref[...].reshape(L * n_max, vpf)

    # Seed frontier (slot 0): one (query, scene root) pair per live slot of
    # the tile.  Scene s's root sits at flat index s of the level-0 row
    # (0 for a single scene).
    fq_scr[0, :] = jnp.where(lane < n_q, q_base + lane, 0)
    fn_scr[0, :] = jnp.where(lane < n_q, s, 0)
    if meta_fmt == "u8":
        # Scene-local codes: every scene's root code is 0.
        fp_scr[0, :] = jnp.zeros((fcap,), jnp.int32)

    carry0 = (jnp.minimum(n_q, fcap),
              jnp.full((bq,), PAYLOAD_INF, jnp.int32),
              jnp.zeros((L,), jnp.int32),
              jnp.zeros((NUM_EXIT_CODES,), jnp.int32),
              jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
              jnp.int32(0), jnp.int32(0),
              jnp.zeros((ring_cap, 2), jnp.int32),
              jnp.int32(0))
    (_, best_vec, per_level, hist, leaf, axis_exec, sphere, overflow,
     spilled, _, ring, meta_rows) = jax.lax.fori_loop(0, L, level_body,
                                                      carry0)

    collide_ref[...] = best_vec.reshape(1, bq)
    perlevel_ref[...] = per_level.reshape(1, L)
    hist_ref[...] = hist.reshape(1, NUM_EXIT_CODES)
    nodes = jnp.sum(per_level)
    scalars_ref[...] = jnp.stack(
        [nodes, leaf, axis_exec, nodes * NUM_AXES, sphere, overflow,
         spilled, meta_rows]).reshape(1, 8)
    ring_ref[...] = ring.reshape(1, ring_cap, 2)


def make_persist_call(num_tiles: int, bq: int, fcap: int, depth: int,
                      n_max: int, ring_cap: int, use_spheres: bool,
                      interpret: bool, stream: bool, meta_fmt: str = "fp32",
                      num_scenes: int = 1, wsub: int = 1024):
    """Build the whole-traversal pallas_call.

    Inputs: scal (S * (3 + depth+1),) f32 SMEM — per scene [scene_lo xyz,
    per-level cells], flat scene-major; scene_off / scene_counts
    (S * (depth+1),) int32 SMEM — per-scene flat sub-extents of the level
    rows (offset 0 / total counts for a single scene); scene_of_tile
    (num_tiles,) int32 SMEM; live query count (1,) int32 SMEM (the pool's
    live prefix — pad slots past it never seed, see the sharded executor);
    OBB table (num_tiles * bq, 15) f32, blocked per tile; node_meta
    (depth+1, n_max, words) int32 packed per ``meta_fmt`` (fp32: 4 words,
    bf16: 2, u8: 1 — :mod:`repro.core.quantize`) — a resident VMEM block,
    or an HBM-space (``pltpu.ANY``) table streamed through the ping/pong
    sub-level window scratch of ``wsub + 8`` rows per slot when ``stream``
    (the DMA machinery is format-agnostic: only the row width changes);
    payload (num_tiles * bq,) int32 per-query payload lane (all zeros for
    boolean plans); owner_local (num_tiles * bq,) int32 per-slot verdict
    group as the group's first tile-local slot, ``-1`` = pad (tile-local
    identity for per-query plans).  Outputs per tile: ``best`` payload
    words (bq,) int32 per owner slot (``PAYLOAD_INF`` = that group never
    hit; 0 = a boolean hit), valid counts per level, exit histogram,
    packed work scalars [nodes, leaf, axis_exec, axis_dec, sphere,
    overflow, spilled, meta_rows], and the spill ring's (query, node)
    pairs.
    """
    if pltpu is None:  # pragma: no cover - exercised only sans TPU extra
        raise RuntimeError("pallas TPU extension unavailable")
    if stream:
        assert n_max % META_ROW_ALIGN == 0, \
            "streamed node_meta needs META_ROW_ALIGN-aligned rows"
        assert wsub % 8 == 0 and wsub > 0, \
            "sub-level windows are whole 8-row DMA chunks"
    L = depth + 1
    vpf = META_FORMAT_WORDS[meta_fmt]
    kernel = functools.partial(
        persist_kernel, bq=bq, fcap=fcap, depth=depth, n_max=n_max,
        ring_cap=ring_cap, use_spheres=use_spheres, stream=stream,
        meta_fmt=meta_fmt, wsub=wsub)
    meta_spec = (pl.BlockSpec(memory_space=pltpu.ANY) if stream
                 else pl.BlockSpec((L, n_max, vpf), lambda t: (0, 0, 0)))
    scratch = [
        pltpu.VMEM((2, fcap), jnp.int32),    # frontier queries (2 slots)
        pltpu.VMEM((2, fcap), jnp.int32),    # frontier node indices
    ]
    if meta_fmt == "u8":
        scratch.append(pltpu.VMEM((2, fcap), jnp.int32))  # own-code lane
    if stream:
        scratch += [
            # sub-level window ping/pong pair, flat: slot s = rows
            # [s * (wsub + 8), (s + 1) * (wsub + 8)) — constant in n_max.
            pltpu.VMEM((2 * (wsub + 8), vpf), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),      # per-slot window DMAs
        ]
    return pl.pallas_call(
        kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scal
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scene_off
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scene_counts
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scene_of_tile
            pl.BlockSpec(memory_space=pltpu.SMEM),            # live count
            pl.BlockSpec((bq, 15), lambda t: (t, 0)),         # OBB tile
            meta_spec,                                        # node meta
            pl.BlockSpec((bq,), lambda t: (t,)),              # payload lane
            pl.BlockSpec((bq,), lambda t: (t,)),              # owner_local
        ],
        out_specs=[
            pl.BlockSpec((1, bq), lambda t: (t, 0)),
            pl.BlockSpec((1, L), lambda t: (t, 0)),
            pl.BlockSpec((1, NUM_EXIT_CODES), lambda t: (t, 0)),
            pl.BlockSpec((1, 8), lambda t: (t, 0)),
            pl.BlockSpec((1, ring_cap, 2), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles, bq), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, L), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, NUM_EXIT_CODES), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, 8), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, ring_cap, 2), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )
