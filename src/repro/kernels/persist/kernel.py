"""Persistent whole-traversal Pallas megakernel — one ``pallas_call`` for
the ENTIRE multi-level wavefront walk.

RoboGPU's central claim (§II, Fig. 11) is that a collision query should
stay *resident in the core* across the whole tree walk: conditional
returns, never spilling intermediates.  The per-level fused step
(:mod:`repro.kernels.traverse`) still launches one kernel per octree level
and round-trips the compacted frontier through HBM between levels; this
kernel removes that last HBM round trip.  The grid walks tiles of ``bq``
queries, and each grid step owns its tile's traversal end to end:

  1. the tile's frontier lives in a **double-buffered VMEM scratch** pair
     ``(2, fcap)`` of (query, CSR node index) lanes — level ``l`` reads
     slot ``l % 2`` and compacts survivors' children into slot
     ``(l + 1) % 2``; the frontier never exists in HBM;
  2. the **level loop runs inside the kernel body** (``lax.fori_loop`` over
     ``depth + 1`` levels; a drained frontier makes the remaining levels
     natural no-ops — every update is masked by ``lane < n_live``);
  3. each level gathers the lanes' query OBBs (one-hot matmul against the
     resident packed OBB table), reconstructs node AABBs from Morton codes
     in-register, and runs the two-phase staged SACT via the shared
     :func:`repro.kernels.sact.kernel.sact_tile` (tile-level conditional
     return skips the 9 edge axes once every lane is decided);
  4. CSR child expansion AND compaction happen **in-register**: per-parent
     child counts (popcount of the occupancy mask) are exclusive-scanned
     over the tile, child ``j`` of parent ``i`` lands at
     ``base[i] + popcount(mask[i] & ((1 << j) - 1))`` — no stream-compaction
     kernel, no candidate list in memory;
  5. children past ``fcap`` overflow to a per-tile **HBM spill ring**
     (``ring_cap`` most recent (query, node) pairs, wrapping) and are
     counted — the count lands in ``Counters.frontier_overflow`` and the
     engine's existing escalate-on-overflow policy replays the query set at
     a larger capacity, exactly as for the per-level arms.  Spilled pairs
     are *not* silently traversed: verdicts are exact iff the overflow
     count is zero.

Because queries are partitioned across tiles and a pair's whole subtree
stays in its query's tile, the early-exit coupling (a decided query
retires all its pairs) is tile-local, and on every clean (overflow-free)
run the union of per-tile work is *bitwise* the work of the global-frontier
fused arm: same pairs per level, same exit codes, same counters (summed
over tiles).  Overflow accounting, however, is per-tile: each tile owns
``fcap`` VMEM lanes, so with multiple tiles the aggregate frontier room is
``num_tiles * fcap`` and a frontier that overflows the ref's single global
pool may fit here (or vice versa under heavy skew).  Each backend
escalates against its *own* overflow count until clean, after which the
counters agree again; only the clamped regime (pinned
``frontier_capacity`` / ``max_frontier``), where verdicts under-approximate
by contract, may drop different pairs per backend.

Per-query HBM traffic collapses to: seed pair in, one verdict word out,
plus spill traffic — the bytes model of
:data:`repro.core.counters.BYTES_PERSIST_QUERY`.

The frontier carries a **payload lane** (:mod:`repro.engine.plan`): each
query's int32 payload rides its pairs, a terminal hit folds it into the
per-query ``best`` with a min (the verdict word), and a pair stays live
only while its payload could still beat its query's best.  All-zero
payloads reproduce the boolean engine bit-for-bit.  Cross-slot owner
lanes (per-EDGE first hit across a swept edge's segments) are served by
the reference arm: queries would no longer own their verdict groups
tile-exclusively — tiling by owner group is the follow-up (DESIGN.md §3).

The node metadata / OBB tables are held as resident VMEM blocks, which
bounds scene size on real hardware (~VMEM/16 B nodes); scaling past that
needs HBM-space DMA of metadata rows, noted in DESIGN.md §3.  On the CPU
CI matrix the kernel runs under ``interpret=True`` on small scenes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.counters import NUM_EXIT_CODES
from repro.core.octree import jnp_morton_decode
from repro.core.sact import PAYLOAD_INF, axis_tests_from_exit
from repro.kernels.persist.ref import csr_child_slots
# _EPS shared with every SACT arm: the bitwise identity across engines
# depends on all of them using the same epsilon and op order.
from repro.kernels.sact.kernel import _EPS, NUM_AXES, sact_tile

try:  # CPU-only containers may lack the TPU extension
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def persist_kernel(scal_ref, obb_ref, meta_ref, payload_ref, collide_ref,
                   perlevel_ref, hist_ref, scalars_ref, ring_ref, fq_scr,
                   fn_scr, *, num_queries: int, bq: int, fcap: int,
                   depth: int, n_max: int, ring_cap: int, use_spheres: bool):
    t = pl.program_id(0)
    L = depth + 1
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, fcap), 1).reshape((fcap,))
    q_base = t * bq
    n_q = jnp.clip(num_queries - q_base, 0, bq)

    scal = scal_ref[...]                       # [scene_lo(3), cells(L)]
    obb_tab = obb_ref[...]                     # (m_pad, 15) resident
    meta_flat = meta_ref[...].reshape(L * n_max, 4)
    pay_tile = payload_ref[...]                # (bq,) payload lane per query
    m_pad = obb_tab.shape[0]
    iota_q = jax.lax.broadcasted_iota(jnp.int32, (1, bq), 1).reshape((bq,))
    iota_hist = jax.lax.broadcasted_iota(
        jnp.int32, (1, NUM_EXIT_CODES), 1).reshape((NUM_EXIT_CODES,))

    # Seed frontier (slot 0): one (query, root) pair per query of the tile.
    fq_scr[0, :] = jnp.where(lane < n_q, q_base + lane, 0)
    fn_scr[0, :] = jnp.zeros((fcap,), jnp.int32)

    def level_body(level, carry):
        (n_live, best_vec, per_level, hist, leaf, axis_exec, sphere,
         overflow, spilled, cursor, ring) = carry
        slot = jax.lax.rem(level, 2)
        q = jnp.where(slot == 0, fq_scr[0, :], fq_scr[1, :])
        idx = jnp.where(slot == 0, fn_scr[0, :], fn_scr[1, :])
        valid = lane < n_live

        # ---- one metadata gather per lane (code, full, CSR cols) ------
        meta = jnp.take(meta_flat,
                        level * n_max + jnp.clip(idx, 0, n_max - 1), axis=0)
        codes = jax.lax.bitcast_convert_type(meta[:, 0], jnp.uint32)
        full_l = meta[:, 1] != 0
        child_start = meta[:, 2]
        child_mask = meta[:, 3]

        # ---- gather query boxes (one-hot matmul, OOB-safe) ------------
        onehot = (q[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (fcap, m_pad), 1)).astype(jnp.float32)
        rows = jnp.dot(onehot, obb_tab,
                       preferred_element_type=jnp.float32)        # (fcap, 15)
        oc = [rows[:, i] for i in range(3)]
        oh = [rows[:, 3 + i] for i in range(3)]
        R = [[rows[:, 6 + 3 * i + k] for k in range(3)] for i in range(3)]

        # ---- node AABB from Morton code, in-register ------------------
        cell = jnp.take(scal, 3 + level)
        xyz = jnp_morton_decode(codes).astype(jnp.float32)
        node_c = [scal[i] + (xyz[:, i] + 0.5) * cell for i in range(3)]
        node_h = cell * 0.5

        # ---- two-phase staged SACT (shared tile formulas) -------------
        tt = [oc[i] - node_c[i] for i in range(3)]
        A = [[jnp.abs(R[i][k]) + _EPS for k in range(3)] for i in range(3)]
        collide_l, exit_code = sact_tile(tt, R, A, [node_h] * 3, oh,
                                         use_spheres=use_spheres)

        is_term = full_l | (level == depth)
        overlap = collide_l & valid
        term_hit = overlap & is_term

        # ---- per-query payload-lane best, tile-local (queries never
        # cross tiles): a terminal hit folds the lane's payload in with a
        # min — the one-hot re-derivation of sact.payload_min_update —
        # and a lane stays live only while its payload could still beat
        # its query's best (boolean early exit == all-zero payloads).
        q_onehot = (q - q_base)[:, None] == iota_q[None, :]       # (fcap, bq)
        inf = jnp.int32(PAYLOAD_INF)
        pay_lane = jnp.sum(jnp.where(q_onehot, pay_tile[None, :], 0), axis=1)
        best_vec = jnp.minimum(best_vec, jnp.min(
            jnp.where(term_hit[:, None] & q_onehot, pay_lane[:, None], inf),
            axis=0))
        best_lane = jnp.min(jnp.where(q_onehot, best_vec[None, :], inf),
                            axis=1)

        # ---- work accounting (formulas of the fused arm, bitwise) -----
        n_valid = jnp.sum(valid.astype(jnp.int32))
        term_valid = jnp.where(valid & is_term, 1, 0)
        leaf = leaf + jnp.sum(term_valid)
        axis_exec = axis_exec + jnp.sum(
            jnp.where(valid, axis_tests_from_exit(exit_code), 0))
        sphere = sphere + (2 * n_valid if use_spheres else 0)
        per_level = per_level + jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (1, L), 1).reshape((L,))
            == level, n_valid, 0)
        hist = hist + jnp.sum(
            jnp.where((exit_code[:, None] == iota_hist[None, :])
                      & (term_valid[:, None] != 0), 1, 0), axis=0)

        # ---- in-register CSR expansion + compaction -------------------
        expand = overlap & ~is_term & (pay_lane < best_lane)
        occupied, offs = csr_child_slots(child_mask)
        n_child = jnp.where(expand,
                            jax.lax.population_count(child_mask), 0)
        base = jnp.cumsum(n_child) - n_child
        n_new = jnp.sum(n_child)
        live = expand[:, None] & occupied                          # (fcap, 8)
        pos = base[:, None] + offs
        q_rep = jnp.repeat(q, 8)
        cand = (child_start[:, None] + offs).reshape(-1)
        tgt = jnp.where(live, pos, fcap).reshape(-1)
        q_next = jnp.zeros((fcap,), jnp.int32).at[tgt].set(q_rep,
                                                           mode="drop")
        i_next = jnp.zeros((fcap,), jnp.int32).at[tgt].set(cand,
                                                           mode="drop")

        # ---- HBM spill ring: children past fcap, newest-wrapping ------
        in_ring = live & (pos >= fcap)
        ring_tgt = jnp.where(
            in_ring, jax.lax.rem(cursor + (pos - fcap), ring_cap),
            ring_cap).reshape(-1)
        ring = ring.at[ring_tgt, 0].set(q_rep, mode="drop")
        ring = ring.at[ring_tgt, 1].set(cand, mode="drop")
        spill_now = jnp.maximum(n_new - fcap, 0)
        overflow = overflow + spill_now
        spilled = spilled + spill_now
        cursor = jax.lax.rem(cursor + spill_now, ring_cap)

        # ---- double-buffer write: next level reads the other slot -----
        nxt = 1 - slot
        fq_scr[0, :] = jnp.where(nxt == 0, q_next, fq_scr[0, :])
        fq_scr[1, :] = jnp.where(nxt == 1, q_next, fq_scr[1, :])
        fn_scr[0, :] = jnp.where(nxt == 0, i_next, fn_scr[0, :])
        fn_scr[1, :] = jnp.where(nxt == 1, i_next, fn_scr[1, :])
        return (jnp.minimum(n_new, fcap), best_vec, per_level, hist,
                leaf, axis_exec, sphere, overflow, spilled, cursor, ring)

    carry0 = (jnp.minimum(n_q, fcap),
              jnp.full((bq,), PAYLOAD_INF, jnp.int32),
              jnp.zeros((L,), jnp.int32),
              jnp.zeros((NUM_EXIT_CODES,), jnp.int32),
              jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
              jnp.int32(0), jnp.int32(0),
              jnp.zeros((ring_cap, 2), jnp.int32))
    (_, best_vec, per_level, hist, leaf, axis_exec, sphere, overflow,
     spilled, _, ring) = jax.lax.fori_loop(0, L, level_body, carry0)

    collide_ref[...] = best_vec.reshape(1, bq)
    perlevel_ref[...] = per_level.reshape(1, L)
    hist_ref[...] = hist.reshape(1, NUM_EXIT_CODES)
    nodes = jnp.sum(per_level)
    scalars_ref[...] = jnp.stack(
        [nodes, leaf, axis_exec, nodes * NUM_AXES, sphere, overflow,
         spilled, jnp.int32(0)]).reshape(1, 8)
    ring_ref[...] = ring.reshape(1, ring_cap, 2)


def make_persist_call(num_queries: int, num_tiles: int, bq: int, fcap: int,
                      depth: int, n_max: int, m_pad: int, ring_cap: int,
                      use_spheres: bool, interpret: bool):
    """Build the whole-traversal pallas_call.

    Inputs: scal (3 + depth+1,) f32 SMEM [scene_lo xyz, per-level cells];
    obb table (m_pad, 15) f32; node_meta (depth+1, n_max, 4) int32 — both
    resident blocks; payload (num_tiles * bq,) int32 per-query payload
    lane (all zeros for boolean plans).  Outputs per query tile: ``best``
    payload words (bq,) int32 (``PAYLOAD_INF`` = query never hit; 0 = a
    boolean hit), valid counts per level, exit histogram, packed work
    scalars [nodes, leaf, axis_exec, axis_dec, sphere, overflow, spilled,
    0], and the spill ring's (query, node) pairs.
    """
    if pltpu is None:  # pragma: no cover - exercised only sans TPU extra
        raise RuntimeError("pallas TPU extension unavailable")
    L = depth + 1
    kernel = functools.partial(
        persist_kernel, num_queries=num_queries, bq=bq, fcap=fcap,
        depth=depth, n_max=n_max, ring_cap=ring_cap,
        use_spheres=use_spheres)
    return pl.pallas_call(
        kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scal
            pl.BlockSpec((m_pad, 15), lambda t: (0, 0)),      # OBB table
            pl.BlockSpec((L, n_max, 4), lambda t: (0, 0, 0)),  # node meta
            pl.BlockSpec((bq,), lambda t: (t,)),              # payload lane
        ],
        out_specs=[
            pl.BlockSpec((1, bq), lambda t: (t, 0)),
            pl.BlockSpec((1, L), lambda t: (t, 0)),
            pl.BlockSpec((1, NUM_EXIT_CODES), lambda t: (t, 0)),
            pl.BlockSpec((1, 8), lambda t: (t, 0)),
            pl.BlockSpec((1, ring_cap, 2), lambda t: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles, bq), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, L), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, NUM_EXIT_CODES), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, 8), jnp.int32),
            jax.ShapeDtypeStruct((num_tiles, ring_cap, 2), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, fcap), jnp.int32),    # frontier queries (2 slots)
            pltpu.VMEM((2, fcap), jnp.int32),    # frontier node indices
        ],
        interpret=interpret,
    )
