"""jnp oracle for the persistent whole-traversal megakernel.

Contract (shared with kernel.py): run the ENTIRE multi-level wavefront
traversal in one compiled call — level loop inside, frontier never
re-entering the caller between levels — and return exactly the
``(collide, stats)`` pair of the per-level fused arm
(:func:`repro.core.wavefront._traverse_fused`), bitwise, including every
work counter.

Two structural ideas carry the wall-clock win of the persistent mode and
both mirror the kernel:

1. **Live-prefix scheduling.**  The kernel never schedules frontier tiles
   at or past ``n_live``; the jnp analogue processes each level at the
   smallest power-of-two width >= ``n_live`` (a ``lax.switch`` over
   pre-compiled widths) instead of always paying the full static
   ``capacity``.  Lanes in ``[n_live, w)`` are masked exactly as the fused
   arm masks ``[n_live, capacity)``, so verdicts and counters cannot
   change — only dead-lane work disappears.  On typical scenes the live
   frontier is ~5-20x smaller than the escalation bucket.

2. **In-register CSR expansion/compaction.**  Instead of materializing the
   8x-expanded candidate list and stream-compacting ``8 * capacity`` lanes
   (cumsum + 2-channel scatter), survivors' children are placed directly:
   per-parent child counts (popcount of the CSR occupancy mask) are
   exclusive-scanned over ``w`` parents, and child ``j`` of parent ``i``
   lands at ``base[i] + popcount(mask[i] & ((1 << j) - 1))`` — the same
   ascending (parent-major, octant-minor) order the stream compactor
   produces, at 1/8th the scan length.  Children past ``capacity`` drop
   (highest positions first) and are counted in ``overflow``, identical to
   the fused arm's clamp.

The same function serves the ragged multi-scene frontier: with
``scene_of_query`` given, pairs are (scene, query, CSR node) triples over a
:class:`repro.core.octree.MultiSceneOctree` flat table — per-pair cell size
and scene origin are gathers by scene id, and scene ``s``'s root is flat
node ``s`` of the level-0 row.  One compiled call and one compaction pool
serve arbitrarily mixed scene sizes with no per-scene padding.

**Streamed-layout window model.**  Under the kernel's streamed metadata
layout (DESIGN.md §3) each query tile iterates a level through fixed-size
sub-level windows of ``stream_wsub`` rows over its OWN scene's sub-extent
of the (possibly concatenated multi-scene) level row, DMAing only the
row-exact occupied span of each window it actually touches.  With
``stream_bq`` / ``stream_wsub`` / ``scene_off`` / ``scene_counts`` /
``scene_of_tile`` given, the ref accumulates the *identical* schedule into
the ``meta_rows`` stat: lane query ids stay sorted through the
in-register compaction (children inherit their parent's query,
parent-major), so a kernel tile touches window ``w`` at level ``l``
exactly when some valid lane has ``q // bq == t`` and ``(node - off) //
wsub == w`` on the global pool — bitwise on every clean run, like the
other counters.  The fetched span of a touched window is its occupied
extent clipped to the window and rounded OUT to whole 8-row DMA chunks
(``floor8(lo) .. ceil8(hi)``), the kernel's exact descriptor arithmetic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sact as sact_mod
from repro.core.counters import NUM_EXIT_CODES
from repro.core.octree import (MAX_DEPTH, jnp_morton_decode,
                               node_centers_from_xyz)
from repro.core.quantize import (BF16_START_BITS, GRID_BITS, META_FORMATS,
                                 U8_START_BITS)
from repro.core.sact import NUM_AXES, PAYLOAD_INF, payload_min_update


def frontier_widths(capacity: int, w_min: int = 128) -> Tuple[int, ...]:
    """Power-of-two processing widths from ``w_min`` up to ``capacity``."""
    widths = []
    w = min(w_min, capacity)
    while w < capacity:
        widths.append(w)
        w *= 2
    widths.append(capacity)
    return tuple(widths)


def csr_child_slots(child_mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """CSR occupancy mask (K,) int32 -> (occupied (K, 8) bool, offs (K, 8)).

    ``offs[i, j] = popcount(mask[i] & ((1 << j) - 1))`` is both the child's
    rank among its parent's occupied octants and its offset from the
    parent's ``child_start`` — shared by the fused step, the persistent
    ref, and the megakernel.
    """
    eight = jnp.arange(8, dtype=jnp.int32)
    occupied = ((child_mask[:, None] >> eight[None, :]) & 1) != 0
    below = (jnp.int32(1) << eight) - 1
    offs = jax.lax.population_count(child_mask[:, None] & below[None, :])
    return occupied, offs


def decode_meta_rows(meta, meta_format: str, level, pcode=None):
    """In-register dequantize of gathered packed metadata rows.

    Shared by the jnp ref arm and the Pallas megakernel (identical jnp
    ops on the same int words -> bitwise-identical geometry and
    topology across formats).  ``meta`` is the (w, words) int32 gather
    for one level; ``pcode`` is the frontier's carried parent-code lane
    (u8 format only — the row stores just the child's octant).

    Returns ``(xyz, full, child_start, child_mask, code_own)`` where
    ``xyz`` is (w, 3) int32 cell coordinates at ``level`` and
    ``code_own`` the lane's own Morton code (int32; only meaningful —
    and only used — under ``meta_format="u8"``, where children inherit
    it as their ``pcode``).
    """
    if meta_format == "fp32":
        codes = jax.lax.bitcast_convert_type(meta[:, 0], jnp.uint32)
        full_l = meta[:, 1] != 0
        child_start = meta[:, 2]
        child_mask = meta[:, 3]
        return (jnp_morton_decode(codes), full_l, child_start, child_mask,
                jnp.zeros(meta.shape[:1], jnp.int32))
    w0 = meta[:, 0]
    # Topology word: full << 31 | [octant << 28 |] child_start << 8 | mask.
    # w0 >> k is an arithmetic shift (sign-extends when full is set); the
    # field masks strip the extension bits.
    full_l = w0 < 0
    child_mask = w0 & 0xFF
    if meta_format == "bf16":
        child_start = (w0 >> 8) & ((1 << BF16_START_BITS) - 1)
        w1 = meta[:, 1]
        # Geometry word: 3 x 10-bit leaf-grid coords; a level-l cell
        # coordinate is the field shifted back down (exact by packing).
        shift = jnp.int32(GRID_BITS) - level
        xyz = jnp.stack([((w1 >> 20) & 0x3FF) >> shift,
                         ((w1 >> 10) & 0x3FF) >> shift,
                         (w1 & 0x3FF) >> shift], axis=-1)
        return xyz, full_l, child_start, child_mask, \
            jnp.zeros(meta.shape[:1], jnp.int32)
    assert meta_format == "u8" and pcode is not None, \
        f"unknown meta_format {meta_format!r}; allowed: {META_FORMATS}"
    child_start = (w0 >> 8) & ((1 << U8_START_BITS) - 1)
    code_own = (pcode << 3) | ((w0 >> 28) & 7)
    xyz = jnp_morton_decode(code_own.astype(jnp.uint32))
    return xyz, full_l, child_start, child_mask, code_own


def _empty_stats():
    return dict(
        nodes=jnp.int32(0), leaf=jnp.int32(0), axis_exec=jnp.int32(0),
        axis_dec=jnp.int32(0), sphere=jnp.int32(0), overflow=jnp.int32(0),
        per_level=jnp.zeros((MAX_DEPTH + 1,), jnp.int32),
        exit_hist=jnp.zeros((NUM_EXIT_CODES,), jnp.int32),
        meta_rows=jnp.int32(0))


def traverse_whole_ref(obb_c, obb_h, obb_r, node_meta, cell_sizes, scene_lo,
                       depth: int, capacity: int, use_spheres: bool,
                       scene_of_query: Optional[jax.Array] = None,
                       w_min: int = 128, owner_of_query=None, payload=None,
                       stream_bq: Optional[int] = None,
                       stream_wsub: Optional[int] = None,
                       scene_off: Optional[jax.Array] = None,
                       scene_counts: Optional[jax.Array] = None,
                       scene_of_tile: Optional[jax.Array] = None,
                       num_valid=None, valid_of_query=None,
                       meta_format: str = "fp32",
                       codes: Optional[jax.Array] = None):
    """Whole-traversal reference arm; see module docstring for the contract.

    Args:
      node_meta: (depth+1, n_max, words) int32 packed CSR metadata rows
        (fp32: [code, full, child_start, child_mask]; bf16/u8: the
        compressed layouts of :mod:`repro.core.quantize`); single-scene
        ``DeviceOctree.node_meta`` or the flat ``MultiSceneOctree`` table.
      meta_format: row encoding of ``node_meta`` ("fp32" | "bf16" | "u8");
        must match the packing the table was built with.  Under "u8" the
        row stores only the node's octant: the kernel carries an extra
        own-Morton-code frontier lane, while this ref gathers the same
        bits from ``codes`` (required then) — the retained
        ``DeviceOctree.codes`` plane.
      cell_sizes: (depth+1,) f32, or (S, depth+1) when ragged.
      scene_lo: (3,) f32, or (S, 3) when ragged.
      scene_of_query: (Q,) int32 scene id per flat query, or None for a
        single scene.
      owner_of_query / payload: optional verdict-group and payload lanes
        (:mod:`repro.engine.plan`): the verdict becomes the (Q,) int32
        per-group ``best`` payload that hit (``PAYLOAD_INF`` = never;
        owner ids are compact so cells past the group count are unused),
        and a pair expands only while its payload could still beat its
        group's best — boolean early exit is the identity-owner,
        zero-payload special case.
      stream_bq / stream_wsub / scene_off / scene_counts / scene_of_tile:
        model the megakernel's streamed metadata layout (see module
        docstring): ``stream_bq`` is the kernel's query-tile width,
        ``stream_wsub`` the fixed sub-level window size in rows,
        ``scene_off`` / ``scene_counts`` the (S, depth+1) per-scene flat
        sub-extents of the level rows (S = 1 and offset 0 for a single
        scene), and ``scene_of_tile`` the (num_tiles,) scene id of each
        query tile.  The ``meta_rows`` stat then counts the row-exact
        spans the per-(tile, window) schedule fetches; without them it
        stays 0 (resident layout).
      num_valid: optional live-prefix query count (int, possibly traced):
        only slots ``[0, num_valid)`` of the pool seed the frontier; the
        tail is padding that contributes ZERO work to any counter.  The
        sharded executor pads every shard's pool to a common width and
        passes each shard's true count here, which is what makes sharded
        counters bitwise-equal to single-device (``None`` = all Q slots
        are live).
      valid_of_query: optional (Q,) bool mask of live pool slots for
        tiled (owner-group / ragged) pools, whose pads sit at each
        TILE's tail rather than the pool's.  Live slots seed the
        frontier in ascending slot order; masked slots contribute zero
        work, exactly like the ``num_valid`` tail.  Mutually exclusive
        with ``num_valid``.
    Returns:
      (verdict, stats dict) — the ``_traverse_fused`` contract: (Q,) bool
      collide flags, or the (Q,) ``best`` array for grouped calls.
    """
    Q = obb_c.shape[0]
    n_max = node_meta.shape[-2]
    assert meta_format != "u8" or codes is not None, \
        "u8 rows need the codes plane to reconstruct lane geometry"
    ragged = scene_of_query is not None
    grouped = owner_of_query is not None or payload is not None
    model_stream = stream_wsub is not None
    if model_stream:
        assert scene_off is not None and scene_counts is not None \
            and scene_of_tile is not None and stream_bq is not None, \
            "streamed-window model needs the full (bq, wsub, extents) spec"
        num_tiles = -(-Q // stream_bq)
        num_wins = -(-n_max // stream_wsub)   # static window grid per level
    else:
        num_tiles = num_wins = 0
    widths = frontier_widths(capacity, w_min)
    widths_arr = jnp.asarray(widths, jnp.int32)

    def make_branch(w: int):
        lane_w = jnp.arange(w, dtype=jnp.int32)

        def branch(level, n_live, q_idx, node_idx, verdict, st):
            q = q_idx[:w]
            idx = node_idx[:w]
            idx_c = jnp.clip(idx, 0, n_max - 1)
            valid = lane_w < n_live
            meta_row = jax.lax.dynamic_index_in_dim(node_meta, level,
                                                    keepdims=False)
            meta = meta_row[idx_c]                              # (w, words)
            if meta_format == "u8":
                # The kernel carries an own-Morton-code frontier lane (it
                # cannot reach the codes plane under streaming); the ref
                # gathers the lane's code from the retained plane instead —
                # same bits ((pcode << 3) | octant reconstructs the gathered
                # code exactly), no capacity-sized carry or scatter.
                pcode = (jax.lax.dynamic_index_in_dim(
                    codes, level, keepdims=False)[idx_c].astype(jnp.int32)
                    >> 3)
            else:
                pcode = None
            xyz, full_l, child_start, child_mask, code_own = decode_meta_rows(
                meta, meta_format, level, pcode)
            is_leaf = level == depth

            if ragged:
                sid = scene_of_query[q]
                cell = jax.lax.dynamic_index_in_dim(
                    cell_sizes, level, axis=1, keepdims=False)[sid]   # (w,)
                lo = scene_lo[sid]                                    # (w, 3)
            else:
                cell = jax.lax.dynamic_index_in_dim(cell_sizes, level,
                                                    keepdims=False)
                lo = scene_lo
            node_c, node_h = node_centers_from_xyz(xyz, lo, cell)
            res = sact_mod.sact_frontier_staged(
                obb_c[q], obb_h[q], obb_r[q], node_c, node_h, valid,
                use_spheres=use_spheres)
            is_term = jnp.where(is_leaf, True, full_l)
            overlap = res.collide & valid
            term_hit = overlap & is_term
            if grouped:
                pay = (jnp.zeros(q.shape, jnp.int32) if payload is None
                       else payload[q])
                own = q if owner_of_query is None else owner_of_query[q]
                verdict = payload_min_update(verdict, own, pay, term_hit)
                undecided = pay < verdict[own]
            else:
                verdict = verdict.at[q].max(term_hit)
                undecided = ~verdict[q]

            # ---- work accounting (formulas of the fused arm, bitwise) ----
            n_valid = jnp.sum(valid.astype(jnp.int32))
            term_valid = (valid & is_term).astype(jnp.int32)

            # ---- in-register CSR expansion (see module docstring) --------
            expand = overlap & ~is_term & undecided
            occupied, offs = csr_child_slots(child_mask)
            n_child = jnp.where(expand,
                                jax.lax.population_count(child_mask), 0)
            base = jnp.cumsum(n_child) - n_child                  # (w,)
            n_new = jnp.sum(n_child)
            live = expand[:, None] & occupied
            tgt = jnp.where(live, base[:, None] + offs,
                            capacity).reshape(-1)
            q_next = jnp.zeros((capacity,), jnp.int32).at[tgt].set(
                jnp.repeat(q, 8), mode="drop")
            idx_next = jnp.zeros((capacity,), jnp.int32).at[tgt].set(
                (child_start[:, None] + offs).reshape(-1), mode="drop")

            # ---- streamed-window schedule model (kernel-identical) -------
            if model_stream:
                # A kernel tile fetches window w of ITS scene's sub-extent
                # at this level iff some valid lane of the tile points into
                # it; the fetched span is the window's occupied extent
                # rounded out to whole 8-row DMA chunks.
                off_l = jax.lax.dynamic_index_in_dim(
                    scene_off, level, axis=1, keepdims=False)       # (S,)
                cnt_l = jax.lax.dynamic_index_in_dim(
                    scene_counts, level, axis=1, keepdims=False)    # (S,)
                off_lane = off_l[sid] if ragged else off_l[0]
                win = jnp.clip((idx - off_lane) // stream_wsub,
                               0, num_wins - 1)
                live = jnp.zeros((num_tiles, num_wins), jnp.int32).at[
                    q // stream_bq, win].max(valid.astype(jnp.int32),
                                             mode="drop")
                off_t = off_l[scene_of_tile][:, None]       # (T, 1)
                cnt_t = cnt_l[scene_of_tile][:, None]
                wlo = jnp.arange(num_wins, dtype=jnp.int32)[None, :] \
                    * stream_wsub                           # (1, NW)
                occ = jnp.clip(cnt_t - wlo, 0, stream_wsub)
                g_lo = off_t + wlo
                g_hi = g_lo + occ
                span = jnp.where(occ > 0,
                                 (-(-g_hi // 8)) * 8 - (g_lo // 8) * 8, 0)
                meta_rows = st["meta_rows"] + jnp.sum(live * span)
            else:
                meta_rows = st["meta_rows"]

            st = dict(
                nodes=st["nodes"] + n_valid,
                leaf=st["leaf"] + jnp.sum(term_valid),
                axis_exec=st["axis_exec"] + jnp.sum(res.axis_tests),
                axis_dec=st["axis_dec"] + n_valid * NUM_AXES,
                sphere=st["sphere"] + jnp.sum(res.sphere_tests),
                overflow=st["overflow"] + jnp.maximum(n_new - capacity, 0),
                per_level=st["per_level"].at[level].set(n_valid),
                exit_hist=st["exit_hist"].at[res.exit_code].add(term_valid),
                meta_rows=meta_rows)
            return (level + 1, jnp.minimum(n_new, capacity), q_next,
                    idx_next, verdict, st)
        return branch

    branches = [make_branch(w) for w in widths]

    def body(carry):
        n_live = carry[1]
        k = jnp.sum((widths_arr < n_live).astype(jnp.int32))
        return jax.lax.switch(k, branches, *carry)

    def cond(carry):
        level, n_live = carry[0], carry[1]
        return (level <= depth) & (n_live > 0)

    lane = jnp.arange(capacity, dtype=jnp.int32)
    if valid_of_query is not None:
        assert num_valid is None, \
            "valid_of_query and num_valid are mutually exclusive"
        # Tiled pools pad at each TILE's tail: compact the live slots (in
        # ascending slot order, preserving the tile-contiguous layout the
        # window model keys on) into the frontier prefix.
        (q0,) = jnp.nonzero(valid_of_query, size=capacity, fill_value=0)
        q0 = q0.astype(jnp.int32)
        n0 = jnp.sum(valid_of_query.astype(jnp.int32))
    else:
        q0 = jnp.where(lane < Q, lane, 0)
        n0 = jnp.asarray(Q if num_valid is None else num_valid, jnp.int32)
    if ragged:
        # scene s's root sits at flat index s of the level-0 row.
        node0 = scene_of_query[q0].astype(jnp.int32)
    else:
        node0 = jnp.zeros((capacity,), jnp.int32)
    verdict0 = (jnp.full((Q,), PAYLOAD_INF, jnp.int32) if grouped
                else jnp.zeros((Q,), bool))
    carry0 = (jnp.int32(0), jnp.minimum(n0, jnp.int32(capacity)),
              q0, node0, verdict0, _empty_stats())
    out = jax.lax.while_loop(cond, body, carry0)
    return out[4], out[5]
