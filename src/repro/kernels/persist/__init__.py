# Persistent whole-traversal megakernel: the ENTIRE multi-level wavefront
# walk in one pallas_call — per-tile double-buffered VMEM frontier, in-kernel
# level loop, in-register CSR expansion/compaction, HBM spill ring.  The jnp
# reference arm mirrors it with live-prefix width scheduling.  Backs
# ``EngineConfig.mode == "wavefront_persistent"`` and the ragged multi-scene
# flat frontier of ``query_batched_scenes``.
