# Persistent whole-traversal megakernel: the ENTIRE multi-level wavefront
# walk in one pallas_call — per-tile double-buffered VMEM frontier, in-kernel
# level loop, in-register CSR expansion/compaction, HBM spill ring, and (for
# scenes past the VMEM residency budget) double-buffered HBM->VMEM streaming
# of per-level node-metadata windows.  The jnp reference arm mirrors it with
# live-prefix width scheduling and models the same window schedule.  Backs
# ``EngineConfig.mode == "wavefront_persistent"`` and the ragged multi-scene
# flat frontier of ``query_batched_scenes``.
