"""Dispatch + glue for the persistent whole-traversal megakernel.

``traverse_whole`` is the single entry point of ``mode=
"wavefront_persistent"``: the ENTIRE multi-level traversal in one call —
the Pallas megakernel on TPU (or ``interpret=True`` for the CPU CI
matrix), the live-prefix jnp reference elsewhere.  Both arms share the
contract of :func:`repro.engine.executor._traverse_fused` — identical
``(collide, stats)`` including every work counter — so the engine's
escalation policy and counter plumbing are mode-agnostic.

**Every plan shape runs on the kernel arm.**  Plans whose pairs cannot be
tiled per-query — cross-slot owner groups (swept-edge CCD) and ragged
multi-scene batches — are lowered to a **tiled pool** first
(:func:`build_tile_map`): pool slots are permuted so every verdict group
lands in one ``bq``-slot tile, tiles never mix scenes, and pads sit at
each tile's tail.  Both arms then consume the SAME permuted pool (the ref
via a slot validity mask), so verdicts and all work counters stay
bitwise-comparable; outputs are mapped back to query/group space in-graph.
The only capability fallback left is an owner group too large for the
largest tile (:data:`MAX_TILE_BQ`; :func:`persist_kernel_unsupported`
names it so the executor can count and log the downgrade).

**Metadata residency layouts x row formats.**  The megakernel holds node
metadata in one of two layouts (:data:`META_LAYOUTS`, DESIGN.md §3):

* ``resident`` — the whole ``(depth+1, n_max, words)`` table is a VMEM
  block (:func:`meta_table_bytes`); fastest when it fits.
* ``streamed`` — the table stays in HBM and each level is iterated
  through fixed-size sub-level windows of :func:`sub_window_rows` rows,
  double-buffered through a ping/pong VMEM scratch pair
  (:func:`meta_stream_bytes` resident bytes — constant in ``n_max``); the
  row-exact fetched spans are counted into the ``meta_rows`` stat →
  ``Counters.meta_rows_streamed`` → priced at the format's row width.

Rows come in one of three formats (:data:`repro.core.quantize.META_FORMATS`:
fp32 = 16 B, bf16 = 8 B, u8 = 4 B — see :mod:`repro.core.quantize` for the
encodings and the soundness argument).  The format is a property of the
packed :class:`DeviceOctree` / :class:`MultiSceneOctree`
(``dev.meta_format``); both arms decode it in-register and
verdicts/counters are bitwise format-independent.

``traverse_whole(streamed=None)`` picks the layout with
:func:`choose_meta_layout` against :data:`DEFAULT_VMEM_BUDGET` (pinning
the tree's own format); the engine's executor runs the full
layout x format chooser per (mode, statics) traversal cache key and
passes both down explicitly (``EngineConfig.stream_meta`` /
``meta_format`` / ``vmem_budget`` override it).  Ragged multi-scene
tables stream and compress exactly like single scenes — the per-scene
sub-extents (``MultiSceneOctree.scene_off`` / ``scene_counts``) key each
tile's window schedule to its own scene.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.counters import (BYTES_META_STREAM, BYTES_META_STREAM_BF16,
                                 BYTES_META_STREAM_U8)
from repro.core.octree import (MAX_DEPTH, META_ROW_ALIGN, DeviceOctree,
                               MultiSceneOctree, align_rows)
from repro.core.quantize import META_FORMATS, format_eligible
from repro.core.sact import PAYLOAD_INF
from repro.kernels.persist.ref import traverse_whole_ref
from repro.kernels.sact.ops import pack_obbs

#: Node-metadata layouts of the persistent megakernel (drift-guarded
#: against the DESIGN.md §3 / README residency tables).
META_LAYOUTS = ("resident", "streamed")

#: Bytes per node-metadata row ([code, full, child_start, child_mask],
#: 4 x int32) — the unit of the residency estimates, aliased to the
#: traffic model's ``BYTES_META_STREAM`` so the two can never drift.
META_BYTES_PER_ROW = BYTES_META_STREAM

#: Bytes per packed row by format, aliased to the traffic-model constants
#: (:mod:`repro.core.quantize` defines the encodings; fp32 = 4 int32
#: words, bf16 = 2, u8 = 1).
META_FORMAT_BYTES = {"fp32": BYTES_META_STREAM,
                     "bf16": BYTES_META_STREAM_BF16,
                     "u8": BYTES_META_STREAM_U8}

#: Default VMEM budget for the resident node-metadata table.  Real TPU
#: cores have ~16 MiB of VMEM; the megakernel also needs its frontier
#: scratch, the per-tile OBB block, and (streamed) the window pair, so
#: the table gets half.  ``EngineConfig.vmem_budget`` overrides per
#: engine; CPU/interpret runs have no hard limit but honor the same
#: estimate so layout choice is backend-independent.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024

#: Fixed sub-level window size in rows for the streamed layout: each
#: level is iterated ``wsub`` rows at a time, so the VMEM window scratch
#: is constant in ``n_max`` (a level narrower than this streams in one
#: window, as the PR 5 whole-level windows did).
SUB_WINDOW_ROWS = 1024

#: Largest owner-group tile the megakernel will build.  A verdict group
#: must fit in one tile (its fold cell is tile-local), so a plan whose
#: largest owner group exceeds this many pairs is a genuine capability
#: fallback to the ref arm (:func:`persist_kernel_unsupported`).
MAX_TILE_BQ = 1024


def meta_table_bytes(depth: int, n_max: int, fmt: str = "fp32") -> int:
    """VMEM bytes of the RESIDENT node-metadata table (aligned rows)."""
    return (depth + 1) * align_rows(n_max) * META_FORMAT_BYTES[fmt]


def sub_window_rows(n_max: int) -> int:
    """Streamed sub-level window size in rows for an ``n_max``-wide table
    (the fixed :data:`SUB_WINDOW_ROWS`, shrunk to the aligned table width
    when the whole table is narrower)."""
    return min(SUB_WINDOW_ROWS, align_rows(n_max))


def meta_stream_bytes(n_max: int, fmt: str = "fp32") -> int:
    """VMEM bytes of the STREAMED layout's ping/pong window pair.

    Each slot holds one fixed-size sub-level window plus one 8-row DMA
    chunk of slack (row-exact spans round the occupied extent OUT to
    whole 8-row chunks, so a window's span can start up to 7 rows before
    its first occupied row).  Constant in ``n_max`` once the table is
    wider than :data:`SUB_WINDOW_ROWS`: VMEM scratch is fully decoupled
    from the widest level, so arbitrarily large scenes stream through
    the same budget.
    """
    return 2 * (sub_window_rows(n_max) + 8) * META_FORMAT_BYTES[fmt]


class MetaChoice(NamedTuple):
    """A point in the {resident, streamed} x {fp32, bf16, u8} plan space."""
    layout: str
    fmt: str


def choose_meta_layout(depth: int, n_max: int,
                       budget: int = DEFAULT_VMEM_BUDGET,
                       fmt: Optional[str] = None,
                       layout: Optional[str] = None) -> MetaChoice:
    """Layout/format chooser over {resident, streamed} x {fp32, bf16, u8}.

    ``fmt`` / ``layout`` pin one or both axes (``None`` = free).  Rules:

    * **Format preference runs widest-first for residency** (fp32 > bf16 >
      u8): compression is only taken when it buys residency the wider
      format cannot afford — a table that fits in fp32 stays fp32 (zero
      decode cost, no reason to compress).
    * **Streamed rows are narrowest-first** (u8 > bf16 > fp32): once the
      table streams, row width is pure HBM traffic, so the narrowest
      *eligible* format wins.
    * **Eligibility** (:func:`repro.core.quantize.format_eligible`) caps
      compressed formats by their CSR ``child_start`` field width (bf16:
      23 bits, u8: 20); fp32 is always eligible.

    Pinning an ineligible ``fmt`` raises ``ValueError`` (a packed table
    with overflowed pointers cannot exist); a free search only visits
    eligible formats, so the fallback is always sound.
    """
    if fmt is not None and fmt not in META_FORMATS:
        raise ValueError(f"unknown meta_format {fmt!r}; "
                         f"allowed: {META_FORMATS}")
    if layout is not None and layout not in META_LAYOUTS:
        raise ValueError(f"unknown meta layout {layout!r}; "
                         f"allowed: {META_LAYOUTS}")
    if fmt is not None and not format_eligible(fmt, n_max):
        raise ValueError(
            f"meta_format {fmt!r} cannot index {n_max} rows per level "
            "(CSR child_start field overflow)")
    widest = [f for f in META_FORMATS if format_eligible(f, n_max)]
    narrowest = widest[::-1]
    if fmt is not None:
        if layout is None:
            layout = ("resident"
                      if meta_table_bytes(depth, n_max, fmt) <= budget
                      else "streamed")
        return MetaChoice(layout, fmt)
    if layout == "resident":
        for f in widest:
            if meta_table_bytes(depth, n_max, f) <= budget:
                return MetaChoice("resident", f)
        return MetaChoice("resident", "fp32")   # nothing fits; pinned anyway
    if layout == "streamed":
        return MetaChoice("streamed", narrowest[0])
    for f in widest:
        if meta_table_bytes(depth, n_max, f) <= budget:
            return MetaChoice("resident", f)
    return MetaChoice("streamed", narrowest[0])


def _use_pallas_default() -> bool:
    return jax.default_backend() == "tpu"


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


class Tiling(NamedTuple):
    """Traced-array view of a tiled pool (crosses jit; see TileMap).

    The pool has ``num_tiles * bq`` slots; all four arrays are int32.
    """
    owner_local: jax.Array    # (Q',) slot's verdict group as the group's
    #                           first tile-local slot; -1 = pad slot
    scene_of_tile: jax.Array  # (T,) scene id per tile (0 = single scene)
    slot_of_query: jax.Array  # (Q,) original query -> pool slot
    group_slot: jax.Array     # (Q,) global group id -> the group's fold
    #                           slot; -1 past the group count


class TileMap(NamedTuple):
    """Host-side owner-group tiling of a plan's pair pool.

    ``perm[slot]`` is the original query index occupying the slot (-1 =
    pad); callers permute their per-query arrays with ``np.maximum(perm,
    0)`` (pad slots carry garbage rows, masked by ``owner_local < 0``).
    """
    tiles: Tiling             # numpy-backed Tiling arrays
    perm: np.ndarray          # (Q',) int64
    bq: int
    num_tiles: int


def build_tile_map(num_queries: int, bq: int,
                   scene_of_query: Optional[np.ndarray] = None,
                   owner_of_query: Optional[np.ndarray] = None,
                   max_bq: int = MAX_TILE_BQ) -> TileMap:
    """Pack a plan's pairs into scene-exclusive, owner-group-exclusive
    tiles (host-side numpy; runs once per plan shape).

    Pairs are ordered scene-major / owner-minor (stable, so real pools —
    already sorted this way by the front ends — keep their order), and
    each (scene, owner) run is placed whole into the first tile of its
    scene with room, opening a new tile on scene change or overflow.
    ``bq`` grows to the next power of two that fits the largest group
    (capped at ``max_bq``: a larger group raises — the executor screens
    with :func:`persist_kernel_unsupported` first).  Pads sit at each
    tile's TAIL, so live slots form every tile's prefix.
    """
    Q = int(num_queries)
    soq = (np.zeros(Q, np.int64) if scene_of_query is None
           else np.asarray(scene_of_query, np.int64))
    own = (np.arange(Q, dtype=np.int64) if owner_of_query is None
           else np.asarray(owner_of_query, np.int64))
    assert soq.shape == (Q,) and own.shape == (Q,)
    order = np.lexsort((own, soq))
    so, oo = soq[order], own[order]
    new_run = np.ones(Q, bool)
    if Q > 1:
        new_run[1:] = (so[1:] != so[:-1]) | (oo[1:] != oo[:-1])
    run_id = np.cumsum(new_run) - 1
    run_starts = np.flatnonzero(new_run)
    run_sizes = np.diff(np.append(run_starts, Q))
    run_owner = oo[run_starts]
    if owner_of_query is not None and \
            len(np.unique(run_owner)) != len(run_owner):
        raise ValueError("an owner group spans multiple scenes; "
                         "its fold cell cannot be tile-local")
    max_run = int(run_sizes.max()) if Q else 1
    bq_eff = max(int(bq), _next_pow2(max_run))
    if bq_eff > max_bq:
        raise ValueError(
            f"owner group of {max_run} pairs needs a {bq_eff}-slot tile "
            f"(cap {max_bq}); screen with persist_kernel_unsupported")

    nrun = len(run_starts)
    tile_of_run = np.zeros(nrun, np.int64)
    first_slot_of_run = np.zeros(nrun, np.int64)
    run_scene = so[run_starts] if nrun else np.zeros(0, np.int64)
    scene_of_tile = []
    tile, used, cur_scene = -1, bq_eff, None
    for r in range(nrun):
        n = int(run_sizes[r])
        s = int(run_scene[r])
        if s != cur_scene or used + n > bq_eff:
            tile += 1
            used = 0
            cur_scene = s
            scene_of_tile.append(s)
        tile_of_run[r] = tile
        first_slot_of_run[r] = used
        used += n
    num_tiles = max(tile + 1, 1)
    if not scene_of_tile:
        scene_of_tile = [0]

    rank_in_run = np.arange(Q) - run_starts[run_id] if Q else np.zeros(0)
    slot_sorted = (tile_of_run[run_id] * bq_eff + first_slot_of_run[run_id]
                   + rank_in_run).astype(np.int64)
    slot_of_query = np.zeros(Q, np.int64)
    slot_of_query[order] = slot_sorted
    Qs = num_tiles * bq_eff
    perm = np.full(Qs, -1, np.int64)
    perm[slot_sorted] = order
    owner_local = np.full(Qs, -1, np.int32)
    owner_local[slot_sorted] = first_slot_of_run[run_id].astype(np.int32)
    group_slot = np.full(Q, -1, np.int32)
    if nrun:
        group_slot[run_owner] = (tile_of_run * bq_eff
                                 + first_slot_of_run).astype(np.int32)
    tiles = Tiling(owner_local=owner_local,
                   scene_of_tile=np.asarray(scene_of_tile, np.int32),
                   slot_of_query=slot_of_query.astype(np.int32),
                   group_slot=group_slot)
    return TileMap(tiles=tiles, perm=perm, bq=bq_eff, num_tiles=num_tiles)


def persist_kernel_unsupported(owner_of_query=None, scene_of_query=None,
                               max_bq: int = MAX_TILE_BQ) -> Optional[str]:
    """Name the reason a persistent-mode plan cannot run on the kernel
    arm, or ``None`` if it can.

    After owner-group tiling there are exactly two capability limits
    left: an owner group too large for the largest tile, and an owner
    group spanning scenes (no front end emits one).  The executor calls
    this before tiling so a downgrade is counted
    (``Counters.ref_arm_fallbacks``) and logged, never silent.
    """
    if owner_of_query is None:
        return None
    own = np.asarray(owner_of_query)
    if own.size == 0:
        return None
    sizes = np.bincount(own.astype(np.int64))
    mx = int(sizes.max())
    if _next_pow2(mx) > max_bq:
        return (f"owner group of {mx} pairs needs a {_next_pow2(mx)}-slot "
                f"tile (cap {max_bq})")
    if scene_of_query is not None:
        soq = np.asarray(scene_of_query)
        pairs = {(int(o), int(s)) for o, s in zip(own, soq)}
        if len(pairs) != len(np.unique(own)):
            return "an owner group spans multiple scenes"
    return None


def _scene_extents(dev) -> Tuple[jax.Array, jax.Array]:
    """(S, depth+1) per-scene flat level sub-extents (offset, count)."""
    L = dev.depth + 1
    if isinstance(dev, MultiSceneOctree):
        return (dev.scene_off.astype(jnp.int32),
                dev.scene_counts.astype(jnp.int32))
    return (jnp.zeros((1, L), jnp.int32),
            jnp.reshape(dev.counts.astype(jnp.int32), (1, L)))


def _kernel_whole(obb_c, obb_h, obb_r, dev, capacity: int,
                  use_spheres: bool, bq: int, ring_cap: int,
                  interpret: bool, stream: bool, payload=None,
                  num_valid=None, owner_local=None,
                  scene_of_tile=None) -> Tuple[jax.Array, dict]:
    """Run the megakernel; returns the RAW (num_tiles * bq,) per-slot
    ``best`` words (PAYLOAD_INF = that owner slot never hit) + stats."""
    from repro.kernels.persist.kernel import make_persist_call

    M = obb_c.shape[0]
    L = dev.depth + 1
    n_max = dev.node_meta.shape[-2]
    obb = pack_obbs(obb_c, obb_h, obb_r)
    pay = (jnp.zeros((M,), jnp.int32) if payload is None
           else payload.astype(jnp.int32))
    if owner_local is not None:
        num_tiles = scene_of_tile.shape[0]
        bq = M // num_tiles
        assert num_tiles * bq == M, "tiled pools are exact tile multiples"
        own = owner_local.astype(jnp.int32)
        sot = scene_of_tile.astype(jnp.int32)
    else:
        num_tiles = max(math.ceil(M / bq), 1)
        pad = num_tiles * bq - M
        obb = jnp.pad(obb, ((0, pad), (0, 0)))
        pay = jnp.pad(pay, (0, pad))
        # Identity owners: every slot its own verdict group; validity
        # comes from the SMEM live-prefix count alone.
        own = jnp.tile(jnp.arange(bq, dtype=jnp.int32), num_tiles)
        sot = jnp.zeros((num_tiles,), jnp.int32)
    if isinstance(dev, MultiSceneOctree):
        scal = jnp.concatenate(
            [dev.scene_lo, dev.cell_sizes], axis=1
        ).astype(jnp.float32).reshape(-1)
        num_scenes = dev.num_scenes
    else:
        scal = jnp.concatenate([jnp.asarray(dev.scene_lo, jnp.float32),
                                jnp.asarray(dev.cell_sizes, jnp.float32)])
        num_scenes = 1
    off, cnt = _scene_extents(dev)
    meta = dev.node_meta
    if stream and n_max % META_ROW_ALIGN:   # hand-built unaligned tables
        padr = align_rows(n_max) - n_max
        meta = jnp.pad(meta, ((0, 0), (0, padr), (0, 0)))
        n_max = n_max + padr
    nvalid = jnp.reshape(jnp.asarray(M if num_valid is None else num_valid,
                                     jnp.int32), (1,))
    call = make_persist_call(num_tiles, bq, capacity, dev.depth, n_max,
                             ring_cap, use_spheres, interpret, stream,
                             meta_fmt=getattr(dev, "meta_format", "fp32"),
                             num_scenes=num_scenes,
                             wsub=sub_window_rows(n_max))
    words, per_level, hist, scalars, _ring = call(
        scal, off.reshape(-1), cnt.reshape(-1), sot, nvalid, obb, meta,
        pay, own)
    best = words.reshape(-1)
    tot = jnp.sum(scalars, axis=0)
    per = jnp.zeros((MAX_DEPTH + 1,), jnp.int32).at[:L].set(
        jnp.sum(per_level, axis=0))
    st = dict(nodes=tot[0], leaf=tot[1], axis_exec=tot[2], axis_dec=tot[3],
              sphere=tot[4], overflow=tot[5], per_level=per,
              exit_hist=jnp.sum(hist, axis=0), meta_rows=tot[7])
    return best, st


def traverse_whole(obb_c, obb_h, obb_r, dev, capacity: int, *,
                   use_spheres: bool, use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None,
                   scene_of_query: Optional[jax.Array] = None,
                   owner_of_query: Optional[jax.Array] = None,
                   payload: Optional[jax.Array] = None,
                   streamed: Optional[bool] = None,
                   bq: int = 128, ring_cap: int = 256, w_min: int = 128,
                   num_valid=None,
                   tiles: Optional[Tiling] = None) -> Tuple[jax.Array, dict]:
    """Whole multi-level traversal for one flat query set.

    ``dev`` is a single-scene :class:`DeviceOctree`, or a
    :class:`MultiSceneOctree` with ``scene_of_query`` (Q,) mapping each
    flat query to its scene.  Composes under jit; returns
    ``(collide (Q,) bool, stats dict)`` bitwise-identical to the per-level
    fused arm.

    ``streamed`` selects the node-metadata layout (see module docstring):
    ``None`` asks :func:`choose_meta_layout` with the default budget.  The
    layout cannot change verdicts or work counters — only the ``meta_rows``
    stat (HBM window traffic, 0 under the resident layout) and the VMEM
    footprint move.  Both kernel and ref arms honor it, so kernel-vs-ref
    runs stay bitwise-comparable per layout, for every plan shape
    (ragged and owner-tiled included).

    Payload lanes (:mod:`repro.engine.plan`): with owner / payload lanes
    the verdict is the (Q,) int32 ``best`` payload per verdict group
    (compact owner ids; cells past the group count are ``PAYLOAD_INF``).
    Cross-slot owner groups and ragged multi-scene pools are lowered to
    an owner-group tiled pool (:func:`build_tile_map`) and run on the
    SAME arm machinery as identity plans: when such a plan arrives
    untiled (and eager — tiling needs concrete ids; the executor
    pre-tiles before jit), the tile map is built here, the pool permuted
    into slot space, and outputs mapped back.  ``tiles`` given means the
    caller already permuted ``obb_* / owner_of_query / payload`` into
    slot space; outputs still come back in query/group space
    (``slot_of_query`` / ``group_slot`` are carried by ``tiles``).

    ``num_valid`` (traced int32, default all Q) marks the live prefix of
    the pool: slots at and past it never seed the frontier and contribute
    ZERO work to every counter, so a padded pool traverses bitwise like
    its unpadded prefix.  The sharded executor pads every shard's local
    pool to a common width and passes the true per-shard count.
    """
    ragged = isinstance(dev, MultiSceneOctree)
    assert ragged or scene_of_query is None, \
        "scene_of_query needs a MultiSceneOctree flat table"
    obb_c = jnp.asarray(obb_c)
    obb_h = jnp.asarray(obb_h)
    obb_r = jnp.asarray(obb_r)
    fmt = getattr(dev, "meta_format", "fp32")
    n_max = dev.node_meta.shape[-2]
    if streamed is None:
        streamed = choose_meta_layout(
            dev.depth, n_max, fmt=fmt).layout == "streamed"
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grouped = owner_of_query is not None or payload is not None

    if tiles is None and (ragged or owner_of_query is not None):
        if any(isinstance(x, jax.core.Tracer)
               for x in (scene_of_query, owner_of_query)):
            # The tile map needs concrete ids; the executor pre-tiles for
            # the kernel arm before jit, so a traced untiled call is the
            # per-level modes' legacy ref routing (resident model only).
            assert not use_pallas, \
                "the kernel arm needs a pre-built tile map under jit"
            return traverse_whole_ref(
                obb_c, obb_h, obb_r, dev.node_meta, dev.cell_sizes,
                dev.scene_lo, dev.depth, capacity, use_spheres,
                scene_of_query=scene_of_query, w_min=w_min,
                owner_of_query=owner_of_query, payload=payload,
                num_valid=num_valid, meta_format=fmt,
                codes=getattr(dev, "codes", None))
        # Untiled non-identity plan: build the tile map eagerly (needs
        # concrete scene/owner ids — the executor pre-tiles before jit)
        # and re-enter in slot space.
        assert not ragged or scene_of_query is not None, \
            "a MultiSceneOctree needs scene_of_query (Q,) untiled"
        reason = persist_kernel_unsupported(
            None if owner_of_query is None else np.asarray(owner_of_query),
            None if scene_of_query is None else np.asarray(scene_of_query))
        if reason is not None:
            # Capability gap: the ref arm serves the plan untiled (the
            # executor counts and logs this routing).
            assert not use_pallas, f"kernel arm unsupported: {reason}"
            return traverse_whole_ref(
                obb_c, obb_h, obb_r, dev.node_meta, dev.cell_sizes,
                dev.scene_lo, dev.depth, capacity, use_spheres,
                scene_of_query=scene_of_query, w_min=w_min,
                owner_of_query=owner_of_query, payload=payload,
                num_valid=num_valid, meta_format=fmt,
                codes=getattr(dev, "codes", None))
        tm = build_tile_map(
            obb_c.shape[0], bq,
            None if scene_of_query is None else np.asarray(scene_of_query),
            None if owner_of_query is None else np.asarray(owner_of_query))
        perm = np.maximum(tm.perm, 0)
        return traverse_whole(
            jnp.asarray(obb_c)[perm], jnp.asarray(obb_h)[perm],
            jnp.asarray(obb_r)[perm], dev, capacity,
            use_spheres=use_spheres, use_pallas=use_pallas,
            interpret=interpret,
            owner_of_query=(None if owner_of_query is None
                            else jnp.asarray(owner_of_query)[perm]),
            payload=(None if payload is None
                     else jnp.asarray(payload)[perm]),
            streamed=streamed, bq=tm.bq, ring_cap=ring_cap, w_min=w_min,
            tiles=jax.tree.map(jnp.asarray, tm.tiles))

    if tiles is not None:
        Qs = obb_c.shape[0]
        num_tiles = tiles.scene_of_tile.shape[0]
        bq_t = Qs // num_tiles
        assert num_tiles * bq_t == Qs, "tiled pools are exact multiples"
        Q = tiles.slot_of_query.shape[0]
        valid = tiles.owner_local >= 0
        if use_pallas:
            best, st = _kernel_whole(
                obb_c, obb_h, obb_r, dev, capacity, use_spheres, bq_t,
                ring_cap, interpret, stream=streamed, payload=payload,
                owner_local=tiles.owner_local,
                scene_of_tile=tiles.scene_of_tile)
        else:
            off, cnt = _scene_extents(dev)
            soq_slot = (jnp.repeat(tiles.scene_of_tile, bq_t) if ragged
                        else None)
            best, st = traverse_whole_ref(
                obb_c, obb_h, obb_r, dev.node_meta, dev.cell_sizes,
                dev.scene_lo, dev.depth, capacity, use_spheres,
                scene_of_query=soq_slot, w_min=w_min,
                owner_of_query=owner_of_query, payload=payload,
                stream_bq=bq_t if streamed else None,
                stream_wsub=sub_window_rows(n_max) if streamed else None,
                scene_off=off if streamed else None,
                scene_counts=cnt if streamed else None,
                scene_of_tile=tiles.scene_of_tile if streamed else None,
                valid_of_query=valid, meta_format=fmt,
                codes=getattr(dev, "codes", None))
        if grouped:
            if use_pallas:
                # Kernel bests live at each group's fold slot; the ref's
                # live at the global group id.  Cells past the group
                # count are PAYLOAD_INF either way.
                out = jnp.where(
                    tiles.group_slot >= 0,
                    best[jnp.clip(tiles.group_slot, 0, Qs - 1)],
                    jnp.int32(PAYLOAD_INF))
            else:
                out = best[:Q]
        else:
            slot_best = (best != PAYLOAD_INF) if use_pallas else best
            out = slot_best[tiles.slot_of_query]
        return out, st

    # ---- identity (single-scene, per-query groups) pools --------------
    M = obb_c.shape[0]
    if use_pallas:
        best, st = _kernel_whole(obb_c, obb_h, obb_r, dev, capacity,
                                 use_spheres, bq, ring_cap, interpret,
                                 stream=streamed, payload=payload,
                                 num_valid=num_valid)
        best = best[:M]
        return (best if grouped else best != PAYLOAD_INF), st
    off, cnt = _scene_extents(dev)
    return traverse_whole_ref(obb_c, obb_h, obb_r, dev.node_meta,
                              dev.cell_sizes, dev.scene_lo, dev.depth,
                              capacity, use_spheres,
                              scene_of_query=None, w_min=w_min,
                              owner_of_query=None, payload=payload,
                              stream_bq=bq if streamed else None,
                              stream_wsub=(sub_window_rows(n_max)
                                           if streamed else None),
                              scene_off=off if streamed else None,
                              scene_counts=cnt if streamed else None,
                              scene_of_tile=(
                                  jnp.zeros((max(math.ceil(M / bq), 1),),
                                            jnp.int32)
                                  if streamed else None),
                              num_valid=num_valid,
                              meta_format=fmt,
                              codes=getattr(dev, "codes", None))
