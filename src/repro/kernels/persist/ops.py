"""Dispatch + glue for the persistent whole-traversal megakernel.

``traverse_whole`` is the single entry point of ``mode=
"wavefront_persistent"``: the ENTIRE multi-level traversal in one call —
the Pallas megakernel on TPU (or ``interpret=True`` for the CPU CI
matrix), the live-prefix jnp reference elsewhere.  Both arms share the
contract of :func:`repro.core.wavefront._traverse_fused` — identical
``(collide, stats)`` including every work counter — so the engine's
escalation policy and counter plumbing are mode-agnostic.

The ragged multi-scene frontier (``scene_of_query`` + a
:class:`repro.core.octree.MultiSceneOctree` flat table) is served by the
reference arm on every backend: one compiled call and one compaction pool
for arbitrarily mixed scene sizes.  The megakernel keeps per-scene
scalars in SMEM and is single-scene for now (DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.octree import MAX_DEPTH, DeviceOctree, MultiSceneOctree
from repro.core.sact import PAYLOAD_INF
from repro.kernels.persist.ref import traverse_whole_ref
from repro.kernels.sact.ops import pack_obbs


def _use_pallas_default() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_whole(obb_c, obb_h, obb_r, dev: DeviceOctree, capacity: int,
                  use_spheres: bool, bq: int, ring_cap: int,
                  interpret: bool, payload=None,
                  grouped: bool = False) -> Tuple[jax.Array, dict]:
    from repro.kernels.persist.kernel import make_persist_call

    M = obb_c.shape[0]
    L = dev.depth + 1
    n_max = dev.codes.shape[-1]
    num_tiles = max(math.ceil(M / bq), 1)
    obb = pack_obbs(obb_c, obb_h, obb_r)
    scal = jnp.concatenate([jnp.asarray(dev.scene_lo, jnp.float32),
                            jnp.asarray(dev.cell_sizes, jnp.float32)])
    pay = (jnp.zeros((M,), jnp.int32) if payload is None
           else payload.astype(jnp.int32))
    pay = jnp.pad(pay, (0, num_tiles * bq - M))
    call = make_persist_call(M, num_tiles, bq, capacity, dev.depth, n_max,
                             obb.shape[0], ring_cap, use_spheres, interpret)
    words, per_level, hist, scalars, _ring = call(scal, obb, dev.node_meta,
                                                  pay)
    best = words.reshape(-1)[:M]
    verdict = best if grouped else best != PAYLOAD_INF
    tot = jnp.sum(scalars, axis=0)
    per = jnp.zeros((MAX_DEPTH + 1,), jnp.int32).at[:L].set(
        jnp.sum(per_level, axis=0))
    st = dict(nodes=tot[0], leaf=tot[1], axis_exec=tot[2], axis_dec=tot[3],
              sphere=tot[4], overflow=tot[5], per_level=per,
              exit_hist=jnp.sum(hist, axis=0))
    return verdict, st


def traverse_whole(obb_c, obb_h, obb_r, dev, capacity: int, *,
                   use_spheres: bool, use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None,
                   scene_of_query: Optional[jax.Array] = None,
                   owner_of_query: Optional[jax.Array] = None,
                   payload: Optional[jax.Array] = None,
                   bq: int = 128, ring_cap: int = 256, w_min: int = 128
                   ) -> Tuple[jax.Array, dict]:
    """Whole multi-level traversal for one flat query set.

    ``dev`` is a single-scene :class:`DeviceOctree`, or a
    :class:`MultiSceneOctree` with ``scene_of_query`` (Q,) mapping each
    flat query to its scene.  Composes under jit; returns
    ``(collide (Q,) bool, stats dict)`` bitwise-identical to the per-level
    fused arm.

    Payload lanes (:mod:`repro.engine.plan`): with owner / payload lanes
    the verdict is the (Q,) int32 ``best`` payload per verdict group
    (compact owner ids; cells past the group count unused).  The
    megakernel carries the payload lane in its VMEM frontier for
    identity-owner plans (``owner_of_query is None`` — per-slot first
    hit); plans with a cross-slot owner lane are served by the reference
    arm, like the ragged multi-scene frontier, because a tile's queries
    would no longer own their verdict groups exclusively (DESIGN.md §3).
    """
    ragged = isinstance(dev, MultiSceneOctree) or scene_of_query is not None
    assert not (isinstance(dev, MultiSceneOctree)
                and scene_of_query is None), \
        "a MultiSceneOctree needs scene_of_query (Q,) to map queries to scenes"
    kernel_ok = not ragged and owner_of_query is None
    if use_pallas is None:
        use_pallas = _use_pallas_default() and kernel_ok
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas and kernel_ok:
        return _kernel_whole(obb_c, obb_h, obb_r, dev, capacity,
                             use_spheres, bq, ring_cap, interpret,
                             payload=payload, grouped=payload is not None)
    # DeviceOctree and MultiSceneOctree expose the same three table fields;
    # scene_of_query switches the ref between scalar and per-pair gathers.
    return traverse_whole_ref(obb_c, obb_h, obb_r, dev.node_meta,
                              dev.cell_sizes, dev.scene_lo, dev.depth,
                              capacity, use_spheres,
                              scene_of_query=scene_of_query, w_min=w_min,
                              owner_of_query=owner_of_query, payload=payload)
