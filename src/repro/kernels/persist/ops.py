"""Dispatch + glue for the persistent whole-traversal megakernel.

``traverse_whole`` is the single entry point of ``mode=
"wavefront_persistent"``: the ENTIRE multi-level traversal in one call —
the Pallas megakernel on TPU (or ``interpret=True`` for the CPU CI
matrix), the live-prefix jnp reference elsewhere.  Both arms share the
contract of :func:`repro.engine.executor._traverse_fused` — identical
``(collide, stats)`` including every work counter — so the engine's
escalation policy and counter plumbing are mode-agnostic.

**Metadata residency layouts x row formats.**  The megakernel holds node
metadata in one of two layouts (:data:`META_LAYOUTS`, DESIGN.md §3):

* ``resident`` — the whole ``(depth+1, n_max, words)`` table is a VMEM
  block (:func:`meta_table_bytes`); fastest when it fits.
* ``streamed`` — the table stays in HBM and per-level row windows are
  double-buffered through a ping/pong VMEM scratch pair
  (:func:`meta_stream_bytes` resident bytes; the fetched rows are counted
  into the ``meta_rows`` stat → ``Counters.meta_rows_streamed`` → priced
  at the format's row width).

Rows come in one of three formats (:data:`repro.core.quantize.META_FORMATS`:
fp32 = 16 B, bf16 = 8 B, u8 = 4 B — see :mod:`repro.core.quantize` for the
encodings and the soundness argument).  The format is a property of the
packed :class:`DeviceOctree` (``dev.meta_format``); both arms decode it
in-register and verdicts/counters are bitwise format-independent.

``traverse_whole(streamed=None)`` picks the layout with
:func:`choose_meta_layout` against :data:`DEFAULT_VMEM_BUDGET` (pinning
the tree's own format); the engine's executor runs the full
layout x format chooser per (mode, statics) traversal cache key and
passes both down explicitly (``EngineConfig.stream_meta`` /
``meta_format`` / ``vmem_budget`` override it).

The ragged multi-scene frontier (``scene_of_query`` + a
:class:`repro.core.octree.MultiSceneOctree` flat table) is served by the
reference arm on every backend: one compiled call and one compaction pool
for arbitrarily mixed scene sizes.  The megakernel keeps per-scene
scalars in SMEM and is single-scene for now; streaming the flat
multi-scene table is the follow-up (DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.counters import (BYTES_META_STREAM, BYTES_META_STREAM_BF16,
                                 BYTES_META_STREAM_U8)
from repro.core.octree import (MAX_DEPTH, META_ROW_ALIGN, DeviceOctree,
                               MultiSceneOctree, align_rows)
from repro.core.quantize import META_FORMATS, format_eligible
from repro.core.sact import PAYLOAD_INF
from repro.kernels.persist.ref import traverse_whole_ref
from repro.kernels.sact.ops import pack_obbs

#: Node-metadata layouts of the persistent megakernel (drift-guarded
#: against the DESIGN.md §3 / README residency tables).
META_LAYOUTS = ("resident", "streamed")

#: Bytes per node-metadata row ([code, full, child_start, child_mask],
#: 4 x int32) — the unit of the residency estimates, aliased to the
#: traffic model's ``BYTES_META_STREAM`` so the two can never drift.
META_BYTES_PER_ROW = BYTES_META_STREAM

#: Bytes per packed row by format, aliased to the traffic-model constants
#: (:mod:`repro.core.quantize` defines the encodings; fp32 = 4 int32
#: words, bf16 = 2, u8 = 1).
META_FORMAT_BYTES = {"fp32": BYTES_META_STREAM,
                     "bf16": BYTES_META_STREAM_BF16,
                     "u8": BYTES_META_STREAM_U8}

#: Default VMEM budget for the resident node-metadata table.  Real TPU
#: cores have ~16 MiB of VMEM; the megakernel also needs its frontier
#: scratch, the per-tile OBB block, and (streamed) the window pair, so
#: the table gets half.  ``EngineConfig.vmem_budget`` overrides per
#: engine; CPU/interpret runs have no hard limit but honor the same
#: estimate so layout choice is backend-independent.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024


def meta_table_bytes(depth: int, n_max: int, fmt: str = "fp32") -> int:
    """VMEM bytes of the RESIDENT node-metadata table (aligned rows)."""
    return (depth + 1) * align_rows(n_max) * META_FORMAT_BYTES[fmt]


def meta_stream_bytes(n_max: int, fmt: str = "fp32") -> int:
    """VMEM bytes of the STREAMED layout's ping/pong window pair.

    A window covers a whole level's occupied extent, so the pair is sized
    to the WIDEST level (``2 * n_max`` rows): streaming buys a
    ``(depth+1)/2``x larger scene per VMEM byte over the resident table,
    not an unbounded one.  Fixed-size sub-level windows (decoupling the
    scratch from the widest level entirely) are the recorded follow-up
    (ROADMAP).
    """
    return 2 * align_rows(n_max) * META_FORMAT_BYTES[fmt]


class MetaChoice(NamedTuple):
    """A point in the {resident, streamed} x {fp32, bf16, u8} plan space."""
    layout: str
    fmt: str


def choose_meta_layout(depth: int, n_max: int,
                       budget: int = DEFAULT_VMEM_BUDGET,
                       fmt: Optional[str] = None,
                       layout: Optional[str] = None) -> MetaChoice:
    """Layout/format chooser over {resident, streamed} x {fp32, bf16, u8}.

    ``fmt`` / ``layout`` pin one or both axes (``None`` = free).  Rules:

    * **Format preference runs widest-first for residency** (fp32 > bf16 >
      u8): compression is only taken when it buys residency the wider
      format cannot afford — a table that fits in fp32 stays fp32 (zero
      decode cost, no reason to compress).
    * **Streamed rows are narrowest-first** (u8 > bf16 > fp32): once the
      table streams, row width is pure HBM traffic, so the narrowest
      *eligible* format wins.
    * **Eligibility** (:func:`repro.core.quantize.format_eligible`) caps
      compressed formats by their CSR ``child_start`` field width (bf16:
      23 bits, u8: 20); fp32 is always eligible.

    Pinning an ineligible ``fmt`` raises ``ValueError`` (a packed table
    with overflowed pointers cannot exist); a free search only visits
    eligible formats, so the fallback is always sound.
    """
    if fmt is not None and fmt not in META_FORMATS:
        raise ValueError(f"unknown meta_format {fmt!r}; "
                         f"allowed: {META_FORMATS}")
    if layout is not None and layout not in META_LAYOUTS:
        raise ValueError(f"unknown meta layout {layout!r}; "
                         f"allowed: {META_LAYOUTS}")
    if fmt is not None and not format_eligible(fmt, n_max):
        raise ValueError(
            f"meta_format {fmt!r} cannot index {n_max} rows per level "
            "(CSR child_start field overflow)")
    widest = [f for f in META_FORMATS if format_eligible(f, n_max)]
    narrowest = widest[::-1]
    if fmt is not None:
        if layout is None:
            layout = ("resident"
                      if meta_table_bytes(depth, n_max, fmt) <= budget
                      else "streamed")
        return MetaChoice(layout, fmt)
    if layout == "resident":
        for f in widest:
            if meta_table_bytes(depth, n_max, f) <= budget:
                return MetaChoice("resident", f)
        return MetaChoice("resident", "fp32")   # nothing fits; pinned anyway
    if layout == "streamed":
        return MetaChoice("streamed", narrowest[0])
    for f in widest:
        if meta_table_bytes(depth, n_max, f) <= budget:
            return MetaChoice("resident", f)
    return MetaChoice("streamed", narrowest[0])


def _use_pallas_default() -> bool:
    return jax.default_backend() == "tpu"


def _window_rows(counts: jax.Array) -> jax.Array:
    """Per-level window sizes in rows: occupied extent rounded up to whole
    :data:`repro.core.octree.META_ROW_ALIGN`-row DMA chunks."""
    w = META_ROW_ALIGN
    return (((counts.astype(jnp.int32) + w - 1) // w) * w)


def _kernel_whole(obb_c, obb_h, obb_r, dev: DeviceOctree, capacity: int,
                  use_spheres: bool, bq: int, ring_cap: int,
                  interpret: bool, stream: bool, payload=None,
                  grouped: bool = False,
                  num_valid=None) -> Tuple[jax.Array, dict]:
    from repro.kernels.persist.kernel import make_persist_call

    M = obb_c.shape[0]
    L = dev.depth + 1
    n_max = dev.codes.shape[-1]
    num_tiles = max(math.ceil(M / bq), 1)
    obb = pack_obbs(obb_c, obb_h, obb_r)
    obb = jnp.pad(obb, ((0, num_tiles * bq - M), (0, 0)))
    scal = jnp.concatenate([jnp.asarray(dev.scene_lo, jnp.float32),
                            jnp.asarray(dev.cell_sizes, jnp.float32)])
    pay = (jnp.zeros((M,), jnp.int32) if payload is None
           else payload.astype(jnp.int32))
    pay = jnp.pad(pay, (0, num_tiles * bq - M))
    meta = dev.node_meta
    if stream and n_max % META_ROW_ALIGN:   # hand-built unaligned tables
        pad = align_rows(n_max) - n_max
        meta = jnp.pad(meta, ((0, 0), (0, pad), (0, 0)))
        n_max = n_max + pad
    nchunks = (_window_rows(dev.counts) // META_ROW_ALIGN if stream
               else jnp.zeros((L,), jnp.int32))
    nvalid = jnp.reshape(jnp.asarray(M if num_valid is None else num_valid,
                                     jnp.int32), (1,))
    call = make_persist_call(M, num_tiles, bq, capacity, dev.depth, n_max,
                             ring_cap, use_spheres, interpret, stream,
                             meta_fmt=getattr(dev, "meta_format", "fp32"))
    words, per_level, hist, scalars, _ring = call(scal, nchunks, nvalid,
                                                  obb, meta, pay)
    best = words.reshape(-1)[:M]
    verdict = best if grouped else best != PAYLOAD_INF
    tot = jnp.sum(scalars, axis=0)
    per = jnp.zeros((MAX_DEPTH + 1,), jnp.int32).at[:L].set(
        jnp.sum(per_level, axis=0))
    st = dict(nodes=tot[0], leaf=tot[1], axis_exec=tot[2], axis_dec=tot[3],
              sphere=tot[4], overflow=tot[5], per_level=per,
              exit_hist=jnp.sum(hist, axis=0), meta_rows=tot[7])
    return verdict, st


def traverse_whole(obb_c, obb_h, obb_r, dev, capacity: int, *,
                   use_spheres: bool, use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None,
                   scene_of_query: Optional[jax.Array] = None,
                   owner_of_query: Optional[jax.Array] = None,
                   payload: Optional[jax.Array] = None,
                   streamed: Optional[bool] = None,
                   bq: int = 128, ring_cap: int = 256, w_min: int = 128,
                   num_valid=None) -> Tuple[jax.Array, dict]:
    """Whole multi-level traversal for one flat query set.

    ``dev`` is a single-scene :class:`DeviceOctree`, or a
    :class:`MultiSceneOctree` with ``scene_of_query`` (Q,) mapping each
    flat query to its scene.  Composes under jit; returns
    ``(collide (Q,) bool, stats dict)`` bitwise-identical to the per-level
    fused arm.

    ``streamed`` selects the node-metadata layout (see module docstring):
    ``None`` asks :func:`choose_meta_layout` with the default budget.  The
    layout cannot change verdicts or work counters — only the ``meta_rows``
    stat (HBM window traffic, 0 under the resident layout) and the VMEM
    footprint move.  Both kernel and ref arms honor it, so kernel-vs-ref
    runs stay bitwise-comparable per layout.

    Payload lanes (:mod:`repro.engine.plan`): with owner / payload lanes
    the verdict is the (Q,) int32 ``best`` payload per verdict group
    (compact owner ids; cells past the group count unused).  The
    megakernel carries the payload lane in its VMEM frontier for
    identity-owner plans (``owner_of_query is None`` — per-slot first
    hit); plans with a cross-slot owner lane are served by the reference
    arm, like the ragged multi-scene frontier, because a tile's queries
    would no longer own their verdict groups exclusively (DESIGN.md §3).

    ``num_valid`` (traced int32, default all Q) marks the live prefix of
    the pool: slots at and past it never seed the frontier and contribute
    ZERO work to every counter, so a padded pool traverses bitwise like
    its unpadded prefix.  The sharded executor pads every shard's local
    pool to a common width and passes the true per-shard count.
    """
    ragged = isinstance(dev, MultiSceneOctree) or scene_of_query is not None
    assert not (isinstance(dev, MultiSceneOctree)
                and scene_of_query is None), \
        "a MultiSceneOctree needs scene_of_query (Q,) to map queries to scenes"
    kernel_ok = not ragged and owner_of_query is None
    if streamed is None:
        streamed = (not ragged) and choose_meta_layout(
            dev.depth, dev.codes.shape[-1],
            fmt=getattr(dev, "meta_format", "fp32")).layout == "streamed"
    if use_pallas is None:
        use_pallas = _use_pallas_default() and kernel_ok
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas and kernel_ok:
        return _kernel_whole(obb_c, obb_h, obb_r, dev, capacity,
                             use_spheres, bq, ring_cap, interpret,
                             stream=streamed, payload=payload,
                             grouped=payload is not None,
                             num_valid=num_valid)
    # DeviceOctree and MultiSceneOctree expose the same three table fields;
    # scene_of_query switches the ref between scalar and per-pair gathers.
    # The streamed-window model only applies where the kernel could run
    # (single-scene, identity-owner): ragged and cross-slot-owner plans
    # are ref-served with the table resident, so modeling window traffic
    # for them would price HBM fetches no arm performs.
    model = streamed and kernel_ok
    return traverse_whole_ref(obb_c, obb_h, obb_r, dev.node_meta,
                              dev.cell_sizes, dev.scene_lo, dev.depth,
                              capacity, use_spheres,
                              scene_of_query=scene_of_query, w_min=w_min,
                              owner_of_query=owner_of_query, payload=payload,
                              stream_bq=bq if model else None,
                              stream_window_rows=(
                                  _window_rows(dev.counts) if model
                                  else None),
                              num_valid=num_valid,
                              meta_format=getattr(dev, "meta_format",
                                                  "fp32"),
                              # MultiSceneOctree carries no codes plane;
                              # it is fp32-only (executor pins it), and
                              # only u8 decode needs the plane.
                              codes=getattr(dev, "codes", None))
