"""Pure-jnp oracle for stream compaction (prefix-sum + scatter).

Contract shared with the Pallas kernel: given ``mask (N,)`` and row payloads
``vals (N, C)``, pack the rows where ``mask`` is True — in ascending input
order — into the first ``count = min(sum(mask), n_out)`` rows of an
``(n_out, C)`` buffer.  Rows past ``count`` are unspecified (callers gate on
the returned count); overflowing elements (output position >= n_out) are the
highest-index survivors and are dropped, matching the legacy host engine's
``max_frontier`` clamp.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compact_ref(mask: jax.Array, vals: jax.Array, n_out: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Reference compaction: (count () int32, packed (n_out, C))."""
    mask = mask.astype(bool)
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1          # inclusive scan - 1
    tgt = jnp.where(mask, pos, n_out)                     # parked at n_out
    out = jnp.zeros((n_out,) + vals.shape[1:], vals.dtype)
    out = out.at[tgt].set(vals, mode="drop")              # scatter; OOB drops
    count = jnp.minimum(jnp.sum(mask.astype(jnp.int32)), n_out)
    return count, out
