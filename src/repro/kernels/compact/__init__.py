# Stream compaction for wavefront frontiers: prefix-sum + scatter that packs
# live (query, node) pairs to the front of a fixed-capacity buffer.  Replaces
# the host-side bucket resize of the legacy engine; runs per octree level
# inside the device-resident while_loop.
