"""Stream-compaction Pallas kernel: blockwise prefix-sum + windowed scatter.

Two-pass compaction in the classic GPU style, mapped onto the sequential TPU
grid: the cheap pass (per-block survivor counts + exclusive scan over blocks)
runs as plain XLA in ops.py; this kernel is the scatter pass.  Grid step ``j``
reads input block ``j``, turns the block-local inclusive scan of its mask into
global output positions ``bases[j] + scan - 1``, builds a one-hot
(input-lane, window-lane) matrix, and reduces it into a ``bn``-wide window
that is stored at ``bases[j]`` with a single dynamic-slice store — survivors
of one block always land in ``[bases[j], bases[j] + bn)``.  Later grid steps
overwrite the window tail, so after the last step exactly the first
``total`` rows are packed survivors (the output carries ``bn`` pad rows so
the final window store never runs out of bounds).

Elements whose global position would exceed ``n_out`` are dropped (the
``max_frontier`` overflow clamp of the wavefront engine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # CPU-only containers may lack the TPU extension
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def compact_kernel(bases_ref, mask_ref, vals_ref, out_ref, *, n_out: int,
                   bn: int):
    j = pl.program_id(0)
    base = bases_ref[j]
    m = mask_ref[...] != 0                                    # (bn,)
    v = vals_ref[...]                                         # (bn, C)
    incl = jnp.cumsum(m.astype(jnp.int32))                    # (bn,)
    pos = base + incl - 1                                     # global slot
    sel = m & (pos < n_out)                                   # overflow drop
    rel = pos - base                                          # in [0, bn)
    lane = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
    onehot = sel[:, None] & (rel[:, None] == lane)            # (in, window)
    win = jnp.sum(jnp.where(onehot[:, :, None], v[:, None, :], 0), axis=0)
    out_ref[pl.ds(jnp.minimum(base, n_out), bn), :] = win


def make_compact_call(n_pad: int, n_out: int, channels: int, bn: int,
                      interpret: bool):
    """Build the pallas_call for (mask (n_pad,), vals (n_pad, C)) inputs."""
    kernel = functools.partial(compact_kernel, n_out=n_out, bn=bn)
    smem = {} if pltpu is None else {"memory_space": pltpu.SMEM}
    return pl.pallas_call(
        kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec(**smem),                             # bases, whole
            pl.BlockSpec((bn,), lambda j: (j,)),
            pl.BlockSpec((bn, channels), lambda j: (j, 0)),
        ],
        # Whole-array output block: it stays resident across the sequential
        # grid so successive windows overwrite each other's tails.
        out_specs=pl.BlockSpec((n_out + bn, channels), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out + bn, channels), jnp.int32),
        interpret=interpret,
    )
