"""JIT wrapper + backend dispatch for the stream-compaction kernel.

``stream_compact`` is the one entry point the wavefront engine calls each
octree level.  On TPU it runs the Pallas scatter kernel (compiled); elsewhere
it falls back to the jnp reference, because interpret-mode Pallas unrolls one
program per grid step at trace time — untenable for million-entry frontiers.
Both paths share the exact contract documented in ref.py, so verdicts do not
depend on the backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.compact.kernel import make_compact_call
from repro.kernels.compact.ref import compact_ref


def _use_pallas_default() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("n_out", "bn", "interpret"))
def _compact_pallas(mask: jax.Array, vals: jax.Array, n_out: int, bn: int,
                    interpret: bool) -> Tuple[jax.Array, jax.Array]:
    N = mask.shape[0]
    pad = (-N) % bn
    m = jnp.pad(mask.astype(jnp.int32), (0, pad))
    v = jnp.pad(vals.astype(jnp.int32), ((0, pad), (0, 0)))
    blk_counts = m.reshape(-1, bn).sum(axis=1, dtype=jnp.int32)
    bases = jnp.cumsum(blk_counts) - blk_counts              # exclusive scan
    call = make_compact_call(m.shape[0], n_out, vals.shape[1], bn, interpret)
    out = call(bases, m, v)
    count = jnp.minimum(blk_counts.sum(), n_out)
    return count, out[:n_out]


def stream_compact(mask: jax.Array, vals: jax.Array, n_out: int, *,
                   bn: int = 256, use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Pack rows of ``vals`` where ``mask`` holds into an (n_out, C) buffer.

    Returns (count () int32, packed (n_out, C)).  Rows past ``count`` are
    unspecified; survivors that would land past ``n_out`` are dropped.
    """
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if not use_pallas:
        return compact_ref(mask, vals.astype(jnp.int32), n_out)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _compact_pallas(mask, vals, n_out, bn, interpret)


def compact_pairs(mask: jax.Array, q_idx: jax.Array, codes: jax.Array,
                  n_out: int, *, use_pallas: Optional[bool] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Frontier-specific wrapper: compact (query, Morton code) int32/uint32
    pairs in one pass.  Returns (count, q_idx (n_out,), codes (n_out,))."""
    vals = jnp.stack(
        [q_idx.astype(jnp.int32),
         jax.lax.bitcast_convert_type(codes, jnp.int32)], axis=-1)
    count, packed = stream_compact(mask, vals, n_out, use_pallas=use_pallas)
    return (count, packed[:, 0],
            jax.lax.bitcast_convert_type(packed[:, 1], jnp.uint32))
