"""Blockwise online-softmax attention (forward) Pallas kernel.

The LM-side embodiment of the paper's fusion discipline (DESIGN.md §2): the
(Tq, Tk) score matrix never exists in HBM — q/k/v tiles stream through VMEM
and the softmax is computed online with running (max, denom) scratch.
Supports GQA (kv head = q head // group) via the BlockSpec index map and
causal masking with whole-tile skipping (the tile-level conditional return:
fully-masked key tiles are never computed).

Forward only: training uses the jnp reference path (XLA fuses the backward
well enough on the dry-run meshes); this kernel targets serving/prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, scale: float, bq: int, bk: int, kv_len: int):
    jk = pl.program_id(3)
    nk = pl.num_programs(3)
    iq = pl.program_id(2)

    @pl.when(jk == 0)
    def _reset():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def tile():
        q = q_ref[0, 0] * scale                  # (bq, d)
        k = k_ref[0, 0]                          # (bk, d)
        v = v_ref[0, 0]
        s = q @ k.T                              # (bq, bk)
        kj = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kj < kv_len                       # key padding
        if causal:
            qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask = mask & (qi >= kj)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    if causal:
        # Tile-level conditional return: skip fully-masked key tiles.
        @pl.when(jk * bk <= iq * bq + bq - 1)
        def _():
            tile()
    else:
        tile()

    @pl.when(jk == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def make_flash_call(B: int, Hq: int, Hkv: int, Tq: int, Tk: int, d: int,
                    bq: int, bk: int, causal: bool, scale: float,
                    interpret: bool, dtype, kv_len: int | None = None):
    group = Hq // Hkv
    kernel = functools.partial(flash_kernel, causal=causal, scale=scale,
                               bq=bq, bk=bk,
                               kv_len=Tk if kv_len is None else kv_len)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, Tq // bq, Tk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tq, d), dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )
