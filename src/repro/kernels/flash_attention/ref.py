"""Pure-jnp attention oracle (GQA + causal)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    kf = jnp.repeat(k, group, axis=1)
    vf = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kf) / (d ** 0.5)
    if causal:
        qi = jnp.arange(Tq)[:, None]
        kj = jnp.arange(Tk)[None, :]
        s = jnp.where(qi >= kj, s, -jnp.inf)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf)
