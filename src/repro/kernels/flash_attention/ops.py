"""JIT wrapper for the flash-attention kernel (GQA + causal + padding)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import make_flash_call


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Attention over (B, H, T, d) tensors; k/v may have fewer heads (GQA).

    Returns (B, Hq, Tq, d).  Sequence dims are padded to block multiples;
    padded keys are masked inside the kernel.
    """
    B, Hq, Tq, d = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    scale = 1.0 / (d ** 0.5)
    bq_ = min(bq, max(8, Tq))
    bk_ = min(bk, max(8, Tk))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, (-Tq) % bq_), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, (-Tk) % bk_), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, (-Tk) % bk_), (0, 0)))
    call = make_flash_call(B, Hq, Hkv, qp.shape[2], kp.shape[2], d, bq_, bk_,
                           causal, scale, interpret, q.dtype, kv_len=Tk)
    out = call(qp, kp, vp)
    return out[:, :, :Tq]
