# Pallas TPU kernels for the compute hot-spots the paper optimizes:
#   sact/            fused staged OBB-AABB separating-axis test
#                    (the "collision OP unit" of RoboGPU SIII-C)
#   ballquery/       tiled fixed-radius neighbor search with tile early-stop
#                    (RoboGPU SIV P-Sphere with early exit)
#   fps/             furthest-point-sampling distance update
#   wkv6/            RWKV-6 chunked recurrence (rwkv6-1.6b arch)
#   flash_attention/ blockwise online-softmax attention (LM archs)
# Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
# padding, interpret switch), ref.py (pure-jnp oracle used by tests).
