"""Fused traversal-step Pallas kernel — one wavefront level, boxes in,
verdict words out.

RoboGPU's RoboCore (§III-C) fuses the staged collision test with the
traversal control flow so intermediates never leave the unit.  The TPU
analogue for the wavefront engine: one `pallas_call` per octree level whose
grid walks the fixed-capacity frontier in (bn,) lane blocks.  Each block

  1. *gathers* its query OBBs by ``q_idx`` from the resident packed OBB
     table — a one-hot matmul against VMEM, so an out-of-range (padding)
     index simply gathers zeros instead of faulting;
  2. reconstructs the frontier nodes' AABBs from their Morton codes
     in-register (bit twiddling, no HBM lookup);
  3. runs the staged SACT via :func:`repro.kernels.sact.kernel.sact_tile` —
     the exact axis formulas of the dense SACT kernel, including the
     tile-level conditional return that skips the 9 edge x edge axes once
     every lane in the block is decided (phase 2 of the two-phase frontier
     cull; phase 1 is the sphere + box-normal stage);
  4. probes terminality from the gathered ``full`` flag / leaf-level scalar;
  5. emits ONE packed int32 word per pair (collide | is_term<<1 | exit<<2).

Blocks that lie entirely at or past ``n_live`` write zeros without touching
the OBB table — the whole-tile analogue of frontier retirement, which is
what stream compaction between levels buys: decided pairs do not just mask
off, their tiles are never scheduled.  The expansion mask and CSR child
codes are pure bit arithmetic on this word plus the frontier's CSR columns,
feeding directly into the prefix-sum/scatter compaction of
:mod:`repro.kernels.compact` — the searchsorted occupancy probe of the
unfused path never runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.octree import jnp_morton_decode
# _EPS is shared with the dense SACT kernel and core/sact.py: the bitwise
# fused-vs-unfused identity depends on all arms using the same epsilon.
from repro.kernels.sact.kernel import _EPS, sact_tile

try:  # CPU-only containers may lack the TPU extension
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def traverse_kernel(scal_i_ref, scal_f_ref, obb_ref, q_ref, code_ref,
                    full_ref, packed_ref, *, bn: int, use_spheres: bool):
    j = pl.program_id(0)
    n_live = scal_i_ref[0]
    is_leaf = scal_i_ref[1]
    cell = scal_f_ref[0]

    @pl.when(j * bn >= n_live)
    def _retired_tile():
        packed_ref[...] = jnp.zeros((bn,), jnp.int32)

    @pl.when(j * bn < n_live)
    def _live_tile():
        # -- gather query boxes by q_idx (one-hot matmul, OOB-safe) -----
        q = q_ref[...]
        m_pad = obb_ref.shape[0]
        onehot = (q[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (bn, m_pad), 1)).astype(jnp.float32)
        rows = jnp.dot(onehot, obb_ref[...],
                       preferred_element_type=jnp.float32)       # (bn, 15)
        oc = [rows[:, i] for i in range(3)]
        oh = [rows[:, 3 + i] for i in range(3)]
        R = [[rows[:, 6 + 3 * i + k] for k in range(3)] for i in range(3)]

        # -- node AABB from Morton code (in-register) -------------------
        xyz = jnp_morton_decode(code_ref[...]).astype(jnp.float32)
        node_c = [scal_f_ref[1 + i] + (xyz[:, i] + 0.5) * cell
                  for i in range(3)]
        node_h = cell * 0.5

        # -- staged SACT, shared tile formulas + conditional return -----
        t = [oc[i] - node_c[i] for i in range(3)]
        A = [[jnp.abs(R[i][k]) + _EPS for k in range(3)] for i in range(3)]
        collide, exit_code = sact_tile(t, R, A, [node_h] * 3, oh,
                                       use_spheres=use_spheres)

        # -- terminality + packed verdict word --------------------------
        is_term = (full_ref[...] != 0) | (is_leaf != 0)
        lane = j * bn + jax.lax.broadcasted_iota(
            jnp.int32, (1, bn), 1).reshape((bn,))
        packed = (collide.astype(jnp.int32)
                  | (is_term.astype(jnp.int32) << 1)
                  | (exit_code << 2))
        packed_ref[...] = jnp.where(lane < n_live, packed, 0)


def make_traverse_call(capacity: int, m_pad: int, bn: int,
                       use_spheres: bool, interpret: bool):
    """Build the pallas_call for one traversal step at a given capacity.

    Inputs: scal_i (2,) int32 [n_live, is_leaf]; scal_f (4,) f32
    [cell, scene_lo xyz]; obb table (m_pad, 15) resident in VMEM; frontier
    q_idx / codes / full blocks.  Output: packed (capacity,) int32 words.
    """
    kernel = functools.partial(traverse_kernel, bn=bn,
                               use_spheres=use_spheres)
    smem = {} if pltpu is None else {"memory_space": pltpu.SMEM}
    return pl.pallas_call(
        kernel,
        grid=(capacity // bn,),
        in_specs=[
            pl.BlockSpec(**smem),                         # scal_i, whole
            pl.BlockSpec(**smem),                         # scal_f, whole
            pl.BlockSpec((m_pad, 15), lambda j: (0, 0)),  # OBB table
            pl.BlockSpec((bn,), lambda j: (j,)),          # q_idx
            pl.BlockSpec((bn,), lambda j: (j,)),          # codes
            pl.BlockSpec((bn,), lambda j: (j,)),          # full flags
        ],
        out_specs=pl.BlockSpec((bn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((capacity,), jnp.int32),
        interpret=interpret,
    )
