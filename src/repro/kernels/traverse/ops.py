"""Fused wavefront traversal step: one level, frontier in / frontier out.

``traverse_step`` is the loop body of the ``wavefront_fused`` engine: it
takes the live (query, CSR node index) frontier pairs and returns the next
level's compacted pairs plus the updated verdicts — the only per-level
HBM-resident intermediates of the fused path.  Compare the
unfused device arm, which materializes ~5 capacity-sized arrays per level
(the 4-field SactResult, two searchsorted probe vectors, the 8x-expanded
candidate codes, and the compaction scratch).

The staged test dispatches like :mod:`repro.kernels.compact`: the Pallas
traversal-step kernel on TPU (or ``interpret=True`` for CPU validation —
untenable inside real traversals because interpret mode unrolls one program
per grid step at trace time), and the jnp two-phase reference elsewhere.
Both arms share this glue, so verdicts, exit codes, and the CSR expansion
are backend-independent; and both cull in two phases — spheres + box-normal
axes decide most pairs, the edge axes run only when survivors remain
(``lax.cond`` batch-wide in jnp, per-tile in the kernel).

Child expansion is O(1) per candidate: occupancy is bit ``j`` of the node's
8-bit CSR child mask, the child's code is ``(code << 3) | j``, and its node
index is ``child_start + popcount(mask & ((1 << j) - 1))`` — no
searchsorted over the level's code array anywhere in the loop body.  The
node index also makes the Morton code *redundant in the frontier*: codes
are re-gathered from the level's code row on entry, so the compaction
moves (query, node index) pairs — no wider than the unfused arm's
(query, code) pairs despite the extra CSR capability.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.octree import DeviceOctree, node_centers_from_codes
from repro.core.quantize import BF16_START_BITS, U8_START_BITS
from repro.core.sact import (SactResult, axis_tests_from_exit,
                             mask_frontier_result, payload_min_update,
                             sact_frontier_staged)
from repro.kernels.compact.ops import compact_pairs
from repro.kernels.persist.ref import csr_child_slots
from repro.kernels.sact.ops import pack_obbs
from repro.kernels.traverse.kernel import make_traverse_call
from repro.kernels.traverse.ref import unpack_verdicts


def _use_pallas_default() -> bool:
    return jax.default_backend() == "tpu"


def _test_pallas(obb_c, obb_h, obb_r, q_idx, codes, full_l, cell, scene_lo,
                 is_leaf, n_live, use_spheres: bool, bn: int,
                 interpret: bool):
    """Pallas arm: packed verdict words for the whole frontier."""
    capacity = q_idx.shape[0]
    pad = (-capacity) % bn
    obb = pack_obbs(obb_c, obb_h, obb_r)
    scal_i = jnp.stack([jnp.asarray(n_live, jnp.int32),
                        jnp.asarray(is_leaf, jnp.int32)])
    scal_f = jnp.concatenate([jnp.asarray(cell, jnp.float32).reshape(1),
                              jnp.asarray(scene_lo, jnp.float32)])
    call = make_traverse_call(capacity + pad, obb.shape[0], bn, use_spheres,
                              interpret)
    packed = call(scal_i, scal_f, obb,
                  jnp.pad(q_idx.astype(jnp.int32), (0, pad)),
                  jnp.pad(codes, (0, pad)),
                  jnp.pad(full_l.astype(jnp.int32), (0, pad)))
    return packed[:capacity]


def traverse_step(obb_c, obb_h, obb_r, dev: DeviceOctree, level, n_live,
                  q_idx, node_idx, verdict, *, use_spheres: bool,
                  use_pallas: Optional[bool] = None,
                  use_pallas_compact: Optional[bool] = None,
                  interpret: Optional[bool] = None, bn: int = 256,
                  owner=None, payload=None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                             dict]:
    """One fused wavefront level for a single scene / query set.

    Pure function of device arrays (level / n_live may be traced); composes
    under jit, vmap, and ``lax.while_loop``.  Returns
    ``(n_next, q_next, idx_next, verdict, info)`` where ``info`` carries the
    per-pair quantities the work model accounts (valid / is_term /
    SactResult / codes / n_new).

    ``verdict`` is the (M,) bool collide array, or — when the plan carries
    owner / payload lanes (:mod:`repro.engine.plan`) — the (G,) int32
    per-group ``best`` array: a terminal hit folds the pair's payload in
    with a min, and a pair expands only while its payload could still beat
    its group's best, which compacts first-hit-decided groups out of the
    frontier exactly like decided waypoint lanes.  The Pallas verdict
    kernel is unchanged either way: it emits per-pair packed words, and the
    payload fold happens in this glue.
    """
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    capacity = q_idx.shape[0]
    lane = jnp.arange(capacity, dtype=jnp.int32)
    valid = lane < n_live
    depth = dev.depth

    def level_row(arr):
        return jax.lax.dynamic_index_in_dim(arr, level, keepdims=False)

    cell = level_row(dev.cell_sizes)
    n_max = dev.codes.shape[-1]
    idx_c = jnp.clip(node_idx, 0, n_max - 1)
    # One (cap, words) gather for all per-node metadata.  Compressed
    # formats (repro.core.quantize) pack topology into word 0; geometry
    # comes from the retained per-level code plane, which the fused arm
    # keeps resident anyway (the Pallas verdict kernel takes codes as an
    # input), so the decode adds no gathers.
    fmt = getattr(dev, "meta_format", "fp32")
    meta = level_row(dev.node_meta)[idx_c]
    if fmt == "fp32":
        codes = jax.lax.bitcast_convert_type(meta[:, 0], jnp.uint32)
        full_l = meta[:, 1] != 0
        child_start = meta[:, 2]
        child_mask = meta[:, 3]
    else:
        w0 = meta[:, 0]
        full_l = w0 < 0
        child_mask = w0 & 0xFF
        start_bits = BF16_START_BITS if fmt == "bf16" else U8_START_BITS
        child_start = (w0 >> 8) & ((1 << start_bits) - 1)
        codes = level_row(dev.codes)[idx_c]
    is_leaf = level == depth

    if use_pallas:
        packed = _test_pallas(obb_c, obb_h, obb_r, q_idx, codes, full_l,
                              cell, dev.scene_lo, is_leaf, n_live,
                              use_spheres, bn, interpret)
        collide_raw, is_term, exit_code = unpack_verdicts(packed)
        n_sphere = jnp.full((capacity,), 2 if use_spheres else 0, jnp.int32)
        res = mask_frontier_result(
            SactResult(collide=collide_raw, exit_code=exit_code,
                       axis_tests=axis_tests_from_exit(exit_code),
                       sphere_tests=n_sphere), valid)
        is_term = is_term | is_leaf
    else:
        node_c, node_h = node_centers_from_codes(codes, dev.scene_lo, cell)
        res = sact_frontier_staged(obb_c[q_idx], obb_h[q_idx], obb_r[q_idx],
                                   node_c, node_h, valid,
                                   use_spheres=use_spheres)
        is_term = jnp.where(is_leaf, True, full_l)

    overlap = res.collide & valid
    term_hit = overlap & is_term
    if owner is not None or payload is not None:
        pay = (jnp.zeros(q_idx.shape, jnp.int32) if payload is None
               else payload[q_idx])
        own = q_idx if owner is None else owner[q_idx]
        verdict = payload_min_update(verdict, own, pay, term_hit)
        undecided = pay < verdict[own]
    else:
        verdict = verdict.at[q_idx].max(term_hit)
        undecided = ~verdict[q_idx]

    # ---- O(1) CSR expansion + on-device stream compaction -------------
    occupied, offs = csr_child_slots(child_mask)                   # (cap, 8)
    cand_idx = child_start[:, None] + offs
    # Early exit: decided queries/groups retire their whole wavefront share.
    expand = overlap & ~is_term & undecided
    child_live = (expand[:, None] & occupied).reshape(-1)          # (cap*8,)
    n_new = jnp.sum(child_live.astype(jnp.int32))
    cnt, q_next, idx_next = compact_pairs(
        child_live, jnp.repeat(q_idx, 8),
        cand_idx.reshape(-1).astype(jnp.uint32), capacity,
        use_pallas=use_pallas_compact)
    idx_next = idx_next.astype(jnp.int32)
    info = dict(valid=valid, is_term=is_term, res=res, codes=codes,
                n_new=n_new)
    return cnt, q_next, idx_next, verdict, info
