"""jnp oracle for the fused traversal-step test kernel.

Contract (shared with kernel.py): given one wavefront level's frontier —
``q_idx``/``codes``/``full`` lanes plus the resident packed OBB table — emit
one packed int32 word per lane:

  bit 0      collide   (staged SACT verdict)
  bit 1      is_term   (leaf level, or full-subtree internal node)
  bits 2..6  exit_code (see repro.core.sact EXIT_*)

Lanes at or past ``n_live`` pack to 0.  The axis-test and sphere-test work
counters are *derived* from the exit code by the caller
(:func:`repro.core.sact.axis_tests_from_exit`), so one word per pair is the
kernel's entire HBM output — that, plus the compacted next frontier, is the
whole per-level traffic of the fused path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sact import SactResult, sact_frontier_staged


def pack_verdicts(res: SactResult, is_term) -> jnp.ndarray:
    """(collide, is_term, exit_code) -> packed int32 word per lane."""
    return (res.collide.astype(jnp.int32)
            | (is_term.astype(jnp.int32) << 1)
            | (res.exit_code << 2))


def unpack_verdicts(packed):
    """Packed word -> (collide bool, is_term bool, exit_code int32)."""
    return (packed & 1) != 0, (packed & 2) != 0, packed >> 2


def traverse_test_ref(obb_c, obb_h, obb_r, q_idx, node_c, node_h, full,
                      is_leaf, n_live, use_spheres: bool):
    """Reference traversal-step test: gather + staged SACT + terminality.

    ``node_c``/``node_h`` are the frontier nodes' AABB centres/halves (the
    kernel reconstructs them from Morton codes in-register); ``full`` the
    gathered full-subtree flags; ``is_leaf`` whether this level is the leaf
    level.  Returns the packed (capacity,) verdict words.
    """
    capacity = q_idx.shape[0]
    valid = jnp.arange(capacity, dtype=jnp.int32) < n_live
    res = sact_frontier_staged(obb_c[q_idx], obb_h[q_idx], obb_r[q_idx],
                               node_c, node_h, valid,
                               use_spheres=use_spheres)
    is_term = jnp.where(is_leaf, True, full)
    return jnp.where(valid, pack_verdicts(res, is_term), 0)
